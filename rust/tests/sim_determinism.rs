//! Regression tests for the determinism substrate the simulator (and now
//! the wire subsystem) leans on: `sim::event` tie-breaking and
//! `util::BitVec` word-boundary behaviour.

use fediac::sim::EventQueue;
use fediac::util::BitVec;

#[test]
fn equal_timestamps_pop_in_insertion_order() {
    // The documented contract: float-coincident events are FIFO. A mix of
    // distinct and tied timestamps, scheduled out of order.
    let mut q = EventQueue::new();
    q.schedule(2.0, "t2-first");
    q.schedule(1.0, "t1-first");
    q.schedule(2.0, "t2-second");
    q.schedule(1.0, "t1-second");
    q.schedule(2.0, "t2-third");
    q.schedule(0.5, "t05");
    let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
    assert_eq!(
        order,
        vec!["t05", "t1-first", "t1-second", "t2-first", "t2-second", "t2-third"]
    );
}

#[test]
fn large_tie_bucket_is_stable() {
    // Heap order must not leak through: 1000 events at one timestamp pop
    // exactly in insertion order.
    let mut q = EventQueue::new();
    for i in 0..1000 {
        q.schedule(3.25, i);
    }
    let order: Vec<usize> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
    assert_eq!(order, (0..1000).collect::<Vec<_>>());
}

#[test]
fn ties_after_interleaved_pops_stay_fifo() {
    // Scheduling between pops (the simulator's actual pattern) keeps the
    // per-timestamp FIFO contract.
    let mut q = EventQueue::new();
    q.schedule(1.0, 0);
    q.schedule(1.0, 1);
    assert_eq!(q.pop().unwrap().1, 0);
    q.schedule(1.0, 2); // same timestamp as the remaining event
    assert_eq!(q.pop().unwrap().1, 1);
    assert_eq!(q.pop().unwrap().1, 2);
    assert!(q.is_empty());
}

#[test]
fn bitvec_word_boundary_indices() {
    // Bit 0, the last bit of word 0, and the first bit of word 1 — the
    // indices a shift bug would corrupt first.
    for d in [65usize, 128, 130] {
        let mut bv = BitVec::zeros(d);
        for &i in &[0usize, 63, 64] {
            assert!(!bv.get(i), "d={d}: bit {i} dirty at init");
            bv.set(i, true);
            assert!(bv.get(i), "d={d}: bit {i} did not set");
        }
        assert_eq!(bv.count_ones(), 3, "d={d}");
        // Neighbours unaffected.
        assert!(!bv.get(1) && !bv.get(62), "d={d}");
        if d > 65 {
            assert!(!bv.get(65), "d={d}");
        }
        let ones: Vec<usize> = bv.iter_ones().collect();
        assert_eq!(ones, vec![0, 63, 64], "d={d}");
        // Clearing across the boundary works too.
        bv.set(63, false);
        bv.set(64, false);
        assert_eq!(bv.count_ones(), 1, "d={d}");
    }
}

#[test]
fn bitvec_last_bit_and_byte_roundtrip_at_boundaries() {
    // Lengths straddling byte and word boundaries: the final bit must
    // survive to_bytes/from_bytes and the tail must stay masked.
    for d in [1usize, 7, 8, 9, 63, 64, 65, 127, 128, 129] {
        let bv = BitVec::from_indices(d, &[0, d - 1]);
        let rt = BitVec::from_bytes(d, &bv.to_bytes());
        assert_eq!(rt, bv, "d={d}");
        assert!(rt.get(d - 1), "d={d}: last bit lost");
        assert_eq!(rt.count_ones(), if d == 1 { 1 } else { 2 }, "d={d}");
        // A payload with garbage tail bits must be masked on parse.
        let mut bytes = bv.to_bytes();
        if d % 8 != 0 {
            *bytes.last_mut().unwrap() |= 0xFF << (d % 8);
            let masked = BitVec::from_bytes(d, &bytes);
            assert_eq!(masked, bv, "d={d}: tail bits leaked");
        }
    }
}
