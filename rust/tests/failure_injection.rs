//! Failure-injection tests: packet loss, retransmission, duplicates and
//! register-memory pressure — the switch-side robustness mechanisms (§II
//! scoreboard + end-host retransmission; §III-B memory waves).

use fediac::configx::{AlgorithmKind, DatasetKind, ExperimentConfig, Partition};
use fediac::experiments::{run, RunOptions};
use fediac::switch::{Mark, RegisterFile, UpdateAggregator, VoteAggregator};
use fediac::util::BitVec;

fn cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid);
    cfg.algorithm = AlgorithmKind::FediAc;
    cfg.num_clients = 5;
    cfg.rounds = 8;
    cfg.samples_per_client = 40;
    cfg.fediac.threshold_a = 2;
    cfg
}

#[test]
fn loss_increases_time_and_traffic_not_accuracy() {
    let clean = run(&cfg(), &RunOptions::default()).unwrap();
    let mut lossy_cfg = cfg();
    lossy_cfg.loss_rate = 0.15;
    let lossy = run(&lossy_cfg, &RunOptions::default()).unwrap();

    // Retransmission is transparent to the learning process: the model
    // trajectory is a function of the (identical) aggregation content.
    for (a, b) in clean.records.iter().zip(&lossy.records) {
        assert_eq!(a.test_accuracy, b.test_accuracy, "loss changed the model");
    }
    assert!(
        lossy.final_time() > clean.final_time(),
        "15% loss must slow the run: {:.3} !> {:.3}",
        lossy.final_time(),
        clean.final_time()
    );
    assert!(
        lossy.total_traffic().up_bytes > clean.total_traffic().up_bytes,
        "retransmitted frames must be charged"
    );
}

#[test]
fn heavy_loss_still_converges() {
    let mut heavy = cfg();
    heavy.loss_rate = 0.4;
    heavy.rounds = 10;
    let rec = run(&heavy, &RunOptions::default()).unwrap();
    assert!(rec.best_accuracy().unwrap() > 0.5, "40% loss broke convergence");
}

#[test]
fn duplicate_votes_do_not_inflate_gia() {
    // Retransmitted phase-1 packets reach the switch twice; the
    // scoreboard must drop the second copy or vote counts corrupt.
    let d = 64;
    let mut rf = RegisterFile::new(d * 2);
    let mut agg = VoteAggregator::new(&mut rf, d, 2, 1, d).unwrap();
    let votes = BitVec::from_indices(d, &[1, 2, 3]);
    assert_eq!(agg.ingest(0, 0, &votes.to_bytes()), Mark::Fresh);
    assert_eq!(agg.ingest(0, 0, &votes.to_bytes()), Mark::Duplicate);
    assert_eq!(agg.ingest(1, 0, &BitVec::zeros(d).to_bytes()), Mark::Completed);
    // Threshold 1: selected = client-0 votes exactly once each.
    let gia = agg.gia();
    assert_eq!(gia.iter_ones().collect::<Vec<_>>(), vec![1, 2, 3]);
    assert_eq!(agg.counters()[1], 1, "duplicate was double counted");
    agg.release(&mut rf);
}

#[test]
fn duplicate_updates_do_not_double_aggregate() {
    let mut rf = RegisterFile::new(64);
    let mut agg = UpdateAggregator::new(&mut rf, 4, 2, 4).unwrap();
    agg.ingest(0, 0, &[5, 5, 5, 5]);
    assert_eq!(agg.ingest(0, 0, &[5, 5, 5, 5]), Mark::Duplicate);
    agg.ingest(1, 0, &[1, 1, 1, 1]);
    assert_eq!(agg.aggregate(), &[6, 6, 6, 6]);
    agg.release(&mut rf);
}

#[test]
fn tiny_switch_memory_forces_waves_but_same_result() {
    // Starving the register file must slow the round (waves) without
    // changing the aggregation content (accuracy trajectory identical).
    // Needs a model spanning multiple aggregation blocks (d ≈ 50k).
    let big = || {
        let mut c = cfg();
        c.dataset = DatasetKind::SynthCifar10;
        c.rounds = 4;
        c
    };
    let normal = run(&big(), &RunOptions::default()).unwrap();
    let mut starved_cfg = big();
    starved_cfg.ps.memory_bytes = 4 * 1024; // 4 KB of registers
    let starved = run(&starved_cfg, &RunOptions::default()).unwrap();
    for (a, b) in normal.records.iter().zip(&starved.records) {
        assert_eq!(a.test_accuracy, b.test_accuracy);
    }
    assert!(
        starved.final_time() > normal.final_time(),
        "memory starvation must cost time: {:.3} !> {:.3}",
        starved.final_time(),
        normal.final_time()
    );
}

#[test]
fn multi_ps_same_model_faster_rounds() {
    // §VI extension: sharding across 4 switches must not change content.
    let single = run(&cfg(), &RunOptions::default()).unwrap();
    let mut multi_cfg = cfg();
    multi_cfg.num_switches = 4;
    let multi = run(&multi_cfg, &RunOptions::default()).unwrap();
    for (a, b) in single.records.iter().zip(&multi.records) {
        assert_eq!(a.test_accuracy, b.test_accuracy);
    }
    assert!(multi.final_time() <= single.final_time() * 1.05);
}
