//! Loopback integration tests: full FediAC rounds over real UDP sockets.
//!
//! The acceptance bar: two jobs running concurrently on one server, each
//! with ≥ 4 clients, where the wire-aggregated update **bit-exactly**
//! matches the in-process `algorithms::fediac` result for the same seeded
//! inputs. The client driver shares its seed derivation with the
//! simulated round (`client::protocol`), so the comparison is exact, not
//! approximate.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use fediac::algorithms::{common, fediac::FediAc, Algorithm};
use fediac::client::{protocol, ClientOptions, FediacClient, RoundOutcome};
use fediac::compress::{self, deduce_gia};
use fediac::configx::{DatasetKind, ExperimentConfig, Partition, PsProfile};
use fediac::data::synth;
use fediac::fl::{FlEnv, NativeBackend};
use fediac::server::{serve, ServeOptions};
use fediac::util::Rng;

const N_CLIENTS: usize = 4;

fn make_env(seed: u64) -> FlEnv {
    let cfg = ExperimentConfig {
        num_clients: N_CLIENTS,
        seed,
        ..ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid)
    };
    let fd = synth::generate(cfg.dataset, cfg.partition, N_CLIENTS, 40, cfg.seed);
    let backend = Box::new(NativeBackend::new(fd, 16, cfg.local_iters, 8, cfg.seed));
    let mut env = FlEnv::new(cfg, backend);
    env.init_model();
    env
}

/// Everything the wire side needs to replay one in-process FediAC round.
struct SimRound {
    seed: u64,
    d: usize,
    k: usize,
    threshold_a: u16,
    bits_b: usize,
    /// The exact round-1 update vectors the simulated round aggregated.
    updates: Vec<Vec<f32>>,
    /// Global model before round 1.
    params_before: Vec<f32>,
    /// Global model after round 1 (the ground truth to reproduce).
    params_after: Vec<f32>,
}

/// Run bootstrap + round 1 of the simulated FediAC and capture the inputs
/// and outputs needed to replay round 1 over the wire.
fn run_sim_round(seed: u64) -> SimRound {
    // Reference run: bootstrap (round 0) then one compressed round.
    let mut env = make_env(seed);
    let mut alg = FediAc::new(&env.cfg, env.d());
    alg.run_round(&mut env, 0).unwrap();
    let params_before = env.params.clone();
    let bits_b = alg.bits().expect("bootstrap sets b");
    alg.run_round(&mut env, 1).unwrap();
    let params_after = env.params.clone();

    // Twin run: identical env, stopped after bootstrap, to re-derive the
    // round-1 local updates (local training is deterministic per seed and
    // the post-bootstrap residuals are all zero).
    let mut env2 = make_env(seed);
    let mut alg2 = FediAc::new(&env2.cfg, env2.d());
    alg2.run_round(&mut env2, 0).unwrap();
    assert_eq!(env2.params, params_before, "twin env diverged in bootstrap");
    let d = env2.d();
    let lr = env2.cfg.lr.at(1) as f32;
    let zero_residuals = vec![vec![0.0f32; d]; N_CLIENTS];
    let local = common::local_training(&mut env2, 1, lr, Some(&zero_residuals));

    SimRound {
        seed,
        d,
        k: protocol::votes_per_client(d, env2.cfg.fediac.k_frac),
        threshold_a: env2.cfg.fediac.threshold_a as u16,
        bits_b,
        updates: local.updates,
        params_before,
        params_after,
    }
}

fn client_opts(server: String, job: u32, id: u16, sim: &SimRound) -> ClientOptions {
    let mut opts = ClientOptions::new(server, job, id, sim.d, N_CLIENTS as u16);
    opts.threshold_a = sim.threshold_a;
    opts.k = sim.k;
    opts.bits_b = sim.bits_b;
    opts.backend_seed = sim.seed;
    opts.timeout = Duration::from_millis(300);
    opts.max_retries = 100;
    opts
}

/// Run all four clients of one job concurrently and return their outcomes.
fn run_job_clients(
    server: std::net::SocketAddr,
    job: u32,
    sim: &SimRound,
    send_loss: f64,
    payload_budget: Option<usize>,
    dropped: &AtomicU64,
    retransmitted: &AtomicU64,
) -> Vec<RoundOutcome> {
    let mut outcomes: Vec<Option<RoundOutcome>> = (0..N_CLIENTS).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (i, slot) in outcomes.iter_mut().enumerate() {
            let update = &sim.updates[i];
            handles.push(scope.spawn(move || {
                let mut opts = client_opts(server.to_string(), job, i as u16, sim);
                opts.send_loss = send_loss;
                if let Some(b) = payload_budget {
                    opts.payload_budget = b;
                }
                let mut client = FediacClient::connect(opts).unwrap();
                let out = client.run_round(1, update).unwrap();
                dropped.fetch_add(client.stats.dropped_sends, Ordering::Relaxed);
                retransmitted.fetch_add(client.stats.retransmissions, Ordering::Relaxed);
                *slot = Some(out);
            }));
        }
        for h in handles {
            h.join().expect("client thread panicked");
        }
    });
    outcomes.into_iter().map(|o| o.unwrap()).collect()
}

#[test]
fn two_concurrent_jobs_match_in_process_fediac_bit_exactly() {
    let sim_a = run_sim_round(7);
    let sim_b = run_sim_round(21);

    let handle = serve(&ServeOptions::default()).unwrap();
    let addr = handle.local_addr();
    let drops = AtomicU64::new(0);
    let retx = AtomicU64::new(0);

    // Both jobs' clients run at the same time against one daemon.
    let (out_a, out_b) = std::thread::scope(|scope| {
        let ha = scope.spawn(|| run_job_clients(addr, 401, &sim_a, 0.0, None, &drops, &retx));
        let hb =
            scope.spawn(|| run_job_clients(addr, 402, &sim_b, 0.0, Some(64), &drops, &retx));
        (ha.join().unwrap(), hb.join().unwrap())
    });

    for (sim, outcomes, job) in [(&sim_a, &out_a, 401u32), (&sim_b, &out_b, 402u32)] {
        // Every client of the job saw the same consensus and aggregate.
        for o in outcomes.iter().skip(1) {
            assert_eq!(o.gia, outcomes[0].gia, "job {job}: GIA differs across clients");
            assert_eq!(
                o.aggregate, outcomes[0].aggregate,
                "job {job}: aggregate differs across clients"
            );
        }
        let out = &outcomes[0];
        assert!(!out.gia_indices.is_empty(), "job {job}: empty consensus");
        // The PS-folded global max equals the simulation's m.
        let m = common::global_max_abs(&sim.updates);
        assert_eq!(out.global_max, m, "job {job}: global max differs");
        // Applying the wire round to the pre-round model reproduces the
        // simulated post-round model bit-for-bit.
        let mut params = sim.params_before.clone();
        out.apply(&mut params);
        assert_eq!(
            params, sim.params_after,
            "job {job}: wire round diverged from algorithms::fediac"
        );
    }

    let stats = handle.stats();
    assert_eq!(stats.jobs_created, 2);
    assert_eq!(stats.rounds_completed, 2);
    handle.shutdown();
}

/// Reference aggregation for synthetic (non-training) inputs, built from
/// the same primitives the simulated round drives.
fn reference_round(
    updates: &[Vec<f32>],
    seed: u64,
    round: usize,
    k: usize,
    a: usize,
    bits_b: usize,
) -> (Vec<usize>, Vec<i32>, f32) {
    let votes: Vec<_> = updates
        .iter()
        .enumerate()
        .map(|(i, u)| protocol::client_vote(u, k, seed, round, i))
        .collect();
    let gia = deduce_gia(&votes, a);
    let indices: Vec<usize> = gia.iter_ones().collect();
    let m = common::global_max_abs(updates);
    let f = compress::scale_factor(bits_b, updates.len(), m);
    let mask = gia.to_f32_mask();
    let mut lanes = vec![0i32; indices.len()];
    for (i, u) in updates.iter().enumerate() {
        let (q, _) = protocol::client_quantize(u, &mask, f, seed, round, i);
        for (slot, &g) in indices.iter().enumerate() {
            lanes[slot] += q[g];
        }
    }
    (indices, lanes, f)
}

fn synthetic_updates(seed: u64, d: usize) -> Vec<Vec<f32>> {
    (0..N_CLIENTS)
        .map(|i| {
            let mut rng = Rng::new(seed ^ (i as u64) << 16);
            (0..d).map(|_| (rng.gaussian() * 0.02) as f32).collect()
        })
        .collect()
}

#[test]
fn lossy_uplink_retransmits_and_stays_exact() {
    // 30% of every client's outgoing datagrams are dropped before the
    // wire — the protocol must finish anyway and produce the identical
    // aggregate (scoreboards drop the duplicate retransmissions).
    let d = 500;
    let seed = 99u64;
    let updates = synthetic_updates(seed, d);
    let k = protocol::votes_per_client(d, 0.05);
    let (ref_indices, ref_lanes, _) = reference_round(&updates, seed, 1, k, 1, 12);
    assert!(!ref_indices.is_empty());

    let handle = serve(&ServeOptions::default()).unwrap();
    let sim = SimRound {
        seed,
        d,
        k,
        threshold_a: 1,
        bits_b: 12,
        updates,
        params_before: Vec::new(),
        params_after: Vec::new(),
    };
    let drops = AtomicU64::new(0);
    let retx = AtomicU64::new(0);
    let outcomes =
        run_job_clients(handle.local_addr(), 77, &sim, 0.30, Some(64), &drops, &retx);
    for o in &outcomes {
        assert_eq!(o.gia_indices, ref_indices, "lossy link changed the consensus");
        assert_eq!(o.aggregate, ref_lanes, "lossy link corrupted the aggregate");
    }
    assert!(drops.load(Ordering::Relaxed) > 0, "loss injector never fired");
    let stats = handle.stats();
    assert!(stats.duplicates > 0 || retx.load(Ordering::Relaxed) > 0);
    handle.shutdown();
}

#[test]
fn register_pressure_forces_waves_over_the_wire() {
    // A server with barely one vote block of registers (budget 16 →
    // 16·8·2 = 256 B per block) must process a 2-block vote space in two
    // waves and still aggregate exactly.
    let d = 256; // 2 vote blocks at budget 16
    let seed = 5u64;
    let updates = synthetic_updates(seed, d);
    let k = protocol::votes_per_client(d, 0.05);
    let (ref_indices, ref_lanes, _) = reference_round(&updates, seed, 1, k, 2, 12);

    let opts = ServeOptions {
        profile: PsProfile { memory_bytes: 300, ..PsProfile::high() },
        ..ServeOptions::default()
    };
    let handle = serve(&opts).unwrap();
    let sim = SimRound {
        seed,
        d,
        k,
        threshold_a: 2,
        bits_b: 12,
        updates,
        params_before: Vec::new(),
        params_after: Vec::new(),
    };
    let drops = AtomicU64::new(0);
    let retx = AtomicU64::new(0);
    let outcomes =
        run_job_clients(handle.local_addr(), 12, &sim, 0.0, Some(16), &drops, &retx);
    for o in &outcomes {
        assert_eq!(o.gia_indices, ref_indices);
        assert_eq!(o.aggregate, ref_lanes);
    }
    let stats = handle.stats();
    assert!(stats.waves >= 1, "no wave advance despite tiny register file");
    handle.shutdown();
}
