//! Quorum wire tests (PROTOCOL.md §11), the fault-plane acceptance
//! criteria end to end on a real UDP socket:
//!
//! 1. A client that dies before a round cannot stall a quorum job past
//!    its phase deadline — on any of the three I/O backends. Every
//!    phase is force-closed by the deadline (never organically — the
//!    dead client guarantees that), the surviving quorum's consensus
//!    and aggregate are bit-exact against a quorum-aware reference
//!    that folds votes, scale and lanes over the survivors only while
//!    keeping the spec's full N in the quantisation scale, and the
//!    round latency stays deadline-bound, far under idle reclamation
//!    or the clients' retry budgets.
//!
//! 2. A `quorum = 0` deployment is bit-identical to the legacy all-N
//!    protocol across all three backends, even with an absurdly short
//!    phase deadline configured: legacy rounds never arm the deadline,
//!    never quorum-close, and reproduce the all-N reference down to
//!    delta and residual.

use std::time::{Duration, Instant};

use fediac::client::{protocol, ClientOptions, FediacClient, RoundOutcome};
use fediac::compress::{self, deduce_gia};
use fediac::server::{serve, IoBackend, JobLimits, ServeOptions};
use fediac::util::{BitVec, Rng};

const BACKENDS: [IoBackend; 3] =
    [IoBackend::Threaded, IoBackend::Reactor, IoBackend::Fleet];

/// Deterministic synthetic update for (seed, client, round) — the same
/// recipe the chaos wire tests use.
fn synthetic_update(seed: u64, d: usize, client: usize, round: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ (client as u64) << 16 ^ (round as u64) << 40);
    (0..d).map(|_| (rng.gaussian() * 0.02) as f32).collect()
}

/// Quorum-aware pure reference: votes, the vote-frame max fold and the
/// lane sums run over `contributors` (client id, update) only, but the
/// quantisation scale keeps the *spec's* `n_clients` — survivors'
/// contributions must land on the same grid the full fleet would have
/// used. With `contributors` = everyone this reduces to the legacy
/// all-N reference.
fn quorum_reference(
    contributors: &[(usize, Vec<f32>)],
    seed: u64,
    round: usize,
    k: usize,
    a: usize,
    n_clients: usize,
) -> (Vec<usize>, Vec<i32>, f32) {
    let votes: Vec<BitVec> = contributors
        .iter()
        .map(|(c, u)| protocol::client_vote(u, k, seed, round, *c))
        .collect();
    let gia = deduce_gia(&votes, a);
    let indices: Vec<usize> = gia.iter_ones().collect();
    let m = contributors
        .iter()
        .map(|(_, u)| compress::max_abs(u))
        .fold(f32::MIN_POSITIVE, f32::max);
    let f = compress::scale_factor(12, n_clients, m);
    let mask = gia.to_f32_mask();
    let mut lanes = vec![0i32; indices.len()];
    for (c, u) in contributors {
        let (q, _) = protocol::client_quantize(u, &mask, f, seed, round, *c);
        for (slot, &g) in indices.iter().enumerate() {
            lanes[slot] += q[g];
        }
    }
    (indices, lanes, m)
}

// ---- the chaos acceptance test: a dead client cannot stall the round ------

#[test]
fn dead_client_cannot_stall_a_quorum_round_past_its_deadline() {
    // N = 3, Q = 2: clients 0 and 1 run three rounds; client 2 never
    // even connects. Without the quorum plane every phase would wait
    // on client 2 until the survivors exhausted their retry budgets.
    const SURVIVORS: [usize; 2] = [0, 1];
    const N: u16 = 3;
    const Q: u16 = 2;
    const ROUNDS: usize = 3;
    let d = 600;
    let seed = 77u64;
    let k = protocol::votes_per_client(d, 0.05);
    let deadline = Duration::from_millis(250);

    for backend in BACKENDS {
        let handle = serve(&ServeOptions {
            io_backend: backend,
            limits: JobLimits { phase_deadline: deadline, ..JobLimits::default() },
            ..ServeOptions::default()
        })
        .unwrap();
        let server = handle.local_addr();
        let started = Instant::now();
        let mut per_client: Vec<Option<Vec<RoundOutcome>>> = vec![None; SURVIVORS.len()];
        std::thread::scope(|scope| {
            for (slot, &client_id) in per_client.iter_mut().zip(&SURVIVORS) {
                scope.spawn(move || {
                    let mut opts =
                        ClientOptions::new(server.to_string(), 801, client_id as u16, d, N);
                    // a = 1 keeps the survivors' consensus non-empty for
                    // any seed (the union of their votes).
                    opts.threshold_a = 1;
                    opts.k = k;
                    opts.backend_seed = seed;
                    opts.payload_budget = 64;
                    // Longer than the phase deadline: the round must be
                    // rescued by the server's forced close, not by
                    // client retransmission.
                    opts.timeout = Duration::from_millis(400);
                    opts.max_retries = 200;
                    opts.quorum = Q;
                    let mut client = FediacClient::connect(opts).unwrap();
                    *slot = Some(
                        (1..=ROUNDS)
                            .map(|round| {
                                let update = synthetic_update(seed, d, client_id, round);
                                client.run_round(round, &update).unwrap()
                            })
                            .collect(),
                    );
                });
            }
        });
        let elapsed = started.elapsed();
        // Liveness: three deadline-bound rounds (two 250 ms forced
        // closes each) must land in seconds — nowhere near the 30 s
        // idle-reclaim horizon or the clients' 200 × 400 ms retry
        // budget a stalled phase would have burned through.
        assert!(
            elapsed < Duration::from_secs(15),
            "{}: quorum rounds stalled ({elapsed:?} for {ROUNDS} rounds)",
            backend.name()
        );
        let stats = handle.stats();
        assert_eq!(
            stats.rounds_completed as usize, ROUNDS,
            "{}: not every round completed without client 2",
            backend.name()
        );
        // The dead client makes organic closure impossible: both phases
        // of every round must have been quorum closes.
        assert_eq!(
            stats.quorum_closes as usize,
            2 * ROUNDS,
            "{}: expected every phase to force-close at the deadline",
            backend.name()
        );
        assert_eq!(
            stats.idle_releases, 0,
            "{}: a deadline-bound round sat idle long enough to be reclaimed",
            backend.name()
        );
        handle.shutdown();

        // Bit-exactness: both survivors decode the quorum reference —
        // votes, scale fold and lanes over {0, 1}, spec N = 3.
        let outs: Vec<Vec<RoundOutcome>> =
            per_client.into_iter().map(|o| o.unwrap()).collect();
        for round in 1..=ROUNDS {
            let contributors: Vec<(usize, Vec<f32>)> = SURVIVORS
                .iter()
                .map(|&c| (c, synthetic_update(seed, d, c, round)))
                .collect();
            let (ref_idx, ref_lanes, ref_max) =
                quorum_reference(&contributors, seed, round, k, 1, N as usize);
            assert!(!ref_idx.is_empty(), "round {round}: degenerate reference");
            for (out_rounds, &c) in outs.iter().zip(&SURVIVORS) {
                let out = &out_rounds[round - 1];
                assert_eq!(
                    out.gia_indices, ref_idx,
                    "{} survivor {c} round {round}: consensus diverged",
                    backend.name()
                );
                assert_eq!(
                    out.aggregate, ref_lanes,
                    "{} survivor {c} round {round}: aggregate diverged",
                    backend.name()
                );
                assert_eq!(
                    out.global_max, ref_max,
                    "{} survivor {c} round {round}: scale must fold over the \
                     quorum's votes only",
                    backend.name()
                );
            }
        }
    }
}

// ---- legacy equivalence: quorum = 0 is the pre-quorum protocol ------------

#[test]
fn quorum_zero_fleet_is_bit_identical_across_all_three_backends() {
    const N: usize = 4;
    const ROUNDS: usize = 2;
    let d = 600;
    let seed = 99u64;
    let k = protocol::votes_per_client(d, 0.05);

    let mut per_backend: Vec<Vec<Vec<RoundOutcome>>> = Vec::new();
    for backend in BACKENDS {
        let handle = serve(&ServeOptions {
            io_backend: backend,
            // A 1 ms deadline that must never fire: quorum = 0 rounds
            // only ever close organically.
            limits: JobLimits {
                phase_deadline: Duration::from_millis(1),
                ..JobLimits::default()
            },
            ..ServeOptions::default()
        })
        .unwrap();
        let server = handle.local_addr();
        let mut per_client: Vec<Option<Vec<RoundOutcome>>> = vec![None; N];
        std::thread::scope(|scope| {
            for (client_id, slot) in per_client.iter_mut().enumerate() {
                scope.spawn(move || {
                    let mut opts = ClientOptions::new(
                        server.to_string(),
                        802,
                        client_id as u16,
                        d,
                        N as u16,
                    );
                    opts.threshold_a = 2;
                    opts.k = k;
                    opts.backend_seed = seed;
                    opts.payload_budget = 64;
                    opts.timeout = Duration::from_millis(300);
                    opts.max_retries = 200;
                    // `quorum` stays at its default 0: the spec encodes
                    // as the legacy 12-byte form.
                    let mut client = FediacClient::connect(opts).unwrap();
                    *slot = Some(
                        (1..=ROUNDS)
                            .map(|round| {
                                let update = synthetic_update(seed, d, client_id, round);
                                client.run_round(round, &update).unwrap()
                            })
                            .collect(),
                    );
                });
            }
        });
        let stats = handle.stats();
        assert_eq!(
            stats.rounds_completed as usize, ROUNDS,
            "{}: legacy rounds did not complete",
            backend.name()
        );
        assert_eq!(
            stats.quorum_closes, 0,
            "{}: a quorum close fired on a quorum = 0 job",
            backend.name()
        );
        handle.shutdown();
        per_backend.push(per_client.into_iter().map(|o| o.unwrap()).collect());
    }

    // Every backend, every client, every round matches the all-N
    // reference (the quorum reference over everyone)…
    for round in 1..=ROUNDS {
        let contributors: Vec<(usize, Vec<f32>)> =
            (0..N).map(|c| (c, synthetic_update(seed, d, c, round))).collect();
        let (ref_idx, ref_lanes, ref_max) =
            quorum_reference(&contributors, seed, round, k, 2, N);
        for (outcomes, backend) in per_backend.iter().zip(BACKENDS) {
            for (c, rounds) in outcomes.iter().enumerate() {
                let out = &rounds[round - 1];
                assert_eq!(
                    out.gia_indices,
                    ref_idx,
                    "{} client {c} round {round}: consensus diverged from all-N",
                    backend.name()
                );
                assert_eq!(
                    out.aggregate,
                    ref_lanes,
                    "{} client {c} round {round}: aggregate diverged from all-N",
                    backend.name()
                );
                assert_eq!(out.global_max, ref_max, "{} client {c}", backend.name());
            }
        }
    }
    // …and the backends are bit-identical to each other, down to the
    // applied delta and carried residual.
    for pair in per_backend.windows(2) {
        for (a, b) in pair[0].iter().zip(&pair[1]) {
            for (x, y) in a.iter().zip(b) {
                assert_eq!(x.gia, y.gia, "quorum = 0: backend GIAs differ");
                assert_eq!(x.aggregate, y.aggregate, "quorum = 0: aggregates differ");
                assert_eq!(x.global_max, y.global_max);
                assert_eq!(x.delta, y.delta, "quorum = 0: deltas differ");
                assert_eq!(x.residual, y.residual, "quorum = 0: residuals differ");
            }
        }
    }
}
