//! I/O-backend equivalence tests: the threaded (thread-per-job),
//! reactor (single-thread event loop) and fleet (SO_REUSEPORT
//! multi-core) backends must be **bit-exact** with each other and with
//! the in-process `algorithms::fediac` simulation — single-server and
//! N=2 sharded, clean and under both-direction chaos. Plus each
//! backend's whole point: the reactor serves ≥ 64 concurrent jobs from
//! one thread with zero per-job spawns (asserted through
//! `ServerStats::workers_spawned`); the fleet partitions jobs across
//! cores exactly as `fleet::owner_core` predicts, with N reactor
//! threads and nothing else (asserted through /proc and per-core
//! stats); and the client twin, the swarm multiplexer, is bit-exact
//! against the blocking driver and the simulation, clean and under
//! chaos, 1k clients on one thread.

use std::net::SocketAddr;
use std::time::Duration;

use fediac::algorithms::{common, fediac::FediAc, Algorithm};
use fediac::client::swarm::{SwarmJobPlan, SwarmOptions, UpdateSource};
use fediac::client::{
    protocol, swarm, ClientOptions, FediacClient, RoundOutcome, ShardedFediacClient,
};
use fediac::compress::{self, deduce_gia};
use fediac::configx::{DatasetKind, ExperimentConfig, Partition, PsProfile};
use fediac::data::synth;
use fediac::fl::{FlEnv, NativeBackend};
use fediac::net::{ChaosConfig, ChaosDirection};
use fediac::server::{serve, serve_sharded, IoBackend, ServeOptions};
use fediac::util::{BitVec, Rng};

const N_CLIENTS: usize = 4;
const BACKENDS: [IoBackend; 3] =
    [IoBackend::Threaded, IoBackend::Reactor, IoBackend::Fleet];

// ---- simulation harness (the wire_loopback recipe) ------------------------

fn make_env(seed: u64, n_switches: usize) -> FlEnv {
    let cfg = ExperimentConfig {
        num_clients: N_CLIENTS,
        num_switches: n_switches,
        seed,
        ..ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid)
    };
    let fd = synth::generate(cfg.dataset, cfg.partition, N_CLIENTS, 40, cfg.seed);
    let backend = Box::new(NativeBackend::new(fd, 16, cfg.local_iters, 8, cfg.seed));
    let mut env = FlEnv::new(cfg, backend);
    env.init_model();
    env
}

struct SimRound {
    seed: u64,
    d: usize,
    k: usize,
    threshold_a: u16,
    bits_b: usize,
    updates: Vec<Vec<f32>>,
    params_before: Vec<f32>,
    params_after: Vec<f32>,
}

/// Bootstrap + round 1 of the simulated FediAC (with `n_switches`
/// collaborating PSes), capturing round-1 inputs and the ground truth.
fn run_sim_round(seed: u64, n_switches: usize) -> SimRound {
    let mut env = make_env(seed, n_switches);
    let mut alg = FediAc::new(&env.cfg, env.d());
    alg.run_round(&mut env, 0).unwrap();
    let params_before = env.params.clone();
    let bits_b = alg.bits().expect("bootstrap sets b");
    alg.run_round(&mut env, 1).unwrap();
    let params_after = env.params.clone();

    let mut env2 = make_env(seed, n_switches);
    let mut alg2 = FediAc::new(&env2.cfg, env2.d());
    alg2.run_round(&mut env2, 0).unwrap();
    assert_eq!(env2.params, params_before, "twin env diverged in bootstrap");
    let d = env2.d();
    let lr = env2.cfg.lr.at(1) as f32;
    let zero_residuals = vec![vec![0.0f32; d]; N_CLIENTS];
    let local = common::local_training(&mut env2, 1, lr, Some(&zero_residuals));

    SimRound {
        seed,
        d,
        k: protocol::votes_per_client(d, env2.cfg.fediac.k_frac),
        threshold_a: env2.cfg.fediac.threshold_a as u16,
        bits_b,
        updates: local.updates,
        params_before,
        params_after,
    }
}

fn client_opts(server: String, job: u32, id: u16, sim: &SimRound) -> ClientOptions {
    let mut opts = ClientOptions::new(server, job, id, sim.d, N_CLIENTS as u16);
    opts.threshold_a = sim.threshold_a;
    opts.k = sim.k;
    opts.bits_b = sim.bits_b;
    opts.backend_seed = sim.seed;
    opts.payload_budget = 16; // enough blocks to exercise chunking
    opts.timeout = Duration::from_millis(300);
    opts.max_retries = 200;
    opts
}

/// Run the 4 clients of one job concurrently against one daemon.
fn run_clients_plain(server: SocketAddr, job: u32, sim: &SimRound) -> Vec<RoundOutcome> {
    let mut outcomes: Vec<Option<RoundOutcome>> = (0..N_CLIENTS).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (i, slot) in outcomes.iter_mut().enumerate() {
            let update = &sim.updates[i];
            scope.spawn(move || {
                let opts = client_opts(server.to_string(), job, i as u16, sim);
                let mut client = FediacClient::connect(opts).unwrap();
                *slot = Some(client.run_round(1, update).unwrap());
            });
        }
    });
    outcomes.into_iter().map(|o| o.unwrap()).collect()
}

/// Run the 4 clients of one job against a sharded endpoint list.
fn run_clients_sharded(servers: &[String], job: u32, sim: &SimRound) -> Vec<RoundOutcome> {
    let mut outcomes: Vec<Option<RoundOutcome>> = (0..N_CLIENTS).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (i, slot) in outcomes.iter_mut().enumerate() {
            let update = &sim.updates[i];
            scope.spawn(move || {
                let opts = client_opts(servers[0].clone(), job, i as u16, sim);
                let mut client = ShardedFediacClient::connect(servers, opts).unwrap();
                *slot = Some(client.run_round(1, update).unwrap());
            });
        }
    });
    outcomes.into_iter().map(|o| o.unwrap()).collect()
}

/// Every client must agree; the applied round must reproduce the
/// simulated post-round model bit-for-bit.
fn assert_matches_sim(outcomes: &[RoundOutcome], sim: &SimRound, label: &str) {
    for o in outcomes.iter().skip(1) {
        assert_eq!(o.gia, outcomes[0].gia, "{label}: GIA differs across clients");
        assert_eq!(
            o.aggregate, outcomes[0].aggregate,
            "{label}: aggregate differs across clients"
        );
    }
    assert!(!outcomes[0].gia_indices.is_empty(), "{label}: empty consensus");
    let m = common::global_max_abs(&sim.updates);
    assert_eq!(outcomes[0].global_max, m, "{label}: global max differs");
    let mut params = sim.params_before.clone();
    outcomes[0].apply(&mut params);
    assert_eq!(params, sim.params_after, "{label}: diverged from algorithms::fediac");
}

// ---- single server, clean -------------------------------------------------

#[test]
fn backends_bit_exact_single_server_vs_simulation() {
    let sim = run_sim_round(7, 1);
    let mut per_backend: Vec<Vec<RoundOutcome>> = Vec::new();
    for backend in BACKENDS {
        let handle =
            serve(&ServeOptions { io_backend: backend, ..ServeOptions::default() }).unwrap();
        let outcomes = run_clients_plain(handle.local_addr(), 501, &sim);
        assert_matches_sim(&outcomes, &sim, backend.name());
        let stats = handle.stats();
        assert_eq!(stats.jobs_created, 1);
        assert_eq!(stats.rounds_completed, 1, "{} backend", backend.name());
        if backend != IoBackend::Threaded {
            assert_eq!(stats.workers_spawned, 0, "{} spawned a worker", backend.name());
        }
        handle.shutdown();
        per_backend.push(outcomes);
    }
    // Backend vs backend, client by client: every adjacent pair (and by
    // transitivity, every pair) must agree bit-for-bit.
    for pair in per_backend.windows(2) {
        for (a, b) in pair[0].iter().zip(&pair[1]) {
            assert_eq!(a.gia, b.gia, "backend GIAs differ");
            assert_eq!(a.aggregate, b.aggregate, "backend aggregates differ");
            assert_eq!(a.global_max, b.global_max);
        }
    }
}

// ---- N=2 sharded, clean ---------------------------------------------------

#[test]
fn backends_bit_exact_sharded_n2_vs_simulation() {
    let sim = run_sim_round(21, 2);
    let mut per_backend: Vec<Vec<RoundOutcome>> = Vec::new();
    for backend in BACKENDS {
        let handles = serve_sharded(
            &ServeOptions { io_backend: backend, ..ServeOptions::default() },
            2,
        )
        .unwrap();
        let servers: Vec<String> =
            handles.iter().map(|h| h.local_addr().to_string()).collect();
        let outcomes = run_clients_sharded(&servers, 502, &sim);
        assert_matches_sim(&outcomes, &sim, &format!("sharded {}", backend.name()));
        for (s, h) in handles.iter().enumerate() {
            let stats = h.stats();
            assert_eq!(stats.rounds_completed, 1, "shard {s} under {}", backend.name());
            if backend != IoBackend::Threaded {
                assert_eq!(stats.workers_spawned, 0, "shard {s} spawned a worker");
            }
        }
        for h in handles {
            h.shutdown();
        }
        per_backend.push(outcomes);
    }
    for pair in per_backend.windows(2) {
        for (a, b) in pair[0].iter().zip(&pair[1]) {
            assert_eq!(a.gia, b.gia, "sharded: backend GIAs differ");
            assert_eq!(a.aggregate, b.aggregate, "sharded: aggregates differ");
        }
    }
}

// ---- chaos (both directions), synthetic reference -------------------------

fn synthetic_update(seed: u64, d: usize, client: usize, round: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ (client as u64) << 16 ^ (round as u64) << 40);
    (0..d).map(|_| (rng.gaussian() * 0.02) as f32).collect()
}

fn reference_round(
    updates: &[Vec<f32>],
    seed: u64,
    round: usize,
    k: usize,
    a: usize,
) -> (Vec<usize>, Vec<i32>) {
    let votes: Vec<BitVec> = updates
        .iter()
        .enumerate()
        .map(|(i, u)| protocol::client_vote(u, k, seed, round, i))
        .collect();
    let gia = deduce_gia(&votes, a);
    let indices: Vec<usize> = gia.iter_ones().collect();
    let m = updates.iter().map(|u| compress::max_abs(u)).fold(f32::MIN_POSITIVE, f32::max);
    let f = compress::scale_factor(12, updates.len(), m);
    let mask = gia.to_f32_mask();
    let mut lanes = vec![0i32; indices.len()];
    for (i, u) in updates.iter().enumerate() {
        let (q, _) = protocol::client_quantize(u, &mask, f, seed, round, i);
        for (slot, &g) in indices.iter().enumerate() {
            lanes[slot] += q[g];
        }
    }
    (indices, lanes)
}

#[test]
fn backends_bit_exact_under_both_direction_chaos() {
    // 15% loss / 10% dup / 25% reorder on the client's both-direction
    // proxy, PLUS a 10% downlink-drop lane inside the daemon itself.
    // Chaos may cost retransmissions, never bits — under either backend.
    let d = 600;
    let seed = 99u64;
    let k = protocol::votes_per_client(d, 0.05);
    const ROUNDS: usize = 3;
    for backend in BACKENDS {
        let handle = serve(&ServeOptions {
            downlink_chaos: Some(ChaosDirection::lossy(0.10, 0.0, 0.0)),
            chaos_seed: 11,
            io_backend: backend,
            ..ServeOptions::default()
        })
        .unwrap();
        let server = handle.local_addr();
        std::thread::scope(|scope| {
            for client_id in 0..N_CLIENTS {
                scope.spawn(move || {
                    let mut opts =
                        ClientOptions::new(server.to_string(), 73, client_id as u16, d, N_CLIENTS as u16);
                    opts.threshold_a = 2;
                    opts.k = k;
                    opts.backend_seed = seed;
                    opts.payload_budget = 64;
                    opts.timeout = Duration::from_millis(150);
                    opts.max_retries = 400;
                    opts.chaos = Some(ChaosConfig::symmetric(
                        5 + client_id as u64,
                        ChaosDirection::lossy(0.15, 0.10, 0.25),
                    ));
                    let mut client = FediacClient::connect(opts).unwrap();
                    for round in 1..=ROUNDS {
                        let update = synthetic_update(seed, d, client_id, round);
                        let out = client.run_round(round, &update).unwrap();
                        let updates: Vec<Vec<f32>> = (0..N_CLIENTS)
                            .map(|c| synthetic_update(seed, d, c, round))
                            .collect();
                        let (ref_idx, ref_lanes) =
                            reference_round(&updates, seed, round, k, 2);
                        assert_eq!(
                            out.gia_indices,
                            ref_idx,
                            "{} client {client_id} round {round}: consensus diverged",
                            backend.name()
                        );
                        assert_eq!(
                            out.aggregate,
                            ref_lanes,
                            "{} client {client_id} round {round}: aggregate diverged",
                            backend.name()
                        );
                    }
                });
            }
        });
        assert_eq!(handle.stats().rounds_completed as usize, ROUNDS);
        handle.shutdown();
    }
}

// ---- swarm multiplexer: bit-exactness and one-thread scale ----------------

/// Swarm options mirroring `client_opts` for one explicit-update job.
fn swarm_opts(server: String, job: u32, sim: &SimRound) -> SwarmOptions {
    let mut opts = SwarmOptions::new(server, sim.d);
    opts.jobs = vec![SwarmJobPlan {
        job,
        n_clients: N_CLIENTS as u16,
        backend_seed: sim.seed,
        updates: UpdateSource::Explicit(vec![sim.updates.clone()]),
    }];
    opts.threshold_a = sim.threshold_a;
    opts.k = sim.k;
    opts.bits_b = sim.bits_b;
    opts.payload_budget = 16;
    opts.rounds = 1;
    opts.sockets = 2;
    opts.timeout = Duration::from_millis(300);
    opts.max_retries = 200;
    opts.collect_outcomes = true;
    opts
}

#[test]
fn swarm_bit_exact_vs_driver_and_simulation() {
    let sim = run_sim_round(7, 1);
    for backend in BACKENDS {
        // The blocking thin drivers…
        let handle =
            serve(&ServeOptions { io_backend: backend, ..ServeOptions::default() }).unwrap();
        let driver_outcomes = run_clients_plain(handle.local_addr(), 601, &sim);
        assert_matches_sim(&driver_outcomes, &sim, &format!("driver/{}", backend.name()));
        handle.shutdown();

        // …and the swarm multiplexer must produce the same round.
        let handle =
            serve(&ServeOptions { io_backend: backend, ..ServeOptions::default() }).unwrap();
        let report =
            swarm::run(&swarm_opts(handle.local_addr().to_string(), 601, &sim)).unwrap();
        assert_eq!(report.clients_hosted, N_CLIENTS);
        let per_client = &report.outcomes.as_ref().expect("collect_outcomes was set")[0];
        let outcomes: Vec<RoundOutcome> =
            per_client.iter().map(|rounds| rounds[0].clone()).collect();
        assert_matches_sim(&outcomes, &sim, &format!("swarm/{}", backend.name()));
        handle.shutdown();

        // Driver and swarm, client by client: the two client backends
        // are indistinguishable on the wire.
        for (a, b) in driver_outcomes.iter().zip(&outcomes) {
            assert_eq!(a.gia, b.gia, "driver and swarm GIAs differ");
            assert_eq!(a.aggregate, b.aggregate, "driver and swarm aggregates differ");
            assert_eq!(a.delta, b.delta, "driver and swarm deltas differ");
            assert_eq!(a.residual, b.residual, "driver and swarm residuals differ");
        }
    }
}

#[test]
fn swarm_bit_exact_under_both_direction_chaos() {
    // The same chaos matrix the driver leg runs: 10% downlink drop in
    // the daemon, 15%/10%/25% loss/dup/reorder on the swarm's uplink.
    let d = 600;
    let seed = 99u64;
    let k = protocol::votes_per_client(d, 0.05);
    const ROUNDS: usize = 3;
    let handle = serve(&ServeOptions {
        downlink_chaos: Some(ChaosDirection::lossy(0.10, 0.0, 0.0)),
        chaos_seed: 11,
        io_backend: IoBackend::Reactor,
        ..ServeOptions::default()
    })
    .unwrap();
    let updates_by_round: Vec<Vec<Vec<f32>>> = (1..=ROUNDS)
        .map(|round| (0..N_CLIENTS).map(|c| synthetic_update(seed, d, c, round)).collect())
        .collect();
    let mut opts = SwarmOptions::new(handle.local_addr().to_string(), d);
    opts.jobs = vec![SwarmJobPlan {
        job: 74,
        n_clients: N_CLIENTS as u16,
        backend_seed: seed,
        updates: UpdateSource::Explicit(updates_by_round.clone()),
    }];
    opts.threshold_a = 2;
    opts.k = k;
    opts.payload_budget = 64;
    opts.rounds = ROUNDS;
    opts.sockets = 1;
    opts.timeout = Duration::from_millis(150);
    opts.max_retries = 400;
    opts.uplink_chaos = Some(ChaosDirection::lossy(0.15, 0.10, 0.25));
    opts.chaos_seed = 5;
    opts.collect_outcomes = true;
    let report = swarm::run(&opts).unwrap();
    assert_eq!(handle.stats().rounds_completed as usize, ROUNDS);
    handle.shutdown();

    let per_client = &report.outcomes.expect("collect_outcomes was set")[0];
    for (round, updates) in (1..=ROUNDS).zip(&updates_by_round) {
        let (ref_idx, ref_lanes) = reference_round(updates, seed, round, k, 2);
        for (c, rounds) in per_client.iter().enumerate() {
            let out = &rounds[round - 1];
            assert_eq!(
                out.gia_indices, ref_idx,
                "swarm client {c} round {round}: consensus diverged under chaos"
            );
            assert_eq!(
                out.aggregate, ref_lanes,
                "swarm client {c} round {round}: aggregate diverged under chaos"
            );
        }
    }
}

/// Threads of this process, from /proc (Linux only).
#[cfg(target_os = "linux")]
fn thread_count() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").expect("/proc/self/status");
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .expect("Threads: line")
}

#[test]
fn swarm_hosts_1k_clients_on_one_thread() {
    const JOBS: usize = 16;
    const PER_JOB: u16 = 64;
    let d = 64;
    let handle = serve(&ServeOptions {
        io_backend: IoBackend::Reactor,
        ..ServeOptions::default()
    })
    .unwrap();
    #[cfg(target_os = "linux")]
    let threads_before = thread_count();

    let mut opts = SwarmOptions::new(handle.local_addr().to_string(), d);
    opts.jobs = swarm::plan_fleet(JOBS * PER_JOB as usize, PER_JOB, 5);
    opts.threshold_a = 1;
    opts.payload_budget = 64;
    opts.rounds = 1;
    opts.sockets = 8;
    opts.timeout = Duration::from_millis(500);
    opts.max_retries = 100;
    let report = swarm::run(&opts).unwrap();

    // The whole fleet ran on the calling thread: the process thread
    // count is unchanged (no client threads), and the reactor daemon
    // spawned no per-job workers either.
    #[cfg(target_os = "linux")]
    assert_eq!(thread_count(), threads_before, "the swarm must not spawn client threads");
    assert_eq!(report.clients_hosted, JOBS * PER_JOB as usize);
    assert_eq!(report.jobs, JOBS);
    assert_eq!(report.sockets_used, 8);
    assert_eq!(report.rounds_completed, (JOBS * PER_JOB as usize) as u64);
    assert_eq!(
        report.round_latency.count(),
        (JOBS * PER_JOB as usize) as u64,
        "one latency sample per client round"
    );
    let stats = handle.stats();
    assert_eq!(stats.jobs_created as usize, JOBS);
    assert_eq!(stats.rounds_completed as usize, JOBS);
    assert_eq!(stats.workers_spawned, 0, "reactor spawned a worker");
    handle.shutdown();
}

// ---- reactor scale: 64 jobs, one thread -----------------------------------

#[test]
fn reactor_serves_64_jobs_from_one_thread() {
    const JOBS: usize = 64;
    let d = 256;
    let handle = serve(&ServeOptions {
        io_backend: IoBackend::Reactor,
        ..ServeOptions::default()
    })
    .unwrap();
    let server = handle.local_addr();
    std::thread::scope(|scope| {
        for job in 0..JOBS {
            scope.spawn(move || {
                let seed = 1000 + job as u64;
                let mut opts =
                    ClientOptions::new(server.to_string(), 7000 + job as u32, 0, d, 1);
                opts.threshold_a = 1;
                opts.backend_seed = seed;
                opts.payload_budget = 64;
                opts.timeout = Duration::from_millis(300);
                opts.max_retries = 100;
                let k = opts.k;
                let mut client = FediacClient::connect(opts).unwrap();
                let update = synthetic_update(seed, d, 0, 1);
                let out = client.run_round(1, &update).unwrap();
                // N = 1, a = 1: the GIA is exactly this client's votes.
                let votes = protocol::client_vote(&update, k, seed, 1, 0);
                assert_eq!(out.gia, votes, "job {job}: wrong consensus");
            });
        }
    });
    let stats = handle.stats();
    assert_eq!(stats.jobs_created as usize, JOBS, "not every job was hosted");
    assert_eq!(stats.rounds_completed as usize, JOBS, "not every round completed");
    assert_eq!(
        stats.workers_spawned, 0,
        "the reactor must not spawn per-job workers"
    );
    handle.shutdown();
}

// ---- fleet scale: 16 jobs partitioned across 4 cores ----------------------

#[cfg(target_os = "linux")]
#[test]
fn fleet_partitions_16_jobs_across_4_cores() {
    use fediac::server::fleet::owner_core;
    const JOBS: usize = 16;
    const CORES: usize = 4;
    let d = 256;
    let threads_before = thread_count();
    let handle = serve(&ServeOptions {
        io_backend: IoBackend::Fleet,
        cores: CORES,
        ..ServeOptions::default()
    })
    .unwrap();
    assert_eq!(handle.cores(), CORES);
    assert_eq!(
        thread_count(),
        threads_before + CORES,
        "a fleet of N cores is exactly N reactor threads, nothing else"
    );
    let server = handle.local_addr();
    std::thread::scope(|scope| {
        for job in 0..JOBS {
            scope.spawn(move || {
                let seed = 2000 + job as u64;
                let mut opts =
                    ClientOptions::new(server.to_string(), 7100 + job as u32, 0, d, 1);
                opts.threshold_a = 1;
                opts.backend_seed = seed;
                opts.payload_budget = 64;
                opts.timeout = Duration::from_millis(300);
                opts.max_retries = 100;
                let k = opts.k;
                let mut client = FediacClient::connect(opts).unwrap();
                let update = synthetic_update(seed, d, 0, 1);
                let out = client.run_round(1, &update).unwrap();
                let votes = protocol::client_vote(&update, k, seed, 1, 0);
                assert_eq!(out.gia, votes, "job {job}: wrong consensus");
            });
        }
    });

    // Aggregate view first: every job hosted, every round completed, no
    // per-job workers on any core.
    let stats = handle.stats();
    assert_eq!(stats.jobs_created as usize, JOBS, "not every job was hosted");
    assert_eq!(stats.rounds_completed as usize, JOBS, "not every round completed");
    assert_eq!(stats.workers_spawned, 0, "fleet cores must not spawn per-job workers");

    // Ownership: each job lives on exactly the core `owner_core` names,
    // no matter which member socket the kernel's per-flow REUSEPORT
    // hash delivered its datagrams to — misdirected flows were steered
    // to the owner (counted in `steered_frames`), never served in
    // place.
    let per_core = handle.per_core_stats();
    assert_eq!(per_core.len(), CORES);
    let mut want = vec![0u64; CORES];
    for job in 0..JOBS {
        want[owner_core(7100 + job as u32, CORES)] += 1;
    }
    for (c, snap) in per_core.iter().enumerate() {
        assert_eq!(
            snap.jobs_created, want[c],
            "core {c} hosts the wrong job set (steering failed?)"
        );
        assert!(
            snap.steered_frames <= snap.packets,
            "core {c}: steered more frames than it received"
        );
    }
    handle.shutdown();
}
