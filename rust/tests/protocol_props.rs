//! Property tests over the FediAC protocol invariants (hand-rolled
//! harness in `fediac::util::prop`; replay failures with
//! FEDIAC_PROP_SEED=<seed>).

use fediac::compress::{
    deduce_gia, dequantize_aggregate, max_abs, quantize_sparsify, scale_factor,
    vote_bitmap,
};
use fediac::prop_assert;
use fediac::switch::{RegisterFile, VoteAggregator};
use fediac::util::{prop, BitVec, Rng};

/// The switch's data-plane GIA must equal the host-side reference for any
/// vote pattern, block size and threshold.
#[test]
fn switch_gia_equals_host_gia() {
    prop::check("switch_gia_host_gia", prop::default_cases(), |rng| {
        let d = 1 + rng.below(600);
        let n = 2 + rng.below(14);
        let a = 1 + rng.below(n);
        let votes: Vec<BitVec> = (0..n)
            .map(|_| {
                let k = rng.below(d + 1);
                let mut idx: Vec<usize> = (0..d).collect();
                rng.shuffle(&mut idx);
                BitVec::from_indices(d, &idx[..k])
            })
            .collect();
        let host = deduce_gia(&votes, a);

        let epb = 8 * (1 + rng.below(32)); // byte-aligned block sizes
        let mut rf = RegisterFile::new(d * 2);
        let mut agg = VoteAggregator::new(&mut rf, d, n, a, epb).unwrap();
        let n_blocks = d.div_ceil(epb);
        for (client, v) in votes.iter().enumerate() {
            let bytes = v.to_bytes();
            for block in 0..n_blocks {
                let lo = block * (epb / 8);
                let hi = ((block + 1) * (epb / 8)).min(bytes.len());
                agg.ingest(client, block, &bytes[lo..hi]);
            }
        }
        prop_assert!(agg.all_complete(), "incomplete scoreboard d={d} n={n}");
        let switch_gia = agg.gia();
        agg.release(&mut rf);
        prop_assert!(switch_gia == host, "GIA mismatch d={d} n={n} a={a} epb={epb}");
        Ok(())
    });
}

/// Conservation: for every client, f·U = q + f·e on GIA lanes and e = U
/// off-GIA — nothing is lost or double-counted by the protocol.
#[test]
fn round_conservation_invariant() {
    prop::check("round_conservation", prop::default_cases(), |rng| {
        let d = 16 + rng.below(512);
        let n = 2 + rng.below(10);
        let k = 1 + rng.below(d);
        let a = 1 + rng.below(n);
        let updates: Vec<Vec<f32>> =
            (0..n).map(|_| prop::gen_updates(rng, d, 0.05)).collect();
        let votes: Vec<BitVec> =
            updates.iter().map(|u| vote_bitmap(u, k, rng)).collect();
        let gia = deduce_gia(&votes, a);
        let mask = gia.to_f32_mask();
        let m = updates.iter().map(|u| max_abs(u)).fold(1e-9f32, f32::max);
        let f = scale_factor(12, n, m);
        for (i, u) in updates.iter().enumerate() {
            let (q, e) = quantize_sparsify(u, &mask, f, rng);
            for l in 0..d {
                if gia.get(l) {
                    let lhs = q[l] as f64 + f as f64 * e[l] as f64;
                    let rhs = f as f64 * u[l] as f64;
                    prop_assert!(
                        (lhs - rhs).abs() <= 1e-2 * rhs.abs().max(1.0),
                        "client {i} lane {l}: {lhs} vs {rhs}"
                    );
                } else {
                    prop_assert!(q[l] == 0, "client {i} lane {l} leaked");
                    prop_assert!(
                        (e[l] - u[l]).abs() < 1e-6,
                        "client {i} lane {l} residual"
                    );
                }
            }
        }
        Ok(())
    });
}

/// Aggregate unbiasedness: E[Σq/(N·f)] = mean(U) on GIA lanes — averaged
/// over many seeds, the dequantised aggregate approaches the true mean.
#[test]
fn aggregate_unbiased_monte_carlo() {
    let d = 64;
    let n = 5;
    let mut rng = Rng::new(99);
    let updates: Vec<Vec<f32>> = (0..n).map(|_| prop::gen_updates(&mut rng, d, 0.1)).collect();
    let gia = BitVec::from_indices(d, &(0..d).collect::<Vec<_>>()); // all lanes
    let mask = gia.to_f32_mask();
    let m = updates.iter().map(|u| max_abs(u)).fold(1e-9f32, f32::max);
    let f = scale_factor(10, n, m);
    let trials = 600;
    let mut mean_est = vec![0f64; d];
    for _ in 0..trials {
        let mut agg = vec![0i64; d];
        for u in &updates {
            let (q, _) = quantize_sparsify(u, &mask, f, &mut rng);
            for l in 0..d {
                agg[l] += q[l] as i64;
            }
        }
        let agg32: Vec<i32> = agg.iter().map(|&v| v as i32).collect();
        let deq = dequantize_aggregate(&agg32, n, f);
        for l in 0..d {
            mean_est[l] += deq[l] as f64;
        }
    }
    for l in 0..d {
        mean_est[l] /= trials as f64;
        let truth: f64 =
            updates.iter().map(|u| u[l] as f64).sum::<f64>() / n as f64;
        // CI: per-trial std ≤ sqrt(n)·0.5/(n·f).
        let tol = 4.0 * (n as f64).sqrt() * 0.5 / (n as f64 * f as f64)
            / (trials as f64).sqrt()
            + 1e-6;
        assert!(
            (mean_est[l] - truth).abs() < tol.max(1e-4),
            "lane {l}: est {} vs truth {truth}",
            mean_est[l]
        );
    }
}

/// GIA size shrinks monotonically in the threshold for *voted* bitmaps
/// (not just arbitrary ones — ties to the real voting distribution).
#[test]
fn gia_size_monotone_in_a_for_real_votes() {
    prop::check("gia_monotone_real_votes", 24, |rng| {
        let d = 256;
        let n = 8;
        let k = 32;
        let updates: Vec<Vec<f32>> =
            (0..n).map(|_| prop::gen_updates(rng, d, 0.05)).collect();
        let votes: Vec<BitVec> = updates.iter().map(|u| vote_bitmap(u, k, rng)).collect();
        let mut prev = usize::MAX;
        for a in 1..=n {
            let size = deduce_gia(&votes, a).count_ones();
            prop_assert!(size <= prev, "a={a}: {size} > {prev}");
            prev = size;
        }
        Ok(())
    });
}

/// Larger quantisation budgets reduce empirical compression error.
#[test]
fn gamma_hat_decreases_with_bits() {
    let d = 4096;
    let mut rng = Rng::new(5);
    let updates = prop::gen_updates(&mut rng, d, 0.05);
    let mask = vec![1.0f32; d];
    let m = max_abs(&updates);
    let gamma_at = |bits: usize, rng: &mut Rng| {
        let f = scale_factor(bits, 20, m);
        let (q, _) = quantize_sparsify(&updates, &mask, f, rng);
        fediac::compress::error::relative_error(&q, &updates, f)
    };
    let g8 = gamma_at(8, &mut rng);
    let g16 = gamma_at(16, &mut rng);
    assert!(g16 < g8, "γ̂(16b) {g16} !< γ̂(8b) {g8}");
}
