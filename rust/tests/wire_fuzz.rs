//! Seeded mutation fuzzing of the wire-facing decoders: prove that no
//! byte sequence a datagram can carry panics `wire::decode_frame`,
//! `compress::golomb::decode`, `wire::decode_lanes` or
//! `wire::JobSpec::decode` — they must return their error forms instead.
//!
//! The corpus is a set of *valid* encoded frames (every kind, every
//! payload codec); each iteration picks one, applies a random mutation
//! (bit flips, truncation, extension, splicing, or full garbage) and
//! pushes the result through every decoder. Deterministic: one
//! `util::Rng` seed drives corpus choice and mutations, so a failure
//! reproduces exactly.
//!
//! Default volume is 120k mutated frames (comfortably past the 100k
//! acceptance bar, still ≪ 1 s of codec work); `FEDIAC_FUZZ_FRAMES`
//! scales it up for deeper CI soaks.

use fediac::compress::golomb;
use fediac::util::{BitVec, Rng};
use fediac::wire::{
    decode_frame, decode_lanes, encode_frame, encode_lanes, vote_chunks, Header, JobSpec,
    ShardPlan, WireKind,
};

/// Dimension cap handed to `golomb::decode_with_limit` — what a real
/// client would pass (its own model dimension).
const GOLOMB_DIM_LIMIT: usize = 1 << 16;

fn fuzz_frames() -> usize {
    std::env::var("FEDIAC_FUZZ_FRAMES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(120_000)
}

/// Valid frames of every kind and payload codec, plus raw payload bodies.
fn corpus(rng: &mut Rng) -> Vec<Vec<u8>> {
    let mut out = Vec::new();
    let spec = JobSpec {
        d: 10_000,
        n_clients: 8,
        threshold_a: 3,
        payload_budget: 256,
        shard: ShardPlan::single(),
        quorum: 0,
    };

    // Join + control kinds.
    out.push(encode_frame(&Header::control(WireKind::Join, 7, 2, 0, 0), &spec.encode()));
    out.push(encode_frame(&Header::control(WireKind::JoinAck, 7, 2, 0, 0), &[]));
    out.push(encode_frame(&Header::control(WireKind::Poll, 7, 2, 3, WireKind::Gia as u32), &[]));
    out.push(encode_frame(&Header::control(WireKind::NotReady, 7, 2, 3, 0), &[]));

    // Vote bitmap blocks.
    let mut bv = BitVec::zeros(2048);
    for i in 0..2048 {
        if rng.f64() < 0.05 {
            bv.set(i, true);
        }
    }
    for (i, (dims, bytes)) in vote_chunks(&bv, 64).iter().enumerate() {
        out.push(encode_frame(
            &Header {
                kind: WireKind::Vote,
                client: 1,
                job: 7,
                round: 3,
                block: i as u32,
                n_blocks: 4,
                elems: *dims as u32,
                aux: 0.25f32.to_bits(),
            },
            bytes,
        ));
    }

    // Golomb-coded GIA broadcast (the full stream in one frame).
    let gia_bytes = golomb::encode(&bv);
    out.push(encode_frame(
        &Header {
            kind: WireKind::Gia,
            client: u16::MAX,
            job: 7,
            round: 3,
            block: 0,
            n_blocks: 1,
            elems: gia_bytes.len() as u32,
            aux: 1.5f32.to_bits(),
        },
        &gia_bytes,
    ));
    // Raw golomb streams too (various densities, incl. empty).
    out.push(golomb::encode(&BitVec::zeros(4096)));
    out.push(golomb::encode(&BitVec::from_indices(257, &[0, 1, 2, 255, 256])));
    out.push(gia_bytes);

    // Update / aggregate lane payloads.
    let lanes: Vec<i32> = (0..200).map(|_| rng.next_u32() as i32).collect();
    let lane_bytes = encode_lanes(&lanes);
    out.push(encode_frame(
        &Header {
            kind: WireKind::Update,
            client: 1,
            job: 7,
            round: 3,
            block: 0,
            n_blocks: 2,
            elems: lanes.len() as u32,
            aux: 2.0f32.to_bits(),
        },
        &lane_bytes,
    ));
    out.push(encode_frame(
        &Header {
            kind: WireKind::Aggregate,
            client: u16::MAX,
            job: 7,
            round: 3,
            block: 1,
            n_blocks: 2,
            elems: lanes.len() as u32,
            aux: lanes.len() as u32,
        },
        &lane_bytes,
    ));
    out.push(lane_bytes);
    out
}

/// One random mutation of `base`.
fn mutate(rng: &mut Rng, base: &[u8]) -> Vec<u8> {
    let mut buf = base.to_vec();
    match rng.below(5) {
        // Bit flips (1–8 of them, anywhere incl. header and checksum).
        0 => {
            if !buf.is_empty() {
                for _ in 0..(1 + rng.below(8)) {
                    let bit = rng.below(buf.len() * 8);
                    buf[bit / 8] ^= 1 << (bit % 8);
                }
            }
        }
        // Truncation at a random point.
        1 => {
            buf.truncate(rng.below(buf.len() + 1));
        }
        // Extension with random bytes.
        2 => {
            for _ in 0..(1 + rng.below(64)) {
                buf.push(rng.next_u32() as u8);
            }
        }
        // Splice a random region with garbage.
        3 => {
            if !buf.is_empty() {
                let start = rng.below(buf.len());
                let len = 1 + rng.below((buf.len() - start).min(16));
                for b in &mut buf[start..start + len] {
                    *b = rng.next_u32() as u8;
                }
            }
        }
        // Replace with pure noise of arbitrary small size.
        _ => {
            let len = rng.below(128);
            buf = (0..len).map(|_| rng.next_u32() as u8).collect();
        }
    }
    buf
}

#[test]
fn mutated_frames_never_panic_any_decoder() {
    let mut rng = Rng::new(0xF0_77_2E);
    let corpus = corpus(&mut rng);
    let total = fuzz_frames();
    let mut decoded_ok = 0u64;
    for _ in 0..total {
        let base = &corpus[rng.below(corpus.len())];
        let mutated = mutate(&mut rng, base);
        // Every decoder must return its error form, never panic.
        if let Ok(frame) = decode_frame(&mutated) {
            decoded_ok += 1;
            // Frames that survive the CRC still carry attacker-shaped
            // payloads relative to their header; push them deeper.
            let _ = decode_lanes(frame.payload);
            let _ = golomb::decode_with_limit(frame.payload, GOLOMB_DIM_LIMIT);
            let _ = JobSpec::decode(frame.payload);
        }
        let _ = decode_lanes(&mutated);
        let _ = golomb::decode_with_limit(&mutated, GOLOMB_DIM_LIMIT);
        let _ = JobSpec::decode(&mutated);
    }
    // Sanity: the unmutated corpus is real input, not garbage — every
    // actual frame in it (the non-frame entries are raw payload bodies)
    // must decode.
    let valid = corpus.iter().filter(|b| decode_frame(b).is_ok()).count();
    assert!(valid >= 10, "corpus lost its valid frames ({valid})");
    // A mutation can be a no-op (e.g. truncation at full length), so a
    // few `Ok` decodes are expected; anything else fails the CRC.
    eprintln!("[wire_fuzz] {total} mutated frames, {decoded_ok} decoded clean");
}

#[test]
fn golomb_word_reader_matches_scalar_under_refill_targeted_mutation() {
    // Differential fuzz of the word-based bit reader against the per-bit
    // scalar oracle, with the corpus and mutations both aimed at the u64
    // machinery: streams whose unary quotient runs span 64-bit word
    // edges (the 70-bit header guarantees every run starts mid-word),
    // and bit flips concentrated on the first refill boundaries. Accept
    // AND reject must agree bit-for-bit on every mutation — a stricter
    // bar than "no panic". Runs at the full ≥100k-mutation budget.
    let mut rng = Rng::new(0x60B0_ED6E);
    let mut corpus: Vec<(Vec<u8>, usize)> = Vec::new();
    // Valid streams crafted with an explicit r = 0 header, so each gap
    // is one pure unary run of `gap` one-bits (`encode()` would pick
    // r > 0 here and keep runs short): runs of 50..=130 bits genuinely
    // span the reader's u64 refill edges before any mutation lands.
    let craft_unary = |d: u64, gaps: &[u64]| -> Vec<u8> {
        let mut w = golomb::scalar::BitWriter::new();
        w.push_bits(d, 32);
        w.push_bits(gaps.len() as u64, 32);
        w.push_bits(0, 6);
        for &g in gaps {
            for _ in 0..g {
                w.push_bit(true);
            }
            w.push_bit(false);
        }
        w.finish()
    };
    for gap in [50u64, 55, 57, 58, 62, 63, 64, 65, 70, 126, 127, 128, 129, 130] {
        let d = 3 * gap as usize + 8;
        let stream = craft_unary(d as u64, &[gap, gap]);
        // Sanity: the corpus entry is valid and boundary-crossing.
        assert_eq!(
            golomb::decode_with_limit(&stream, d).expect("corpus stream must decode"),
            BitVec::from_indices(d, &[gap as usize, 2 * gap as usize + 1]),
        );
        corpus.push((stream, d));
    }
    // A long multi-run stream: every refill path (aligned 8-byte fast
    // path and the byte-wise tail) gets exercised.
    let mut bv = BitVec::zeros(50_000);
    for i in 0..50_000 {
        if rng.f64() < 0.002 {
            bv.set(i, true);
        }
    }
    corpus.push((golomb::encode(&bv), 50_000));

    let total = fuzz_frames();
    let mut accepted = 0u64;
    for _ in 0..total {
        let (base, d) = &corpus[rng.below(corpus.len())];
        let mut evil = base.clone();
        match rng.below(4) {
            // Bit flips biased into bytes 8..24 — the first u64 refill
            // boundary and the word edge after the 70-bit header.
            0 => {
                for _ in 0..(1 + rng.below(4)) {
                    let hot_zone = evil.len().clamp(9, 24);
                    let byte = 8 + rng.below(hot_zone - 8);
                    evil[byte] ^= 1 << rng.below(8);
                }
            }
            // Truncation at word-boundary-adjacent lengths.
            1 => {
                let cuts = [8usize, 9, 15, 16, 17, 23, 24];
                let cut = cuts[rng.below(cuts.len())].min(evil.len());
                evil.truncate(cut);
            }
            // Splice ones into the run region to lengthen/merge runs.
            2 => {
                if evil.len() > 9 {
                    let start = 9 + rng.below(evil.len() - 9);
                    let len = (1 + rng.below(4)).min(evil.len() - start);
                    for b in &mut evil[start..start + len] {
                        *b = 0xFF;
                    }
                }
            }
            // Unbiased flips anywhere (header included).
            _ => {
                let bit = rng.below(evil.len() * 8);
                evil[bit / 8] ^= 1 << (bit % 8);
            }
        }
        let word = golomb::decode_with_limit(&evil, *d);
        let scalar = golomb::scalar::decode_with_limit(&evil, *d);
        assert_eq!(
            word, scalar,
            "word reader diverged from scalar oracle on a mutated stream (d={d})"
        );
        if word.is_some() {
            accepted += 1;
        }
    }
    eprintln!("[wire_fuzz] {total} refill-targeted mutations, {accepted} decoded by both");
}

#[test]
fn golomb_mutation_storm_never_panics() {
    // Focused storm on the trickiest decoder: mutate real Golomb streams
    // (header fields d/count/r live in the first 9 bytes, so bit flips
    // regularly produce adversarial geometry).
    let mut rng = Rng::new(0x601_0B);
    let mut bv = BitVec::zeros(8192);
    for i in 0..8192 {
        if rng.f64() < 0.03 {
            bv.set(i, true);
        }
    }
    let streams = [
        golomb::encode(&bv),
        golomb::encode(&BitVec::zeros(1)),
        golomb::encode(&BitVec::from_indices(64, &(0..64).collect::<Vec<_>>())),
    ];
    let iterations = fuzz_frames() / 4;
    for _ in 0..iterations {
        let mutated = mutate(&mut rng, &streams[rng.below(streams.len())]);
        let _ = golomb::decode_with_limit(&mutated, GOLOMB_DIM_LIMIT);
    }
    // The unbounded entry point must hold up to count/r header flips
    // too. (Flips inside the 32-bit `d` field are exercised through
    // `decode_with_limit` above — unbounded, a flipped high `d` bit
    // legitimately allocates a gigantic bitmap, which is exactly why the
    // wire client passes a limit.)
    for _ in 0..1_000 {
        let mut s = streams[2].clone();
        let bit = 32 + rng.below(s.len().min(9) * 8 - 32);
        s[bit / 8] ^= 1 << (bit % 8);
        let _ = golomb::decode(&s);
    }
}
