//! Chaos-matrix integration tests: full FediAC rounds through the
//! deterministic loss/duplication/reordering/corruption proxy
//! (`net::chaos`), asserting multi-round **bit-exactness** against the
//! clean in-process reference aggregation.
//!
//! The acceptance bar: 5 rounds at (20% loss, 10% dup, 30% reorder) in
//! *each* direction, two jobs running concurrently through one proxy,
//! every round's GIA and aggregate identical to the reference — chaos
//! may only cost time (retransmissions), never correctness.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use fediac::client::{protocol, ClientOptions, FediacClient};
use fediac::compress::{self, deduce_gia};
use fediac::net::{chaos_proxy, ChaosConfig, ChaosDirection, ChaosProxyOptions};
use fediac::server::{serve, ServeOptions, ServerHandle};
use fediac::telemetry::{FlightRecorder, PanicDump, DEFAULT_EVENTS};
use fediac::util::{BitVec, Rng};

const ROUNDS: usize = 5;

/// Deterministic per-(client, round) synthetic update vectors.
fn synthetic_update(seed: u64, d: usize, client: usize, round: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ (client as u64) << 16 ^ (round as u64) << 40);
    (0..d).map(|_| (rng.gaussian() * 0.02) as f32).collect()
}

/// Clean in-process reference for one round: (gia indices, aggregate).
fn reference_round(
    updates: &[Vec<f32>],
    seed: u64,
    round: usize,
    k: usize,
    a: usize,
    bits_b: usize,
) -> (Vec<usize>, Vec<i32>) {
    let votes: Vec<BitVec> = updates
        .iter()
        .enumerate()
        .map(|(i, u)| protocol::client_vote(u, k, seed, round, i))
        .collect();
    let gia = deduce_gia(&votes, a);
    let indices: Vec<usize> = gia.iter_ones().collect();
    let m = updates
        .iter()
        .map(|u| compress::max_abs(u))
        .fold(f32::MIN_POSITIVE, f32::max);
    let f = compress::scale_factor(bits_b, updates.len(), m);
    let mask = gia.to_f32_mask();
    let mut lanes = vec![0i32; indices.len()];
    for (i, u) in updates.iter().enumerate() {
        let (q, _) = protocol::client_quantize(u, &mask, f, seed, round, i);
        for (slot, &g) in indices.iter().enumerate() {
            lanes[slot] += q[g];
        }
    }
    (indices, lanes)
}

struct JobSetup {
    job: u32,
    seed: u64,
    d: usize,
    n_clients: usize,
    threshold_a: u16,
    payload_budget: usize,
}

impl JobSetup {
    fn k(&self) -> usize {
        protocol::votes_per_client(self.d, 0.05)
    }
}

/// Run every client of one job for `ROUNDS` rounds against `server`
/// (usually a chaos-proxy address) and assert each round bit-exact
/// against the clean reference; accumulates retransmissions into `retx`.
fn run_job(server: SocketAddr, setup: &JobSetup, retx: &AtomicU64) {
    std::thread::scope(|scope| {
        for client_id in 0..setup.n_clients {
            scope.spawn(move || {
                let mut opts = ClientOptions::new(
                    server.to_string(),
                    setup.job,
                    client_id as u16,
                    setup.d,
                    setup.n_clients as u16,
                );
                opts.threshold_a = setup.threshold_a;
                opts.k = setup.k();
                opts.backend_seed = setup.seed;
                opts.payload_budget = setup.payload_budget;
                opts.timeout = Duration::from_millis(150);
                opts.max_retries = 400;
                let mut client = FediacClient::connect(opts).unwrap();
                for round in 1..=ROUNDS {
                    let update = synthetic_update(setup.seed, setup.d, client_id, round);
                    let out = client.run_round(round, &update).unwrap();
                    // Recompute the reference per client thread — cheap,
                    // and keeps the threads free of shared state.
                    let updates: Vec<Vec<f32>> = (0..setup.n_clients)
                        .map(|c| synthetic_update(setup.seed, setup.d, c, round))
                        .collect();
                    let (ref_idx, ref_lanes) = reference_round(
                        &updates,
                        setup.seed,
                        round,
                        setup.k(),
                        setup.threshold_a as usize,
                        12,
                    );
                    assert_eq!(
                        out.gia_indices, ref_idx,
                        "job {} client {client_id} round {round}: consensus diverged",
                        setup.job
                    );
                    assert_eq!(
                        out.aggregate, ref_lanes,
                        "job {} client {client_id} round {round}: aggregate diverged",
                        setup.job
                    );
                }
                retx.fetch_add(client.stats.retransmissions, Ordering::Relaxed);
            });
        }
    });
}

/// Serve with a flight recorder attached and its panic guard armed: if
/// any assertion in the calling test fails, the last protocol events
/// dump to stderr automatically — the black box for chaos post-mortems.
/// Telemetry is observer-only, so bit-exactness is unaffected.
fn start_traced_server(mut opts: ServeOptions) -> (ServerHandle, PanicDump) {
    let rec = Arc::new(FlightRecorder::new(DEFAULT_EVENTS));
    let guard = rec.dump_on_panic();
    opts.trace = Some(rec);
    (serve(&opts).unwrap(), guard)
}

fn start_server() -> (ServerHandle, PanicDump) {
    start_traced_server(ServeOptions::default())
}

fn start_proxy(upstream: SocketAddr, config: ChaosConfig) -> fediac::net::ChaosHandle {
    chaos_proxy(&ChaosProxyOptions {
        listen: "127.0.0.1:0".into(),
        upstream: upstream.to_string(),
        config,
    })
    .unwrap()
}

/// The acceptance scenario: heavy chaos in BOTH directions, two jobs
/// concurrently through one shared proxy, 5 rounds each, bit-exact.
#[test]
fn both_direction_chaos_two_jobs_five_rounds_bit_exact() {
    let (server, _trace_guard) = start_server();
    let chaos = ChaosDirection::lossy(0.20, 0.10, 0.30);
    let proxy = start_proxy(
        server.local_addr(),
        ChaosConfig { seed: 71, uplink: chaos, downlink: chaos },
    );
    let retx = AtomicU64::new(0);

    let job_a = JobSetup {
        job: 501,
        seed: 17,
        d: 384,
        n_clients: 4,
        threshold_a: 2,
        payload_budget: 16,
    };
    let job_b = JobSetup {
        job: 502,
        seed: 23,
        d: 300,
        n_clients: 3,
        threshold_a: 1,
        payload_budget: 32,
    };
    let addr = proxy.local_addr();
    std::thread::scope(|scope| {
        scope.spawn(|| run_job(addr, &job_a, &retx));
        scope.spawn(|| run_job(addr, &job_b, &retx));
    });

    let snap = proxy.snapshot();
    assert_eq!(snap.flows, 7, "one NAT flow per client socket");
    assert!(snap.up.dropped > 0, "uplink chaos never fired");
    assert!(snap.down.dropped > 0, "downlink chaos never fired");
    assert!(snap.up.reordered > 0 && snap.down.reordered > 0);
    assert!(snap.up.duplicated > 0 && snap.down.duplicated > 0);
    let stats = server.stats();
    assert_eq!(stats.rounds_completed, 2 * ROUNDS as u64);
    assert!(
        stats.duplicates > 0 || retx.load(Ordering::Relaxed) > 0,
        "chaos at these rates should force retransmission"
    );
    proxy.shutdown();
    server.shutdown();
}

/// Direction sweep: the same lossy trio applied to only one side at a
/// time, plus a corruption-heavy config (CRC must shield the codec), all
/// bit-exact over multiple rounds.
#[test]
fn per_direction_and_corruption_matrix_stays_bit_exact() {
    let lossy = ChaosDirection::lossy(0.20, 0.10, 0.30);
    let corrupting = ChaosDirection::lossy(0.10, 0.05, 0.10).with_corrupt(0.15);
    let matrix: Vec<(&str, ChaosConfig)> = vec![
        (
            "uplink-only",
            ChaosConfig { seed: 81, uplink: lossy, downlink: ChaosDirection::clean() },
        ),
        (
            "downlink-only",
            ChaosConfig { seed: 82, uplink: ChaosDirection::clean(), downlink: lossy },
        ),
        ("corrupt-both", ChaosConfig { seed: 83, uplink: corrupting, downlink: corrupting }),
    ];
    for (name, config) in matrix {
        let (server, _trace_guard) = start_server();
        let proxy = start_proxy(server.local_addr(), config);
        let setup = JobSetup {
            job: 600,
            seed: 29,
            d: 256,
            n_clients: 2,
            threshold_a: 1,
            payload_budget: 16,
        };
        let retx = AtomicU64::new(0);
        run_job(proxy.local_addr(), &setup, &retx);
        let snap = proxy.snapshot();
        let touched = snap.up.dropped
            + snap.up.reordered
            + snap.up.corrupted
            + snap.down.dropped
            + snap.down.reordered
            + snap.down.corrupted;
        assert!(touched > 0, "{name}: chaos config never fired");
        assert_eq!(server.stats().rounds_completed, ROUNDS as u64, "{name}");
        proxy.shutdown();
        server.shutdown();
    }
}

/// Empty-consensus regression: with a threshold no dimension reaches
/// (disjoint hot dimension ranges per client), every round must still
/// close on both sides — the client uploads the zero-lane completion
/// block and receives the empty aggregate; the server frees the round
/// instead of pinning a live-round slot until idle-release.
#[test]
fn unreachable_threshold_rounds_complete_without_wedging() {
    let (server, _trace_guard) = start_server();
    let d = 512;
    let n_clients = 2usize;
    let retx = AtomicU64::new(0);

    std::thread::scope(|scope| {
        for client_id in 0..n_clients {
            let server_addr = server.local_addr();
            let retx = &retx;
            scope.spawn(move || {
                let mut opts = ClientOptions::new(
                    server_addr.to_string(),
                    700,
                    client_id as u16,
                    d,
                    n_clients as u16,
                );
                // a = 2 but the clients' hot dimensions are disjoint
                // halves of the index space, so no dimension ever gets
                // two votes: k_S = 0 every round.
                opts.threshold_a = 2;
                opts.k = 8;
                opts.backend_seed = 31;
                opts.payload_budget = 32;
                opts.timeout = Duration::from_millis(150);
                opts.max_retries = 100;
                opts.chaos = Some(ChaosConfig::symmetric(
                    91 + client_id as u64,
                    ChaosDirection::lossy(0.10, 0.05, 0.15),
                ));
                let mut client = FediacClient::connect(opts).unwrap();
                for round in 1..=3usize {
                    // Hot |U| only inside this client's private half; the
                    // vote scorer (∝ |U|) cannot realistically pick a
                    // ~1e-30-magnitude dimension over a 1.0 one.
                    let lo = client_id * (d / 2);
                    let update: Vec<f32> = (0..d)
                        .map(|i| if (lo..lo + d / 2).contains(&i) { 1.0 } else { 0.0 })
                        .collect();
                    let out = client.run_round(round, &update).unwrap();
                    assert!(
                        out.gia_indices.is_empty(),
                        "client {client_id} round {round}: expected empty consensus"
                    );
                    assert!(out.aggregate.is_empty());
                    assert_eq!(out.residual, update, "empty round must carry all residual");
                }
                retx.fetch_add(client.stats.retransmissions, Ordering::Relaxed);
            });
        }
    });

    let stats = server.stats();
    assert_eq!(
        stats.rounds_completed, 3,
        "every empty-consensus round must close server-side"
    );
    server.shutdown();
}

/// Chaos under register pressure (ROADMAP follow-on from the chaos PR):
/// a server with barely one resident vote block must process a
/// many-block space in waves *while* the link reorders heavily in both
/// directions. Reordered blocks land beyond the register window and take
/// the spill path; dropped spill is repaired by retransmission — and the
/// rounds must still be bit-exact.
#[test]
fn chaos_under_register_pressure_stays_bit_exact() {
    // budget 16 → one 128-dim vote block = 256 B of counters; 300 B of
    // registers hold exactly one block, so d = 1024 (8 blocks) forces
    // waves on every round.
    let (server, _trace_guard) = start_traced_server(ServeOptions {
        profile: fediac::configx::PsProfile {
            memory_bytes: 300,
            ..fediac::configx::PsProfile::high()
        },
        ..ServeOptions::default()
    });
    let heavy_reorder = ChaosDirection {
        drop: 0.10,
        duplicate: 0.10,
        reorder: 0.50,
        reorder_depth: 6,
        ..ChaosDirection::default()
    };
    let proxy = start_proxy(
        server.local_addr(),
        ChaosConfig { seed: 101, uplink: heavy_reorder, downlink: heavy_reorder },
    );
    let setup = JobSetup {
        job: 650,
        seed: 43,
        d: 1024,
        n_clients: 2,
        threshold_a: 1,
        payload_budget: 16,
    };
    let retx = AtomicU64::new(0);
    run_job(proxy.local_addr(), &setup, &retx);

    let snap = proxy.snapshot();
    assert!(snap.up.reordered > 0 && snap.down.reordered > 0, "reorder never fired");
    let stats = server.stats();
    assert_eq!(stats.rounds_completed, ROUNDS as u64);
    assert!(stats.waves > 0, "tiny register file never forced a wave");
    assert!(
        stats.spilled > 0,
        "heavy reorder against a one-block window should spill out-of-window packets"
    );
    proxy.shutdown();
    server.shutdown();
}

/// Re-join under chaos: restart the server (same port, empty state)
/// between rounds. The client's next round runs into JOIN_UNKNOWN_JOB,
/// re-registers inline and completes bit-exactly — all through a lossy,
/// reordering proxy.
#[test]
fn server_restart_rejoin_under_chaos_stays_exact() {
    let (first, _trace_guard) = start_server();
    let addr = first.local_addr();
    let proxy = start_proxy(
        addr,
        ChaosConfig::symmetric(47, ChaosDirection::lossy(0.15, 0.10, 0.20)),
    );

    let d = 256;
    let seed = 37u64;
    let k = protocol::votes_per_client(d, 0.05);
    let mut opts = ClientOptions::new(proxy.local_addr().to_string(), 800, 0, d, 1);
    opts.threshold_a = 1;
    opts.k = k;
    opts.backend_seed = seed;
    opts.payload_budget = 16;
    opts.timeout = Duration::from_millis(150);
    opts.max_retries = 400;
    let mut client = FediacClient::connect(opts).unwrap();

    let run_and_check = |client: &mut FediacClient, round: usize| {
        let update = synthetic_update(seed, d, 0, round);
        let out = client.run_round(round, &update).unwrap();
        let (ref_idx, ref_lanes) =
            reference_round(&[update], seed, round, k, 1, 12);
        assert_eq!(out.gia_indices, ref_idx, "round {round}");
        assert_eq!(out.aggregate, ref_lanes, "round {round}");
    };
    run_and_check(&mut client, 1);

    // Kill the server and bring an amnesiac replacement up on the SAME
    // address (UDP rebinds immediately; the proxy's upstream sockets
    // keep pointing at it).
    first.shutdown();
    let (second, _second_guard) = start_traced_server(ServeOptions {
        bind: addr.to_string(),
        ..ServeOptions::default()
    });
    assert_eq!(second.local_addr(), addr);

    run_and_check(&mut client, 2);
    assert!(client.stats.rejoins >= 1, "restart must force a mid-round re-join");
    assert_eq!(second.stats().rounds_completed, 1);
    proxy.shutdown();
    second.shutdown();
}
