//! Table-driven tests of the sans-I/O [`Job`] state machine: frame
//! sequences fed straight into `Job::handle` — **no sockets, no
//! threads, no clock** — with the expected transmissions checked step by
//! step. Locks in the behaviours PROTOCOL.md §5–§7 specify: the
//! empty-consensus round closing at phase 1, duplicate/spill discipline
//! under register pressure, and re-serve budget exhaustion
//! (anti-reflection).

use std::net::SocketAddr;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fediac::configx::PsProfile;
use fediac::net::chaos::{ChaosDirection, ChaosLane};
use fediac::server::{Job, JobLimits, ServerStats};
use fediac::telemetry::{FlightRecorder, TraceNote};
use fediac::util::BitVec;
use fediac::wire::{
    decode_frame, encode_frame, update_chunks, vote_chunks, Header, JobSpec, ShardPlan, WireKind,
};

fn addr(port: u16) -> SocketAddr {
    format!("127.0.0.1:{port}").parse().unwrap()
}

fn mkspec(d: u32, n_clients: u16, threshold_a: u16, payload_budget: u16) -> JobSpec {
    JobSpec { d, n_clients, threshold_a, payload_budget, shard: ShardPlan::single(), quorum: 0 }
}

fn profile(memory: usize) -> PsProfile {
    PsProfile { memory_bytes: memory, ..PsProfile::high() }
}

fn join_frame(job: u32, client: u16, spec: &JobSpec) -> Vec<u8> {
    encode_frame(&Header::control(WireKind::Join, job, client, 0, 0), &spec.encode())
}

fn vote_frame(job: u32, client: u16, round: u32, bits: &BitVec, spec: &JobSpec, block: usize) -> Vec<u8> {
    let chunks = vote_chunks(bits, spec.payload_budget as usize);
    let (dims, bytes) = &chunks[block];
    encode_frame(
        &Header {
            kind: WireKind::Vote,
            client,
            job,
            round,
            block: block as u32,
            n_blocks: chunks.len() as u32,
            elems: *dims as u32,
            aux: 1.0f32.to_bits(),
        },
        bytes,
    )
}

fn update_frame(
    job: u32,
    client: u16,
    round: u32,
    lanes: &[i32],
    spec: &JobSpec,
    block: usize,
) -> Vec<u8> {
    let chunks = update_chunks(lanes, spec.payload_budget as usize);
    let (n, bytes) = &chunks[block];
    encode_frame(
        &Header {
            kind: WireKind::Update,
            client,
            job,
            round,
            block: block as u32,
            n_blocks: chunks.len() as u32,
            elems: *n as u32,
            aux: 0,
        },
        bytes,
    )
}

fn poll_frame(job: u32, client: u16, round: u32, want: WireKind) -> Vec<u8> {
    encode_frame(
        &Header {
            kind: WireKind::Poll,
            client,
            job,
            round,
            block: 0,
            n_blocks: 0,
            elems: 0,
            aux: want as u32,
        },
        &[],
    )
}

/// What one step of a script must transmit.
enum Expect {
    /// No datagrams at all.
    Silence,
    /// Exactly these kinds, in multiset terms (order-free — multicast
    /// fan-out order is an implementation detail).
    Kinds(&'static [WireKind]),
}

struct Step {
    desc: &'static str,
    datagram: Vec<u8>,
    from: SocketAddr,
    expect: Expect,
}

/// Feed a script into the job and check each step's transmissions.
fn run_script(job: &mut Job, steps: Vec<Step>) {
    let now = Instant::now();
    for step in steps {
        let frame = decode_frame(&step.datagram).expect(step.desc);
        let out = job.handle(&frame, step.from, now);
        let mut kinds: Vec<WireKind> = out
            .frames
            .iter()
            .map(|(bytes, _)| decode_frame(bytes).expect(step.desc).header.kind)
            .collect();
        match step.expect {
            Expect::Silence => {
                assert!(kinds.is_empty(), "{}: expected silence, sent {kinds:?}", step.desc)
            }
            Expect::Kinds(want) => {
                let mut want: Vec<WireKind> = want.to_vec();
                let sort = |v: &mut Vec<WireKind>| v.sort_by_key(|k| *k as u8);
                sort(&mut kinds);
                sort(&mut want);
                assert_eq!(kinds, want, "{}: wrong transmissions", step.desc);
            }
        }
    }
}

fn stat(counter: &std::sync::atomic::AtomicU64) -> u64 {
    counter.load(Ordering::Relaxed)
}

#[test]
fn empty_consensus_round_closes_at_phase_one() {
    // PROTOCOL §5: a = N = 2 with disjoint votes → empty GIA; the
    // completion multicast must carry BOTH the GIA and the zero-lane
    // aggregate (to each of the 2 clients), and the round is closed —
    // late updates are duplicates, polls re-serve.
    let spec = mkspec(64, 2, 2, 8);
    let stats = Arc::new(ServerStats::default());
    let mut job = Job::with_limits(9, profile(1 << 20), JobLimits::default(), Arc::clone(&stats));
    let v0 = BitVec::from_indices(64, &[1, 2]);
    let v1 = BitVec::from_indices(64, &[10, 20]);
    run_script(
        &mut job,
        vec![
            Step {
                desc: "client 0 joins",
                datagram: join_frame(9, 0, &spec),
                from: addr(4000),
                expect: Expect::Kinds(&[WireKind::JoinAck]),
            },
            Step {
                desc: "client 1 joins",
                datagram: join_frame(9, 1, &spec),
                from: addr(4001),
                expect: Expect::Kinds(&[WireKind::JoinAck]),
            },
            Step {
                desc: "first vote: phase 1 incomplete",
                datagram: vote_frame(9, 0, 0, &v0, &spec, 0),
                from: addr(4000),
                expect: Expect::Silence,
            },
            Step {
                desc: "second vote: empty consensus multicasts GIA + empty aggregate",
                datagram: vote_frame(9, 1, 0, &v1, &spec, 0),
                from: addr(4001),
                expect: Expect::Kinds(&[
                    WireKind::Gia,
                    WireKind::Gia,
                    WireKind::Aggregate,
                    WireKind::Aggregate,
                ]),
            },
            Step {
                desc: "zero-lane update after the close is a late straggler",
                datagram: update_frame(9, 0, 0, &[], &spec, 0),
                from: addr(4000),
                expect: Expect::Silence,
            },
            Step {
                desc: "poll re-serves the empty aggregate to the asker only",
                datagram: poll_frame(9, 0, 0, WireKind::Aggregate),
                from: addr(4000),
                expect: Expect::Kinds(&[WireKind::Aggregate]),
            },
        ],
    );
    assert_eq!(job.round_gia(0).unwrap().count_ones(), 0);
    assert_eq!(job.round_aggregate(0), Some(&[][..]), "round did not close");
    assert_eq!(stat(&stats.rounds_completed), 1);
    // Post-close data frames are stragglers, not duplicates — the
    // distinction is what makes quorum-closed rounds diagnosable.
    assert_eq!(stat(&stats.duplicates), 0);
    assert_eq!(stat(&stats.late_after_close), 1);
}

#[test]
fn duplicate_spill_is_deduped_and_capped() {
    // PROTOCOL §7: with one resident 64-dim wave (200 B of registers)
    // and a spill budget clamped to 16 entries, out-of-window blocks
    // spill once each, retransmissions of spilled blocks are duplicates
    // (never re-buffered), and blocks beyond the cap are dropped.
    let spec = mkspec(64 * 40, 2, 2, 8);
    let stats = Arc::new(ServerStats::default());
    let limits = JobLimits { spill_bytes: 1, ..JobLimits::default() };
    let mut job = Job::with_limits(9, profile(200), limits, Arc::clone(&stats));
    let v = BitVec::from_indices(spec.d as usize, &[1]);
    let mut steps = vec![Step {
        desc: "client 0 joins",
        datagram: join_frame(9, 0, &spec),
        from: addr(4000),
        expect: Expect::Kinds(&[WireKind::JoinAck]),
    }];
    // Blocks 1..=20 all land beyond the (stalled-at-0) window.
    for block in 1..=20 {
        steps.push(Step {
            desc: "out-of-window block spills or drops at the cap",
            datagram: vote_frame(9, 0, 0, &v, &spec, block),
            from: addr(4000),
            expect: Expect::Silence,
        });
    }
    // Retransmissions of an already-spilled block are duplicates.
    steps.push(Step {
        desc: "retransmitted spilled block is a duplicate",
        datagram: vote_frame(9, 0, 0, &v, &spec, 1),
        from: addr(4000),
        expect: Expect::Silence,
    });
    run_script(&mut job, steps);
    assert_eq!(stat(&stats.spilled), 16, "spill cap must clamp to 16 entries");
    assert_eq!(stat(&stats.spill_dropped), 4, "beyond-cap blocks must drop");
    assert_eq!(stat(&stats.duplicates), 1, "re-spill must dedup");
}

#[test]
fn reserve_budget_exhaustion_suppresses_reflection() {
    // PROTOCOL §6–§7: only Poll triggers a re-serve; each source gets
    // `reserve_budget` full-set re-serves per round (4× for addresses
    // registered through Join), after which the server goes silent.
    let spec = mkspec(64, 2, 1, 8);
    let stats = Arc::new(ServerStats::default());
    let limits = JobLimits { reserve_budget: 2, ..JobLimits::default() };
    let mut job = Job::with_limits(9, profile(1 << 20), limits, Arc::clone(&stats));
    let v = BitVec::from_indices(64, &[1, 2]);
    let spoofed = addr(6666);
    let mut steps = vec![
        Step {
            desc: "client 0 joins",
            datagram: join_frame(9, 0, &spec),
            from: addr(4000),
            expect: Expect::Kinds(&[WireKind::JoinAck]),
        },
        Step {
            desc: "client 1 joins",
            datagram: join_frame(9, 1, &spec),
            from: addr(4001),
            expect: Expect::Kinds(&[WireKind::JoinAck]),
        },
        Step {
            desc: "vote 0",
            datagram: vote_frame(9, 0, 0, &v, &spec, 0),
            from: addr(4000),
            expect: Expect::Silence,
        },
        Step {
            desc: "vote 1 completes phase 1 (a=1): GIA to both clients",
            datagram: vote_frame(9, 1, 0, &v, &spec, 0),
            from: addr(4001),
            expect: Expect::Kinds(&[WireKind::Gia, WireKind::Gia]),
        },
        Step {
            desc: "late data frame reflects nothing",
            datagram: vote_frame(9, 0, 0, &v, &spec, 0),
            from: spoofed,
            expect: Expect::Silence,
        },
    ];
    // A spoofed source gets exactly `reserve_budget` re-serves.
    for expect in [
        Expect::Kinds(&[WireKind::Gia][..]),
        Expect::Kinds(&[WireKind::Gia][..]),
        Expect::Silence,
        Expect::Silence,
    ] {
        steps.push(Step {
            desc: "spoofed poll against the re-serve budget",
            datagram: poll_frame(9, 0, 0, WireKind::Gia),
            from: spoofed,
            expect,
        });
    }
    // Join-registered sources keep 4× headroom: 8 polls all serve.
    for _ in 0..8 {
        steps.push(Step {
            desc: "registered client re-serve within 4x budget",
            datagram: poll_frame(9, 0, 0, WireKind::Gia),
            from: addr(4000),
            expect: Expect::Kinds(&[WireKind::Gia]),
        });
    }
    // The 9th registered poll exhausts 4 × 2 and goes silent too.
    steps.push(Step {
        desc: "registered client beyond 4x budget",
        datagram: poll_frame(9, 0, 0, WireKind::Gia),
        from: addr(4000),
        expect: Expect::Silence,
    });
    run_script(&mut job, steps);
    assert_eq!(stat(&stats.reserves_suppressed), 3);
    assert_eq!(stat(&stats.joins), 2);
}

/// Feed one datagram at `t0 + at_ms`, discarding transmissions — the
/// timed scripts below assert on timing and recorder state instead.
fn feed_at(job: &mut Job, t0: Instant, at_ms: u64, datagram: &[u8], from: SocketAddr) {
    let frame = decode_frame(datagram).expect("timed frame");
    job.handle(&frame, from, t0 + Duration::from_millis(at_ms));
}

#[test]
fn phase_durations_follow_the_scripted_clock_exactly() {
    // The Job clocks rounds purely from the `now` values handed to
    // `handle`, so a scripted timeline pins exact durations: votes at
    // +10/+30 ms (vote phase = 20 ms from round creation), updates at
    // +50/+70 ms (update phase = 40 ms, round total = 60 ms), and a
    // 20 ms straggler gap at each phase close.
    let spec = mkspec(64, 2, 1, 8);
    let stats = Arc::new(ServerStats::default());
    let mut job = Job::with_limits(9, profile(1 << 20), JobLimits::default(), Arc::clone(&stats));
    let t0 = Instant::now();
    let v = BitVec::from_indices(64, &[1, 2]);
    let lanes = [3i32, -4];
    feed_at(&mut job, t0, 0, &join_frame(9, 0, &spec), addr(4000));
    feed_at(&mut job, t0, 0, &join_frame(9, 1, &spec), addr(4001));
    feed_at(&mut job, t0, 10, &vote_frame(9, 0, 0, &v, &spec, 0), addr(4000));
    feed_at(&mut job, t0, 30, &vote_frame(9, 1, 0, &v, &spec, 0), addr(4001));
    let mid = job.round_timing(0).expect("round 0 must exist after votes");
    assert_eq!(mid.vote, Some(Duration::from_millis(20)), "vote phase duration");
    assert_eq!(mid.update, None, "update phase still open");
    assert_eq!(mid.total, None, "round still open");
    feed_at(&mut job, t0, 50, &update_frame(9, 0, 0, &lanes, &spec, 0), addr(4000));
    feed_at(&mut job, t0, 70, &update_frame(9, 1, 0, &lanes, &spec, 0), addr(4001));
    let timing = job.round_timing(0).expect("round 0 must exist after close");
    assert_eq!(timing.vote, Some(Duration::from_millis(20)));
    assert_eq!(timing.update, Some(Duration::from_millis(40)));
    assert_eq!(timing.total, Some(Duration::from_millis(60)));
    // The server histograms see the same durations, in microseconds.
    let vote = stats.hist_vote_phase.summary();
    let upd = stats.hist_update_phase.summary();
    let total = stats.hist_round_latency.summary();
    let gap = stats.hist_straggler_gap.summary();
    assert_eq!((vote.count(), vote.max), (1, 20_000), "vote-phase histogram");
    assert_eq!((upd.count(), upd.max), (1, 40_000), "update-phase histogram");
    assert_eq!((total.count(), total.max), (1, 60_000), "round-latency histogram");
    assert_eq!((gap.count(), gap.max), (2, 20_000), "one straggler gap per closed phase");
    assert!(stats.hist_register_stall.summary().is_empty(), "no register stall occurred");
}

#[test]
fn flight_recorder_captures_the_protocol_timeline_in_order() {
    let spec = mkspec(64, 2, 1, 8);
    let stats = Arc::new(ServerStats::default());
    let rec = Arc::new(FlightRecorder::new(64));
    let mut job = Job::with_limits(9, profile(1 << 20), JobLimits::default(), Arc::clone(&stats));
    job.attach_recorder(Arc::clone(&rec));
    let t0 = Instant::now();
    let v = BitVec::from_indices(64, &[1, 2]);
    let lanes = [3i32, -4];
    feed_at(&mut job, t0, 0, &join_frame(9, 0, &spec), addr(4000));
    feed_at(&mut job, t0, 0, &join_frame(9, 1, &spec), addr(4001));
    feed_at(&mut job, t0, 10, &vote_frame(9, 0, 0, &v, &spec, 0), addr(4000));
    feed_at(&mut job, t0, 30, &vote_frame(9, 1, 0, &v, &spec, 0), addr(4001));
    // Retransmission after phase 1 closed: recorded as a late straggler.
    feed_at(&mut job, t0, 40, &vote_frame(9, 0, 0, &v, &spec, 0), addr(4000));
    feed_at(&mut job, t0, 50, &update_frame(9, 0, 0, &lanes, &spec, 0), addr(4000));
    feed_at(&mut job, t0, 70, &update_frame(9, 1, 0, &lanes, &spec, 0), addr(4001));
    feed_at(&mut job, t0, 80, &poll_frame(9, 0, 0, WireKind::Aggregate), addr(4000));
    let notes: Vec<TraceNote> = rec.events().iter().map(|e| e.note).collect();
    assert_eq!(
        notes,
        vec![
            TraceNote::JoinAccepted,
            TraceNote::JoinAccepted,
            TraceNote::Accepted,
            TraceNote::PhaseOneDone,
            TraceNote::LateAfterClose,
            TraceNote::Accepted,
            TraceNote::RoundDone,
            TraceNote::PollServed,
        ],
        "one verdict per handled frame, in arrival order"
    );
    // Every event carries its frame's protocol coordinates and the
    // exact scripted timestamp (measured from the recorder's epoch).
    let phase1 = rec.events()[3];
    assert_eq!(phase1.job, 9);
    assert_eq!(phase1.round, 0);
    assert_eq!(phase1.kind, Some(WireKind::Vote));
    assert_eq!(phase1.client, 1);
    assert_eq!(phase1.peer, Some(addr(4001)));
    assert_eq!(phase1.at_us, rec.stamp(t0 + Duration::from_millis(30)));
    let stamps: Vec<u64> = rec.events().iter().map(|e| e.at_us).collect();
    assert!(stamps.windows(2).all(|w| w[0] <= w[1]), "stamps monotone along the script");
}

#[test]
fn quorum_round_closes_at_the_exact_deadline_and_counts_the_straggler() {
    // PROTOCOL §11: N = 3, Q = 2. Two clients deliver both phases;
    // client 2 never shows. Each phase must close exactly at
    // `phase_deadline` (the armed timer says when, the tick multicast
    // says what), the survivor aggregate must be bit-exact, and the
    // dead client's post-close frames must move only
    // `late_after_close` — recorded as QuorumClose/LateAfterClose
    // verdicts on the flight recorder.
    let spec = JobSpec { quorum: 2, ..mkspec(64, 3, 2, 8) };
    let stats = Arc::new(ServerStats::default());
    let rec = Arc::new(FlightRecorder::new(64));
    let limits =
        JobLimits { phase_deadline: Duration::from_millis(25), ..JobLimits::default() };
    let mut job = Job::with_limits(9, profile(1 << 20), limits, Arc::clone(&stats));
    job.attach_recorder(Arc::clone(&rec));
    let t0 = Instant::now();
    for c in 0..spec.n_clients {
        feed_at(&mut job, t0, 0, &join_frame(9, c, &spec), addr(4000 + c));
    }
    let v = BitVec::from_indices(64, &[1, 2, 40]);
    feed_at(&mut job, t0, 5, &vote_frame(9, 0, 0, &v, &spec, 0), addr(4000));
    feed_at(&mut job, t0, 10, &vote_frame(9, 1, 0, &v, &spec, 0), addr(4001));
    // Quorum met at +10 ms: the phase deadline arms at round creation
    // (+5 ms), so the wakeup lands at exactly +30 ms.
    let deadline = job.next_timer().expect("met quorum must arm the phase deadline");
    assert_eq!(deadline, t0 + Duration::from_millis(5 + 25));
    assert!(job.round_gia(0).is_none(), "phase 1 must stay open before the deadline");
    let out = job.on_tick(deadline);
    let kinds: Vec<WireKind> =
        out.frames.iter().map(|(b, _)| decode_frame(b).unwrap().header.kind).collect();
    assert!(kinds.contains(&WireKind::Gia), "deadline tick must multicast the GIA");
    let gia = job.round_gia(0).expect("phase 1 closed").clone();
    assert_eq!(gia, fediac::compress::deduce_gia(&[v.clone(), v.clone()], 2));
    assert_eq!(stat(&stats.quorum_closes), 1);

    // Phase 2: both survivors upload; the close again waits for the
    // deadline armed by the first Update frame.
    let k_s = gia.count_ones();
    let lanes: Vec<i32> = (0..k_s as i32).map(|x| x + 1).collect();
    let t1_ms = 40u64;
    for c in 0..2u16 {
        feed_at(&mut job, t0, t1_ms, &update_frame(9, c, 0, &lanes, &spec, 0), addr(4000 + c));
    }
    assert!(job.round_aggregate(0).is_none(), "phase 2 must stay open before the deadline");
    let deadline2 = job.next_timer().expect("phase-2 quorum must arm its deadline");
    assert_eq!(deadline2, t0 + Duration::from_millis(t1_ms + 25));
    let out = job.on_tick(deadline2);
    let kinds: Vec<WireKind> =
        out.frames.iter().map(|(b, _)| decode_frame(b).unwrap().header.kind).collect();
    assert!(kinds.contains(&WireKind::Aggregate), "deadline tick must multicast the sum");
    let want: Vec<i32> = lanes.iter().map(|x| 2 * x).collect();
    assert_eq!(job.round_aggregate(0), Some(&want[..]), "survivor sum must be bit-exact");
    assert_eq!(stat(&stats.quorum_closes), 2);
    assert_eq!(stat(&stats.rounds_completed), 1);

    // The dead client finally speaks: a vote and an update for the
    // closed round are counted and dropped — never folded, never
    // reflected.
    feed_at(&mut job, t0, 80, &vote_frame(9, 2, 0, &v, &spec, 0), addr(4002));
    feed_at(&mut job, t0, 85, &update_frame(9, 2, 0, &lanes, &spec, 0), addr(4002));
    assert_eq!(stat(&stats.late_after_close), 2);
    assert_eq!(stat(&stats.duplicates), 0);
    assert_eq!(job.round_aggregate(0), Some(&want[..]), "stragglers corrupted the sum");
    let notes: Vec<TraceNote> = rec.events().iter().map(|e| e.note).collect();
    assert_eq!(notes.iter().filter(|n| **n == TraceNote::QuorumClose).count(), 2);
    assert_eq!(notes.iter().filter(|n| **n == TraceNote::LateAfterClose).count(), 2);
}

#[test]
fn legacy_all_n_rounds_ignore_the_phase_deadline() {
    // quorum = 0 (the pre-§11 wire): even with a phase deadline
    // configured and long expired, an incomplete phase stays open —
    // the round closes only when every client completes, exactly as
    // before the extension. No quorum close, no forced GIA.
    let spec = mkspec(64, 2, 2, 8);
    let stats = Arc::new(ServerStats::default());
    let limits =
        JobLimits { phase_deadline: Duration::from_millis(10), ..JobLimits::default() };
    let mut job = Job::with_limits(9, profile(1 << 20), limits, Arc::clone(&stats));
    let t0 = Instant::now();
    for c in 0..spec.n_clients {
        feed_at(&mut job, t0, 0, &join_frame(9, c, &spec), addr(4000 + c));
    }
    let v = BitVec::from_indices(64, &[4, 9]);
    feed_at(&mut job, t0, 1, &vote_frame(9, 0, 0, &v, &spec, 0), addr(4000));
    // 1 of 2 votes in, deadline long gone: ticks must not force a close.
    let out = job.on_tick(t0 + Duration::from_millis(500));
    assert!(out.frames.is_empty(), "all-N round must never quorum-close");
    assert!(job.round_gia(0).is_none(), "phase 1 closed without every client");
    assert_eq!(stat(&stats.quorum_closes), 0);
    // The last client completes the phase the legacy way.
    feed_at(&mut job, t0, 600, &vote_frame(9, 1, 0, &v, &spec, 0), addr(4001));
    assert_eq!(
        job.round_gia(0),
        Some(&fediac::compress::deduce_gia(&[v.clone(), v], 2)),
        "all-N completion must close phase 1 exactly as before the extension"
    );
    assert_eq!(stat(&stats.quorum_closes), 0);
}

/// Recorded (job, round, kind, client, note) tuples, arrival order.
type ChaosEvents = Vec<(u32, u32, Option<WireKind>, u16, TraceNote)>;

/// One seeded uplink chaos run: two rounds of both clients' multi-block
/// votes pass through a drop/dup [`ChaosLane`] before reaching the job.
/// Returns the recorded event sequence plus the lane's drop/dup
/// counters.
fn chaos_leg(seed: u64) -> (ChaosEvents, u64, u64) {
    let spec = mkspec(1024, 2, 1, 8);
    let stats = Arc::new(ServerStats::default());
    let rec = Arc::new(FlightRecorder::new(1024));
    let mut job = Job::with_limits(9, profile(1 << 20), JobLimits::default(), Arc::clone(&stats));
    job.attach_recorder(Arc::clone(&rec));
    let now = Instant::now();
    // Drop and duplicate only — no reordering holds, no corruption —
    // so every surviving copy still parses and arrives immediately.
    let mut lane: ChaosLane<SocketAddr> =
        ChaosLane::new(ChaosDirection::lossy(0.2, 0.3, 0.0), seed);
    // Joins bypass the lane so the job is always configured.
    feed_at(&mut job, now, 0, &join_frame(9, 0, &spec), addr(4000));
    feed_at(&mut job, now, 0, &join_frame(9, 1, &spec), addr(4001));
    let v = BitVec::from_indices(1024, &[1, 2, 3]);
    let blocks = vote_chunks(&v, spec.payload_budget as usize).len();
    for round in 0..2u32 {
        for client in 0..2u16 {
            for block in 0..blocks {
                let datagram = vote_frame(9, client, round, &v, &spec, block);
                for (bytes, from) in lane.process(&datagram, addr(4000 + client), now) {
                    let frame = decode_frame(&bytes).expect("chaos keeps frames parseable");
                    job.handle(&frame, from, now);
                }
            }
        }
    }
    let events =
        rec.events().iter().map(|e| (e.job, e.round, e.kind, e.client, e.note)).collect();
    let dropped = lane.stats().dropped.load(Ordering::Relaxed);
    let duplicated = lane.stats().duplicated.load(Ordering::Relaxed);
    (events, dropped, duplicated)
}

#[test]
fn chaos_drop_dup_events_reach_the_recorder_deterministically() {
    // The lane's RNG stream is fully determined by its seed, and the
    // Job is a pure state machine — so the whole recorded timeline must
    // replay bit-for-bit, and the lane's duplicated copies must each
    // surface as a recorded duplicate verdict.
    let (first, dropped, duplicated) = chaos_leg(42);
    let (second, dropped2, duplicated2) = chaos_leg(42);
    assert_eq!(first, second, "same seed must record the identical event sequence");
    assert_eq!((dropped, duplicated), (dropped2, duplicated2), "lane counters replay too");
    assert!(dropped > 0, "seed 42 must exercise the drop knob");
    assert!(duplicated > 0, "seed 42 must exercise the dup knob");
    let dup_notes =
        first.iter().filter(|(_, _, _, _, note)| *note == TraceNote::Duplicate).count();
    let late_notes =
        first.iter().filter(|(_, _, _, _, note)| *note == TraceNote::LateAfterClose).count();
    assert_eq!(
        (dup_notes + late_notes) as u64,
        duplicated,
        "every lane duplicate must surface as a duplicate (phase open) or a \
         late-after-close straggler (phase closed) verdict"
    );
}
