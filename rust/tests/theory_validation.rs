//! Monte-Carlo validation of §IV-B: the analytic Proposition-1 pipeline
//! (p_l → q_l → r_l → E[k_S], γ) against the actual voting + GIA +
//! quantisation implementation (E7 as assertions).

use fediac::compress::{
    deduce_gia, error::relative_error, max_abs, quantize_sparsify, scale_factor,
    vote_bitmap,
};
use fediac::theory::{
    bits_lower_bound, fit_power_law, min_bits, prop1_evaluate, PowerLaw, Prop1Params,
};
use fediac::util::{BitVec, Rng};

/// Build a shuffled power-law update vector.
fn power_law_updates(d: usize, law: &PowerLaw, rng: &mut Rng) -> Vec<f32> {
    let mut index_of_rank: Vec<usize> = (0..d).collect();
    rng.shuffle(&mut index_of_rank);
    let mut u = vec![0.0f32; d];
    for (rank, &idx) in index_of_rank.iter().enumerate() {
        let sign = if rng.f64() < 0.5 { -1.0 } else { 1.0 };
        u[idx] = (sign * law.magnitude(rank + 1)) as f32;
    }
    u
}

#[test]
fn expected_uploads_match_simulation() {
    let d = 8_000;
    let n = 20;
    let k = d / 20;
    let law = PowerLaw { phi: 0.1, alpha: -0.7 };
    let mut rng = Rng::new(3);
    let updates = power_law_updates(d, &law, &mut rng);
    for a in [1usize, 3, 6] {
        let analytic = prop1_evaluate(&Prop1Params {
            d,
            n_clients: n,
            k,
            threshold_a: a,
            law,
            bits_b: 12,
        })
        .expected_uploads;
        let trials = 6;
        let mut sim = 0.0;
        for _ in 0..trials {
            let votes: Vec<BitVec> =
                (0..n).map(|_| vote_bitmap(&updates, k, &mut rng)).collect();
            sim += deduce_gia(&votes, a).count_ones() as f64;
        }
        sim /= trials as f64;
        let rel = (sim - analytic).abs() / analytic.max(1.0);
        // The analytic form assumes per-rank independence; the simulation
        // samples without replacement, so agreement within ~35% is the
        // expected regime (tightens as a grows).
        assert!(rel < 0.35, "a={a}: sim {sim:.1} vs analytic {analytic:.1}");
    }
}

#[test]
fn gamma_bound_holds_empirically() {
    // Proposition 1 is an upper bound: measured γ̂ must stay below the
    // analytic γ for every threshold (with the matched b from Cor. 1).
    let d = 8_000;
    let n = 20;
    let k = d / 20;
    let law = PowerLaw { phi: 0.1, alpha: -0.7 };
    let mut rng = Rng::new(4);
    let updates = power_law_updates(d, &law, &mut rng);
    for a in [1usize, 3, 6] {
        let b = min_bits(d, n, k, a, &law);
        let out = prop1_evaluate(&Prop1Params {
            d,
            n_clients: n,
            k,
            threshold_a: a,
            law,
            bits_b: b,
        });
        let votes: Vec<BitVec> =
            (0..n).map(|_| vote_bitmap(&updates, k, &mut rng)).collect();
        let gia = deduce_gia(&votes, a);
        let f = scale_factor(b, n, max_abs(&updates));
        let (q, _) = quantize_sparsify(&updates, &gia.to_f32_mask(), f, &mut rng);
        let gamma_hat = relative_error(&q, &updates, f);
        assert!(
            gamma_hat <= out.gamma + 0.05,
            "a={a}: γ̂ {gamma_hat:.4} exceeds bound γ {:.4}",
            out.gamma
        );
        assert!(gamma_hat < 1.0, "a={a}: γ̂ {gamma_hat} ≥ 1 breaks convergence");
    }
}

#[test]
fn fitted_law_reproduces_generator() {
    let law = PowerLaw { phi: 0.2, alpha: -0.85 };
    let mut rng = Rng::new(5);
    let updates = power_law_updates(10_000, &law, &mut rng);
    let fit = fit_power_law(&updates).unwrap();
    assert!((fit.alpha - law.alpha).abs() < 0.05, "alpha {}", fit.alpha);
    assert!((fit.phi - law.phi).abs() / law.phi < 0.1, "phi {}", fit.phi);
}

#[test]
fn corollary1_is_tight_under_simulation() {
    // One bit below the Corollary-1 minimum must push the analytic γ out
    // of (0,1) — the knife-edge the paper tunes b on.
    let d = 5_000;
    let n = 20;
    let k = 250;
    let a = 3;
    let law = PowerLaw { phi: 0.1, alpha: -0.7 };
    let b = min_bits(d, n, k, a, &law);
    let bound = bits_lower_bound(d, n, k, a, &law);
    assert!((b as f64) > bound && (b as f64 - 1.0) <= bound);
    let ok = prop1_evaluate(&Prop1Params {
        d,
        n_clients: n,
        k,
        threshold_a: a,
        law,
        bits_b: b,
    });
    assert!(ok.gamma < 1.0);
    if b > 2 {
        let below = prop1_evaluate(&Prop1Params {
            d,
            n_clients: n,
            k,
            threshold_a: a,
            law,
            bits_b: b - 1,
        });
        assert!(
            below.gamma >= ok.gamma,
            "shrinking b must not shrink γ: {} vs {}",
            below.gamma,
            ok.gamma
        );
    }
}

#[test]
fn vote_probability_chain_is_ordered() {
    // p and q decrease in rank; r decreases in rank for fixed a.
    let d = 1_000;
    let p = fediac::theory::prop1::vote_prob(d, -0.6);
    let q = fediac::theory::prop1::voted_prob(&p, 50);
    let r: Vec<f64> =
        q.iter().map(|&ql| fediac::theory::prop1::binom_tail_geq(20, ql, 3)).collect();
    for w in p.windows(2) {
        assert!(w[0] >= w[1]);
    }
    for w in q.windows(2) {
        assert!(w[0] >= w[1] - 1e-12);
    }
    for w in r.windows(2) {
        assert!(w[0] >= w[1] - 1e-12);
    }
}
