//! Property-style round-trip tests for the wire codec: encode→decode
//! identity for all three packet phases, plus corruption cases (truncated
//! buffer, flipped checksum byte, wrong version).

use fediac::compress::golomb;
use fediac::prop_assert;
use fediac::util::{prop, BitVec, Rng};
use fediac::wire::{
    byte_chunks, decode_frame, decode_lanes, encode_frame, encode_lanes, update_chunks,
    vote_chunks, ChunkAssembler, Frame, Header, JobSpec, ShardPlan, WireError, WireKind,
    HEADER_LEN,
};

fn random_bitvec(rng: &mut Rng, d: usize, density: f64) -> BitVec {
    let mut bv = BitVec::zeros(d);
    for i in 0..d {
        if rng.f64() < density {
            bv.set(i, true);
        }
    }
    bv
}

fn header(kind: WireKind, block: u32, n_blocks: u32, elems: u32, aux: u32) -> Header {
    Header { kind, client: 2, job: 31, round: 5, block, n_blocks, elems, aux }
}

#[test]
fn vote_phase_roundtrip_property() {
    // A client's vote bitmap, chunked into Vote frames, must survive
    // encode→decode→reassembly bit-exactly for any dimension/density.
    prop::check("vote_wire_roundtrip", prop::default_cases(), |rng| {
        let d = prop::gen_dim(rng);
        let bv = random_bitvec(rng, d, rng.f64());
        let budget = 8 * (1 + rng.below(4)); // 8..32 bytes
        let chunks = vote_chunks(&bv, budget);
        let mut bytes = Vec::new();
        for (i, (dims, payload)) in chunks.iter().enumerate() {
            let buf = encode_frame(
                &header(WireKind::Vote, i as u32, chunks.len() as u32, *dims as u32, 0),
                payload,
            );
            let frame: Frame<'_> = decode_frame(&buf).map_err(|e| e.to_string())?;
            prop_assert!(frame.header.kind == WireKind::Vote, "kind changed");
            prop_assert!(frame.header.block == i as u32, "block changed");
            prop_assert!(frame.payload == &payload[..], "payload changed");
            bytes.extend_from_slice(frame.payload);
        }
        let rt = BitVec::from_bytes(d, &bytes);
        prop_assert!(rt == bv, "bitmap mutated on the wire (d={d})");
        Ok(())
    });
}

#[test]
fn update_phase_roundtrip_property() {
    prop::check("update_wire_roundtrip", prop::default_cases(), |rng| {
        let k_s = 1 + rng.below(2000);
        let lanes: Vec<i32> =
            (0..k_s).map(|_| (rng.next_u32() as i32).wrapping_div(3)).collect();
        let budget = 4 * (1 + rng.below(64)); // 4..256 bytes
        let chunks = update_chunks(&lanes, budget);
        let mut got = Vec::new();
        for (i, (n, payload)) in chunks.iter().enumerate() {
            let buf = encode_frame(
                &header(WireKind::Update, i as u32, chunks.len() as u32, *n as u32, 0),
                payload,
            );
            let frame = decode_frame(&buf).map_err(|e| e.to_string())?;
            let dec = decode_lanes(frame.payload).map_err(|e| e.to_string())?;
            prop_assert!(dec.len() == *n, "lane count changed");
            got.extend(dec);
        }
        prop_assert!(got == lanes, "lanes mutated on the wire (k_s={k_s})");
        Ok(())
    });
}

#[test]
fn broadcast_phase_roundtrip_property() {
    // Golomb-coded GIA chunked into Broadcast frames and reassembled out
    // of order must decode to the original bitmap.
    prop::check("gia_wire_roundtrip", prop::default_cases(), |rng| {
        let d = prop::gen_dim(rng);
        let gia = random_bitvec(rng, d, rng.f64() * rng.f64());
        let encoded = golomb::encode(&gia);
        let budget = 8 * (1 + rng.below(8));
        let chunks = byte_chunks(&encoded, budget);
        let mut order: Vec<usize> = (0..chunks.len()).collect();
        rng.shuffle(&mut order);
        let mut asm = ChunkAssembler::new(chunks.len());
        for &i in &order {
            let buf = encode_frame(
                &header(WireKind::Gia, i as u32, chunks.len() as u32, chunks[i].len() as u32, 0),
                &chunks[i],
            );
            let frame = decode_frame(&buf).map_err(|e| e.to_string())?;
            asm.insert(frame.header.block as usize, frame.payload);
        }
        prop_assert!(asm.is_complete(), "chunks missing after shuffle");
        let rt = golomb::decode(&asm.assemble()).ok_or("golomb decode failed")?;
        prop_assert!(rt == gia, "GIA mutated on the wire (d={d})");
        Ok(())
    });
}

#[test]
fn truncated_buffers_rejected_at_every_length() {
    let payload: Vec<u8> = (0..=200u8).collect();
    let buf = encode_frame(&header(WireKind::Aggregate, 0, 1, 201, 7), &payload);
    for cut in 0..buf.len() {
        let err = decode_frame(&buf[..cut]).unwrap_err();
        assert!(
            matches!(err, WireError::Truncated { .. }),
            "cut at {cut} gave {err:?}"
        );
    }
    assert!(decode_frame(&buf).is_ok());
}

#[test]
fn flipped_checksum_byte_rejected() {
    let buf = encode_frame(&header(WireKind::Vote, 0, 1, 8, 0), &[0xAB]);
    // Flip each stored-checksum byte individually.
    for i in 36..HEADER_LEN {
        let mut bad = buf.clone();
        bad[i] ^= 0x01;
        assert!(
            matches!(decode_frame(&bad), Err(WireError::ChecksumMismatch { .. })),
            "checksum byte {i} accepted"
        );
    }
    // Flip a payload byte: the checksum must catch it.
    let mut bad = buf.clone();
    *bad.last_mut().unwrap() ^= 0x80;
    assert!(matches!(decode_frame(&bad), Err(WireError::ChecksumMismatch { .. })));
}

#[test]
fn wrong_version_rejected() {
    let mut buf = encode_frame(&header(WireKind::Vote, 0, 1, 8, 0), &[0xFF]);
    buf[4] = 2;
    assert_eq!(decode_frame(&buf).unwrap_err(), WireError::BadVersion(2));
    buf[4] = 0;
    assert_eq!(decode_frame(&buf).unwrap_err(), WireError::BadVersion(0));
}

#[test]
fn job_spec_survives_join_frame() {
    let spec = JobSpec {
        d: 123_456,
        n_clients: 20,
        threshold_a: 3,
        payload_budget: 1408,
        shard: ShardPlan::single(),
        quorum: 0,
    };
    let buf = encode_frame(&Header::control(WireKind::Join, 9, 4, 0, 0), &spec.encode());
    let frame = decode_frame(&buf).unwrap();
    assert_eq!(JobSpec::decode(frame.payload).unwrap(), spec);
}
