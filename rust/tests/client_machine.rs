//! Table-driven tests of the sans-I/O [`ClientCore`] state machine:
//! frame scripts fed straight into `ClientCore::handle` with a
//! **scripted clock** — no sockets, no threads, no sleeps — mirroring
//! `tests/job_machine.rs` on the server side. Locks in the
//! chaos-matrix-proven client behaviours: join-ack races, mid-phase
//! re-join, GIA stream resets, retransmit budget exhaustion, the
//! empty-consensus round and the bounded pending stash.

use std::time::{Duration, Instant};

use fediac::client::{ClientCore, ClientOutput, CoreConfig, Progress};
use fediac::compress::golomb;
use fediac::server::{JOIN_OK, JOIN_UNKNOWN_JOB};
use fediac::util::BitVec;
use fediac::wire::{
    byte_chunks, decode_frame, encode_frame, encode_lanes, Header, ShardPlan, WireKind,
};

const JOB: u32 = 9;
const TIMEOUT: Duration = Duration::from_millis(100);

fn mk_core(d: usize, payload_budget: usize, max_retries: usize) -> ClientCore {
    ClientCore::new(CoreConfig {
        job: JOB,
        client_id: 0,
        n_clients: 2,
        d,
        threshold_a: 1,
        payload_budget,
        timeout: TIMEOUT,
        max_retries,
        shard: ShardPlan::single(),
        quorum: 0,
    })
}

/// A core for a quorum job (PROTOCOL §11): same endpoint, but timeouts
/// with a partially-assembled wanted broadcast re-sync instead of
/// retransmitting.
fn mk_quorum_core(d: usize, payload_budget: usize, max_retries: usize, quorum: u16) -> ClientCore {
    ClientCore::new(CoreConfig {
        job: JOB,
        client_id: 0,
        n_clients: 2,
        d,
        threshold_a: 1,
        payload_budget,
        timeout: TIMEOUT,
        max_retries,
        shard: ShardPlan::single(),
        quorum,
    })
}

fn join_ack(job: u32, client: u16, status: u32) -> Vec<u8> {
    encode_frame(&Header::control(WireKind::JoinAck, job, client, 0, status), &[])
}

/// The server's broadcast chunks for an opaque byte stream.
fn bcast_frames(
    kind: WireKind,
    round: u32,
    bytes: &[u8],
    aux: u32,
    budget: usize,
) -> Vec<Vec<u8>> {
    let chunks = byte_chunks(bytes, budget);
    let n_blocks = chunks.len() as u32;
    chunks
        .iter()
        .enumerate()
        .map(|(i, c)| {
            encode_frame(
                &Header {
                    kind,
                    client: u16::MAX,
                    job: JOB,
                    round,
                    block: i as u32,
                    n_blocks,
                    elems: c.len() as u32,
                    aux,
                },
                c,
            )
        })
        .collect()
}

fn gia_frames(round: u32, gia: &BitVec, global_max: f32, budget: usize) -> Vec<Vec<u8>> {
    bcast_frames(WireKind::Gia, round, &golomb::encode(gia), global_max.to_bits(), budget)
}

fn agg_frames(round: u32, lanes: &[i32], budget: usize) -> Vec<Vec<u8>> {
    bcast_frames(WireKind::Aggregate, round, &encode_lanes(lanes), lanes.len() as u32, budget)
}

/// The kinds of an output's emitted frames, in order.
fn kinds(out: &ClientOutput) -> Vec<WireKind> {
    out.frames.iter().map(|f| decode_frame(f).expect("emitted frame decodes").header.kind).collect()
}

/// Join a fresh core at `now` (one ack, no races).
fn joined(core: &mut ClientCore, now: Instant) {
    let out = core.start_join(now);
    assert_eq!(kinds(&out), [WireKind::Join]);
    let out = core.handle(&join_ack(JOB, 0, JOIN_OK), now);
    assert!(matches!(out.progress, Some(Progress::Joined)));
    assert!(out.timer.is_none(), "join ack disarms the timer");
    assert!(core.is_joined());
}

/// Drive a full clean vote phase for `round` and return the decoded GIA.
fn vote_to_gia(core: &mut ClientCore, round: u32, gia: &BitVec, budget: usize, now: Instant) {
    let votes = BitVec::from_indices(gia.len(), &[0]);
    let out = core.start_vote(round, &votes, 1.0, now);
    assert!(kinds(&out).iter().all(|k| *k == WireKind::Vote));
    assert_eq!(core.waiting_round(), Some(round));
    let frames = gia_frames(round, gia, 2.0, budget);
    let (last, head) = frames.split_last().expect("at least one GIA chunk");
    for f in head {
        let out = core.handle(f, now);
        assert!(out.progress.is_none(), "incomplete stream must not complete");
    }
    let out = core.handle(last, now);
    match out.progress {
        Some(Progress::GiaReady { round: r, gia: got, global_max }) => {
            assert_eq!(r, round);
            assert_eq!(&got, gia);
            assert_eq!(global_max, 2.0);
        }
        other => panic!("expected GiaReady, got {other:?}"),
    }
    assert!(out.timer.is_none(), "completed wait disarms the timer");
    assert_eq!(core.waiting_round(), None);
}

#[test]
fn join_ack_races_are_harmless() {
    let t0 = Instant::now();
    let mut core = mk_core(64, 32, 3);
    let out = core.start_join(t0);
    assert_eq!(kinds(&out), [WireKind::Join]);
    assert!(out.timer.is_some());

    // An ack for some other job: ignored, still joining.
    let out = core.handle(&join_ack(JOB + 1, 0, JOIN_OK), t0);
    assert!(out.progress.is_none());
    assert!(!core.is_joined());

    // The real ack.
    let out = core.handle(&join_ack(JOB, 0, JOIN_OK), t0);
    assert!(matches!(out.progress, Some(Progress::Joined)));

    // A duplicate ack while idle: no progress, no frames, no timer.
    let out = core.handle(&join_ack(JOB, 0, JOIN_OK), t0);
    assert!(out.progress.is_none() && out.frames.is_empty() && out.timer.is_none());

    // A duplicate ack mid-wait (the retransmitted join's second ack
    // arriving after the first already moved us on): ignored, and the
    // wanted broadcast still completes the phase.
    let gia = BitVec::from_indices(64, &[3, 17]);
    let votes = BitVec::from_indices(64, &[0]);
    core.start_vote(1, &votes, 1.0, t0);
    let out = core.handle(&join_ack(JOB, 0, JOIN_OK), t0);
    assert!(out.progress.is_none() && out.frames.is_empty());
    assert_eq!(core.waiting_round(), Some(1));
    for f in gia_frames(1, &gia, 2.0, 32) {
        core.handle(&f, t0);
    }
    assert_eq!(core.waiting_round(), None, "GIA completed the wait");

    // A refused *initial* join is terminal.
    let mut refused = mk_core(64, 32, 3);
    refused.start_join(t0);
    let out = refused.handle(&join_ack(JOB, 0, 3), t0);
    match out.progress {
        Some(Progress::Failed { reason }) => {
            assert!(reason.contains("refused join"), "{reason}")
        }
        other => panic!("expected Failed, got {other:?}"),
    }
    assert!(refused.is_failed());
}

#[test]
fn mid_phase_rejoin_reuploads_and_completes() {
    let t0 = Instant::now();
    let mut core = mk_core(64, 32, 3);
    joined(&mut core, t0);

    let votes = BitVec::from_indices(64, &[0, 9]);
    let out = core.start_vote(1, &votes, 1.0, t0);
    let n_vote_frames = out.frames.len();

    // Server evicted the job: UNKNOWN_JOB triggers an inline re-join
    // without leaving the wait.
    let out = core.handle(&join_ack(JOB, 0, JOIN_UNKNOWN_JOB), t0);
    assert_eq!(kinds(&out), [WireKind::Join]);
    assert_eq!(core.stats.rejoins, 1);
    assert_eq!(core.waiting_round(), Some(1), "still waiting through the re-join");

    // A repeated UNKNOWN_JOB while the re-join is in flight: the timer
    // path owns the retransmit — no second join, no failure.
    let out = core.handle(&join_ack(JOB, 0, JOIN_UNKNOWN_JOB), t0);
    assert!(out.frames.is_empty() && out.progress.is_none());
    assert_eq!(core.stats.rejoins, 1);

    // Re-registration confirmed: the phase's upload is re-sent in full
    // (the server may have lost the round state too).
    let out = core.handle(&join_ack(JOB, 0, JOIN_OK), t0);
    assert_eq!(out.frames.len(), n_vote_frames);
    assert!(kinds(&out).iter().all(|k| *k == WireKind::Vote));
    assert_eq!(core.stats.retransmissions, n_vote_frames as u64);

    // The wanted broadcast still lands.
    let gia = BitVec::from_indices(64, &[9]);
    let mut done = false;
    for f in gia_frames(1, &gia, 2.0, 32) {
        done = core.handle(&f, t0).progress.is_some();
    }
    assert!(done, "GIA must complete after the re-join");

    // A *refused* re-join, by contrast, is terminal.
    let mut core = mk_core(64, 32, 3);
    joined(&mut core, t0);
    core.start_vote(1, &votes, 1.0, t0);
    core.handle(&join_ack(JOB, 0, JOIN_UNKNOWN_JOB), t0);
    let out = core.handle(&join_ack(JOB, 0, 5), t0);
    match out.progress {
        Some(Progress::Failed { reason }) => {
            assert!(reason.contains("refused re-join: status 5"), "{reason}")
        }
        other => panic!("expected Failed, got {other:?}"),
    }
}

#[test]
fn gia_stream_reset_discards_the_stale_stream() {
    let t0 = Instant::now();
    // Dense GIA over d=512 at an 8-byte budget: several chunks, so a
    // stale stream can interleave mid-reassembly.
    let d = 512;
    let budget = 8;
    let mut core = mk_core(d, budget, 3);
    joined(&mut core, t0);
    let votes = BitVec::from_indices(d, &[0]);
    core.start_vote(1, &votes, 1.0, t0);

    let gia = BitVec::from_indices(d, &(0..d).step_by(2).collect::<Vec<_>>());
    let real = gia_frames(1, &gia, 2.0, budget);
    assert!(real.len() >= 2, "test needs a multi-chunk GIA stream");

    // Chunk 0 of the real stream…
    assert!(core.handle(&real[0], t0).progress.is_none());
    // …then a stale GIA broadcast for the same round disagreeing on the
    // aux word (a different global max — e.g. a replayed pre-restart
    // stream): the assembler restarts rather than completing with
    // chunks from both.
    let stale = gia_frames(1, &gia, 9.0, budget);
    assert!(core.handle(&stale[0], t0).progress.is_none());
    assert_eq!(core.stats.stream_resets, 1);

    // The real stream, re-delivered in full, completes with the real
    // aux (one more reset as it displaces the stale stream).
    let mut completed = None;
    for f in &real {
        if let Some(p) = core.handle(f, t0).progress {
            completed = Some(p);
        }
    }
    match completed {
        Some(Progress::GiaReady { gia: got, global_max, .. }) => {
            assert_eq!(got, gia);
            assert_eq!(global_max, 2.0, "stale stream's aux must not survive");
        }
        other => panic!("expected GiaReady, got {other:?}"),
    }
    assert_eq!(core.stats.stream_resets, 2);
}

#[test]
fn retransmit_budget_exhaustion_fails_the_wait() {
    let t0 = Instant::now();
    let mut core = mk_core(64, 32, 2);
    joined(&mut core, t0);
    let votes = BitVec::from_indices(64, &[0]);
    let out = core.start_vote(1, &votes, 1.0, t0);
    let n_vote_frames = out.frames.len();
    let deadline = out.timer.expect("wait arms the timer");

    // An early tick is a no-op that re-reports the live deadline.
    let out = core.on_tick(t0 + Duration::from_millis(1));
    assert!(out.frames.is_empty());
    assert_eq!(out.timer, Some(deadline));

    // Each due tick within budget retransmits the upload and polls.
    for burned in 1..=2u64 {
        let out = core.on_tick(t0 + TIMEOUT * 3 * burned as u32);
        let ks = kinds(&out);
        assert_eq!(ks.len(), n_vote_frames + 1);
        assert_eq!(*ks.last().unwrap(), WireKind::Poll);
        assert_eq!(core.stats.polls, burned);
        assert!(out.timer.is_some());
    }
    // The tick past the budget is terminal.
    let out = core.on_tick(t0 + TIMEOUT * 12);
    match out.progress {
        Some(Progress::Failed { reason }) => {
            assert!(reason.contains("timed out waiting for Gia of round 1"), "{reason}")
        }
        other => panic!("expected Failed, got {other:?}"),
    }
    assert!(out.timer.is_none());
    assert!(core.is_failed());
    // A dead core ignores everything.
    let out = core.handle(&join_ack(JOB, 0, JOIN_OK), t0 + TIMEOUT * 13);
    assert!(out.frames.is_empty() && out.progress.is_none() && out.timer.is_none());

    // Join waits exhaust the same way.
    let mut core = mk_core(64, 32, 1);
    core.start_join(t0);
    assert!(core.on_tick(t0 + TIMEOUT).progress.is_none());
    let out = core.on_tick(t0 + TIMEOUT * 4);
    match out.progress {
        Some(Progress::Failed { reason }) => {
            assert!(reason.contains("join timed out"), "{reason}")
        }
        other => panic!("expected Failed, got {other:?}"),
    }
}

#[test]
fn empty_consensus_round_completes_without_an_aggregate_wait() {
    let t0 = Instant::now();
    let d = 64;
    let budget = 32;
    let mut core = mk_core(d, budget, 3);
    joined(&mut core, t0);

    // No dimension reached the threshold: the GIA is all zeros and the
    // server multicasts GIA and the empty aggregate back-to-back.
    let gia = BitVec::zeros(d);
    vote_to_gia(&mut core, 1, &gia, budget, t0);

    // The empty aggregate lands while the caller is still between
    // phases (Idle): it must be stashed, not dropped.
    for f in agg_frames(1, &[], budget) {
        let out = core.handle(&f, t0);
        assert!(out.progress.is_none());
    }

    // start_update with zero lanes then completes from the stash
    // immediately — no upload, no timer, no extra wait.
    let out = core.start_update(1, &[], 1.0, t0);
    assert!(out.frames.is_empty(), "stash-served wait must not upload");
    assert!(out.timer.is_none());
    match out.progress {
        Some(Progress::AggregateReady { round, lanes }) => {
            assert_eq!(round, 1);
            assert!(lanes.is_empty());
        }
        other => panic!("expected AggregateReady, got {other:?}"),
    }
}

#[test]
fn quorum_timeout_with_partial_broadcast_resyncs_instead_of_retransmitting() {
    // PROTOCOL §11: on a quorum job, a timeout while the wanted
    // broadcast has already started arriving proves the phase closed
    // without us — the round went on. Retransmitting the upload would
    // only feed the server's late-after-close counter, so the core
    // sends ONLY a Poll for the remaining chunks. A legacy all-N core
    // in the identical state keeps the historical
    // retransmit-everything behaviour, bit for bit.
    let t0 = Instant::now();
    let d = 512;
    let budget = 8;
    let gia = BitVec::from_indices(d, &(0..d).step_by(2).collect::<Vec<_>>());
    let bcast = gia_frames(1, &gia, 2.0, budget);
    assert!(bcast.len() >= 2, "test needs a multi-chunk GIA stream");

    let run = |mut core: ClientCore| -> (Vec<WireKind>, u64, u64) {
        joined(&mut core, t0);
        let votes = BitVec::from_indices(d, &[0]);
        core.start_vote(1, &votes, 1.0, t0);
        // The first chunk of the re-served GIA lands, then silence: the
        // quorum closed the phase and the rest of the broadcast was
        // lost.
        assert!(core.handle(&bcast[0], t0).progress.is_none());
        let retx_before = core.stats.retransmissions;
        let out = core.on_tick(t0 + TIMEOUT * 2);
        assert!(out.progress.is_none(), "one timeout must not fail the wait");
        (kinds(&out), core.stats.retransmissions - retx_before, core.stats.quorum_resyncs)
    };

    let (ks, retx, resyncs) = run(mk_quorum_core(d, budget, 3, 2));
    assert_eq!(ks, [WireKind::Poll], "quorum re-sync sends the Poll and nothing else");
    assert_eq!(retx, 0, "re-sync must not retransmit the vote upload");
    assert_eq!(resyncs, 1);

    let (ks, retx, resyncs) = run(mk_core(d, budget, 3));
    assert_eq!(*ks.last().unwrap(), WireKind::Poll);
    assert!(
        ks.iter().filter(|k| **k == WireKind::Vote).count() > 0,
        "legacy all-N timeout must keep retransmitting the upload"
    );
    assert_eq!(retx, ks.len() as u64 - 1, "every non-Poll frame is a retransmission");
    assert_eq!(resyncs, 0, "quorum=0 must never take the re-sync path");
}

#[test]
fn stale_rejoiner_catches_up_from_reserved_broadcasts() {
    // The client-churn rejoin path, scripted at the core level: a fresh
    // core (the corpse's replacement, same client id) joins a job whose
    // round already quorum-closed without it. Its vote upload is dead
    // weight server-side (late_after_close), but the re-served GIA
    // broadcast completes the vote wait; the update wait then times out
    // with a partial aggregate stream and must re-sync — Poll only —
    // before the remaining chunks land the round.
    let t0 = Instant::now();
    let d = 64;
    let budget = 8;
    let mut core = mk_quorum_core(d, budget, 3, 2);
    joined(&mut core, t0);

    // Vote for the stale round; the server never counts it, but the
    // GIA it already multicast (re-served from round history) arrives
    // in full and completes the wait.
    let votes = BitVec::from_indices(d, &[0, 9]);
    core.start_vote(1, &votes, 1.0, t0);
    let gia = BitVec::from_indices(d, &[4, 8, 12, 16, 20, 24]);
    let mut got_gia = None;
    for f in gia_frames(1, &gia, 2.0, budget) {
        if let Some(p) = core.handle(&f, t0).progress {
            got_gia = Some(p);
        }
    }
    match got_gia {
        Some(Progress::GiaReady { round, gia: got, .. }) => {
            assert_eq!(round, 1);
            assert_eq!(got, gia, "stale rejoiner must adopt the quorum's GIA");
        }
        other => panic!("expected GiaReady, got {other:?}"),
    }

    // Update phase: the closed round's aggregate stream arrives
    // partially, the timeout re-syncs (no lane retransmission), and the
    // remaining chunks complete the round.
    let lanes: Vec<i32> = (0..gia.count_ones() as i32).collect();
    core.start_update(1, &lanes, 1.0, t0);
    let agg: Vec<i32> = lanes.iter().map(|x| 3 * x).collect();
    let frames = agg_frames(1, &agg, budget);
    assert!(frames.len() >= 2, "test needs a multi-chunk aggregate stream");
    assert!(core.handle(&frames[0], t0).progress.is_none());
    let retx_before = core.stats.retransmissions;
    let out = core.on_tick(t0 + TIMEOUT * 2);
    assert_eq!(kinds(&out), [WireKind::Poll], "re-sync polls for the rest of the sum");
    assert_eq!(core.stats.retransmissions, retx_before);
    assert_eq!(core.stats.quorum_resyncs, 1);
    let mut done = None;
    for f in &frames[1..] {
        if let Some(p) = core.handle(f, t0 + TIMEOUT * 2).progress {
            done = Some(p);
        }
    }
    match done {
        Some(Progress::AggregateReady { round, lanes: got }) => {
            assert_eq!(round, 1);
            assert_eq!(got, agg, "the rejoiner's aggregate is the quorum's, bit-exact");
        }
        other => panic!("expected AggregateReady, got {other:?}"),
    }
}

#[test]
fn pending_stash_overflow_is_counted_not_silent() {
    let t0 = Instant::now();
    let mut core = mk_core(64, 32, 3);
    joined(&mut core, t0);
    let votes = BitVec::from_indices(64, &[0]);
    core.start_vote(1, &votes, 1.0, t0);

    // A babbling server floods this round's *other*-phase broadcast
    // with distinct blocks (dedup only skips exact duplicates). The
    // stash holds 256 and counts the overflow.
    let flood = 300u32;
    for block in 0..flood {
        let f = encode_frame(
            &Header {
                kind: WireKind::Aggregate,
                client: u16::MAX,
                job: JOB,
                round: 1,
                block,
                n_blocks: flood,
                elems: 0,
                aux: 0,
            },
            &[0, 0, 0, 0],
        );
        let out = core.handle(&f, t0);
        assert!(out.progress.is_none(), "sidelined frames never complete the vote wait");
    }
    assert_eq!(core.stats.pending_dropped, 44, "300 stashed − 256 capacity");

    // Exact duplicates are skipped silently — they neither occupy the
    // stash nor count as drops.
    let dup = encode_frame(
        &Header {
            kind: WireKind::Aggregate,
            client: u16::MAX,
            job: JOB,
            round: 1,
            block: 0,
            n_blocks: flood,
            elems: 0,
            aux: 0,
        },
        &[0, 0, 0, 0],
    );
    core.handle(&dup, t0);
    assert_eq!(core.stats.pending_dropped, 44);
}
