//! Queueing-theory validation of the network/switch timing model
//! (§V-A2): the simulated M/G/1 behaviour must match analytic results.

use fediac::configx::PsProfile;
use fediac::net::{pollaczek_khinchine, Mg1Queue, PoissonProcess};
use fediac::switch::ProgrammableSwitch;
use fediac::util::Rng;

/// M/G/1 with Gaussian (truncated) service: sample-path mean wait vs
/// Pollaczek–Khinchine within 10%.
#[test]
fn gaussian_service_matches_pk() {
    let lambda = 60_000.0; // pkts/s
    let mean_s = 1.0e-5;
    let jitter = 2.0e-6;
    let mut rng = Rng::new(42);
    let mut q = Mg1Queue::new();
    let mut proc = PoissonProcess::new(lambda, 0.0);
    let n = 300_000;
    for _ in 0..n {
        let t = proc.next(&mut rng);
        let s = rng.gaussian_pos(mean_s, jitter);
        q.serve(t, s);
    }
    let analytic = pollaczek_khinchine(lambda, mean_s, jitter * jitter).unwrap();
    let sim = q.mean_wait();
    assert!(
        (sim - analytic).abs() / analytic < 0.10,
        "sim {sim:.3e} vs PK {analytic:.3e}"
    );
}

/// The switch's service loop is exactly that M/G/1: empirical mean wait
/// under heavy load matches PK for the high-perf profile.
#[test]
fn switch_queue_matches_pk() {
    let profile = PsProfile::high();
    let lambda = 0.8 / profile.agg_mean_s; // ρ = 0.8
    let mut sw = ProgrammableSwitch::new(profile.clone(), 3);
    let mut rng = Rng::new(4);
    let mut proc = PoissonProcess::new(lambda, 0.0);
    for _ in 0..400_000 {
        let t = proc.next(&mut rng);
        sw.service_packet(t);
    }
    let analytic = pollaczek_khinchine(
        lambda,
        profile.agg_mean_s,
        profile.agg_jitter_s * profile.agg_jitter_s,
    )
    .unwrap();
    let sim = sw.mean_queue_wait();
    assert!(
        (sim - analytic).abs() / analytic < 0.10,
        "sim {sim:.3e} vs PK {analytic:.3e}"
    );
}

/// Utilisation sanity: below saturation the queue drains (departure rate
/// equals arrival rate); above saturation it falls behind.
#[test]
fn saturation_behaviour() {
    let mean_s = 1e-4;
    for (rho, should_keep_up) in [(0.5, true), (2.0, false)] {
        let lambda = rho / mean_s;
        let mut rng = Rng::new(7);
        let mut q = Mg1Queue::new();
        let mut proc = PoissonProcess::new(lambda, 0.0);
        let n = 50_000;
        let mut last_arrival = 0.0;
        for _ in 0..n {
            last_arrival = proc.next(&mut rng);
            q.serve(last_arrival, rng.gaussian_pos(mean_s, mean_s * 0.01));
        }
        let lag = q.next_free() - last_arrival;
        if should_keep_up {
            assert!(lag < 0.05 * last_arrival, "ρ={rho}: lag {lag}");
        } else {
            // Falls behind by ~(ρ−1)/ρ of the horizon.
            assert!(lag > 0.2 * last_arrival, "ρ={rho}: lag {lag}");
        }
    }
}

/// Per-aggregation cost ratio between the two PS profiles is the paper's
/// 10× (3.03e-6 / 3.03e-7) under service-bound load.
#[test]
fn profile_cost_ratio_is_ten_x() {
    let serve_all = |profile: PsProfile| {
        let mut sw = ProgrammableSwitch::new(profile, 11);
        let mut t_done = 0.0;
        for i in 0..100_000 {
            t_done = sw.service_packet(i as f64 * 1e-9);
        }
        t_done
    };
    let high = serve_all(PsProfile::high());
    let low = serve_all(PsProfile::low());
    let ratio = low / high;
    assert!((8.0..12.0).contains(&ratio), "ratio {ratio}");
}
