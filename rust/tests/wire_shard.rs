//! Sharded-wire integration tests: one job's block space split
//! round-robin across N collaborating daemons (PROTOCOL.md §8), proven
//! **bit-exact** against both the single-server wire path and the
//! `num_switches` simulation (`fl::FlEnv::upload_phase_sharded`) — clean
//! and under `net::chaos` in both directions — plus the register-pressure
//! relief the shard plane exists for: at fixed `--memory`, each of N
//! servers must see strictly fewer waves + register stalls than the one
//! server handling the whole model.

use std::time::Duration;

use fediac::algorithms::{common, fediac::FediAc, Algorithm};
use fediac::client::{protocol, ClientOptions, FediacClient, RoundOutcome, ShardedFediacClient};
use fediac::compress::{self, deduce_gia};
use fediac::configx::{DatasetKind, ExperimentConfig, Partition, PsProfile};
use fediac::data::synth;
use fediac::fl::{FlEnv, NativeBackend};
use fediac::net::{ChaosConfig, ChaosDirection};
use fediac::server::{serve_sharded, ServeOptions, ServerHandle};
use fediac::util::{BitVec, Rng};

const N_CLIENTS: usize = 4;

fn make_env(seed: u64, n_switches: usize) -> FlEnv {
    let cfg = ExperimentConfig {
        num_clients: N_CLIENTS,
        num_switches: n_switches,
        seed,
        ..ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid)
    };
    let fd = synth::generate(cfg.dataset, cfg.partition, N_CLIENTS, 40, cfg.seed);
    let backend = Box::new(NativeBackend::new(fd, 16, cfg.local_iters, 8, cfg.seed));
    let mut env = FlEnv::new(cfg, backend);
    env.init_model();
    env
}

/// Everything the wire side needs to replay one in-process FediAC round
/// (the `wire_loopback` recipe, parameterised on `num_switches`).
struct SimRound {
    seed: u64,
    d: usize,
    k: usize,
    threshold_a: u16,
    bits_b: usize,
    updates: Vec<Vec<f32>>,
    params_before: Vec<f32>,
    params_after: Vec<f32>,
}

/// Run bootstrap + round 1 of the simulated FediAC with `n_switches`
/// collaborative PSes and capture the round-1 inputs and ground truth.
fn run_sim_round(seed: u64, n_switches: usize) -> SimRound {
    let mut env = make_env(seed, n_switches);
    let mut alg = FediAc::new(&env.cfg, env.d());
    alg.run_round(&mut env, 0).unwrap();
    let params_before = env.params.clone();
    let bits_b = alg.bits().expect("bootstrap sets b");
    alg.run_round(&mut env, 1).unwrap();
    let params_after = env.params.clone();

    // Twin run stopped after bootstrap to re-derive the round-1 updates
    // (deterministic per seed; post-bootstrap residuals are zero).
    let mut env2 = make_env(seed, n_switches);
    let mut alg2 = FediAc::new(&env2.cfg, env2.d());
    alg2.run_round(&mut env2, 0).unwrap();
    assert_eq!(env2.params, params_before, "twin env diverged in bootstrap");
    let d = env2.d();
    let lr = env2.cfg.lr.at(1) as f32;
    let zero_residuals = vec![vec![0.0f32; d]; N_CLIENTS];
    let local = common::local_training(&mut env2, 1, lr, Some(&zero_residuals));

    SimRound {
        seed,
        d,
        k: protocol::votes_per_client(d, env2.cfg.fediac.k_frac),
        threshold_a: env2.cfg.fediac.threshold_a as u16,
        bits_b,
        updates: local.updates,
        params_before,
        params_after,
    }
}

fn client_opts(server: String, job: u32, id: u16, sim: &SimRound) -> ClientOptions {
    let mut opts = ClientOptions::new(server, job, id, sim.d, N_CLIENTS as u16);
    opts.threshold_a = sim.threshold_a;
    opts.k = sim.k;
    opts.bits_b = sim.bits_b;
    opts.backend_seed = sim.seed;
    opts.payload_budget = 16; // enough vote blocks to split 4 ways
    opts.timeout = Duration::from_millis(300);
    opts.max_retries = 200;
    opts
}

/// Run all clients of one job concurrently against the shard endpoint
/// list (a single endpoint = the plain single-server path) and return
/// their outcomes in client order.
fn run_clients(servers: &[String], job: u32, sim: &SimRound) -> Vec<RoundOutcome> {
    let mut outcomes: Vec<Option<RoundOutcome>> = (0..N_CLIENTS).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (i, slot) in outcomes.iter_mut().enumerate() {
            let update = &sim.updates[i];
            scope.spawn(move || {
                let opts = client_opts(servers[0].clone(), job, i as u16, sim);
                let mut client = ShardedFediacClient::connect(servers, opts).unwrap();
                *slot = Some(client.run_round(1, update).unwrap());
            });
        }
    });
    outcomes.into_iter().map(|o| o.unwrap()).collect()
}

/// The plain single-server wire path (ordinary [`FediacClient`] against
/// one daemon) — the reference the sharded rounds must equal.
fn run_clients_plain(server: &str, job: u32, sim: &SimRound) -> Vec<RoundOutcome> {
    let mut outcomes: Vec<Option<RoundOutcome>> = (0..N_CLIENTS).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (i, slot) in outcomes.iter_mut().enumerate() {
            let update = &sim.updates[i];
            scope.spawn(move || {
                let opts = client_opts(server.to_string(), job, i as u16, sim);
                let mut client = FediacClient::connect(opts).unwrap();
                *slot = Some(client.run_round(1, update).unwrap());
            });
        }
    });
    outcomes.into_iter().map(|o| o.unwrap()).collect()
}

fn endpoints(handles: &[ServerHandle]) -> Vec<String> {
    handles.iter().map(|h| h.local_addr().to_string()).collect()
}

/// The acceptance matrix: for N ∈ {2, 4}, a sharded wire round must be
/// bit-exact against (a) the single-server wire round and (b) the
/// simulated FediAC round configured with `num_switches = N`.
#[test]
fn sharded_wire_matches_single_server_and_simulation_bit_exactly() {
    for n_shards in [2usize, 4] {
        let sim = run_sim_round(7, n_shards);

        let single = serve_sharded(&ServeOptions::default(), 1).unwrap();
        let single_out =
            run_clients_plain(&endpoints(&single)[0], 300 + n_shards as u32, &sim);

        let shards = serve_sharded(&ServeOptions::default(), n_shards as u8).unwrap();
        let sharded_out = run_clients(&endpoints(&shards), 400 + n_shards as u32, &sim);

        for (i, (a, b)) in single_out.iter().zip(&sharded_out).enumerate() {
            assert_eq!(b.gia, a.gia, "N={n_shards} client {i}: GIA differs from single-server");
            assert_eq!(
                b.aggregate, a.aggregate,
                "N={n_shards} client {i}: aggregate differs from single-server"
            );
            assert_eq!(
                b.global_max, a.global_max,
                "N={n_shards} client {i}: folded global max differs"
            );
        }
        // Every client of the sharded job saw the same consensus.
        for o in sharded_out.iter().skip(1) {
            assert_eq!(o.gia, sharded_out[0].gia);
            assert_eq!(o.aggregate, sharded_out[0].aggregate);
        }
        let out = &sharded_out[0];
        assert!(!out.gia_indices.is_empty(), "N={n_shards}: empty consensus");
        assert_eq!(out.global_max, common::global_max_abs(&sim.updates));
        // Applying the sharded wire round to the pre-round model
        // reproduces the `upload_phase_sharded` simulation bit-for-bit.
        let mut params = sim.params_before.clone();
        out.apply(&mut params);
        assert_eq!(
            params, sim.params_after,
            "N={n_shards}: sharded wire round diverged from the num_switches simulation"
        );
        // Each shard server hosted exactly its slice of the round.
        for (s, h) in shards.iter().enumerate() {
            let st = h.stats();
            assert_eq!(st.jobs_created, 1, "N={n_shards} shard {s}");
            assert_eq!(st.rounds_completed, 1, "N={n_shards} shard {s}");
        }
        for h in single {
            h.shutdown();
        }
        for h in shards {
            h.shutdown();
        }
    }
}

/// Deterministic per-(client, round) synthetic update vectors (the
/// `wire_chaos` recipe).
fn synthetic_update(seed: u64, d: usize, client: usize, round: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ (client as u64) << 16 ^ (round as u64) << 40);
    (0..d).map(|_| (rng.gaussian() * 0.02) as f32).collect()
}

/// Clean in-process reference for one round: (gia indices, aggregate).
fn reference_round(
    updates: &[Vec<f32>],
    seed: u64,
    round: usize,
    k: usize,
    a: usize,
    bits_b: usize,
) -> (Vec<usize>, Vec<i32>) {
    let votes: Vec<BitVec> = updates
        .iter()
        .enumerate()
        .map(|(i, u)| protocol::client_vote(u, k, seed, round, i))
        .collect();
    let gia = deduce_gia(&votes, a);
    let indices: Vec<usize> = gia.iter_ones().collect();
    let m = updates.iter().map(|u| compress::max_abs(u)).fold(f32::MIN_POSITIVE, f32::max);
    let f = compress::scale_factor(bits_b, updates.len(), m);
    let mask = gia.to_f32_mask();
    let mut lanes = vec![0i32; indices.len()];
    for (i, u) in updates.iter().enumerate() {
        let (q, _) = protocol::client_quantize(u, &mask, f, seed, round, i);
        for (slot, &g) in indices.iter().enumerate() {
            lanes[slot] += q[g];
        }
    }
    (indices, lanes)
}

/// Chaos matrix for the shard plane: every client↔shard path runs
/// through its own decorrelated in-process chaos proxy (loss, dup,
/// bounded reorder in BOTH directions), multi-round, N ∈ {2, 4} — and
/// the reassembled rounds stay bit-exact.
#[test]
fn sharded_rounds_under_both_direction_chaos_stay_bit_exact() {
    const ROUNDS: usize = 3;
    let d = 640;
    let seed = 41u64;
    let n_clients = 2usize;
    let k = protocol::votes_per_client(d, 0.05);
    for n_shards in [2usize, 4] {
        let shards = serve_sharded(&ServeOptions::default(), n_shards as u8).unwrap();
        let servers = endpoints(&shards);
        std::thread::scope(|scope| {
            for client_id in 0..n_clients {
                let servers = &servers;
                scope.spawn(move || {
                    let mut opts = ClientOptions::new(
                        servers[0].clone(),
                        900 + n_shards as u32,
                        client_id as u16,
                        d,
                        n_clients as u16,
                    );
                    opts.threshold_a = 1;
                    opts.k = k;
                    opts.backend_seed = seed;
                    opts.payload_budget = 16;
                    opts.timeout = Duration::from_millis(150);
                    opts.max_retries = 400;
                    opts.chaos = Some(ChaosConfig::symmetric(
                        57 + client_id as u64,
                        ChaosDirection::lossy(0.20, 0.10, 0.30),
                    ));
                    let mut client = ShardedFediacClient::connect(servers, opts).unwrap();
                    for round in 1..=ROUNDS {
                        let update = synthetic_update(seed, d, client_id, round);
                        let out = client.run_round(round, &update).unwrap();
                        let updates: Vec<Vec<f32>> = (0..n_clients)
                            .map(|c| synthetic_update(seed, d, c, round))
                            .collect();
                        let (ref_idx, ref_lanes) =
                            reference_round(&updates, seed, round, k, 1, 12);
                        assert_eq!(
                            out.gia_indices, ref_idx,
                            "N={n_shards} client {client_id} round {round}: consensus diverged"
                        );
                        assert_eq!(
                            out.aggregate, ref_lanes,
                            "N={n_shards} client {client_id} round {round}: aggregate diverged"
                        );
                    }
                    // The chaos proxies really fired on this client's paths.
                    let touched: u64 = client
                        .shards()
                        .iter()
                        .filter_map(|c| c.chaos_snapshot())
                        .map(|s| {
                            s.up.dropped + s.down.dropped + s.up.reordered + s.down.reordered
                        })
                        .sum();
                    assert!(touched > 0, "N={n_shards} client {client_id}: chaos never fired");
                });
            }
        });
        for (s, h) in shards.iter().enumerate() {
            assert_eq!(
                h.stats().rounds_completed,
                ROUNDS as u64,
                "N={n_shards} shard {s}: rounds did not close under chaos"
            );
        }
        for h in shards {
            h.shutdown();
        }
    }
}

/// The point of the shard plane: per-server register pressure drops. At
/// fixed tiny `--memory`, the one server of an unsharded job processes
/// the whole block space in waves; each of N shard servers owns 1/N of
/// the blocks and must see strictly fewer `waves + register_stalls` —
/// while the aggregation stays bit-exact.
#[test]
fn sharding_relieves_register_pressure_at_fixed_memory() {
    let d = 2048;
    let seed = 61u64;
    let n_clients = 2usize;
    let k = protocol::votes_per_client(d, 0.05);
    let opts = ServeOptions {
        // budget 16 → one 128-dim vote block costs 256 B of counters;
        // 300 B of registers hold exactly one resident block.
        profile: PsProfile { memory_bytes: 300, ..PsProfile::high() },
        ..ServeOptions::default()
    };

    let mut pressure_per_n = Vec::new();
    for n_shards in [1usize, 2, 4] {
        let shards = serve_sharded(&opts, n_shards as u8).unwrap();
        let servers = endpoints(&shards);
        std::thread::scope(|scope| {
            for client_id in 0..n_clients {
                let servers = &servers;
                scope.spawn(move || {
                    let mut copts = ClientOptions::new(
                        servers[0].clone(),
                        700 + n_shards as u32,
                        client_id as u16,
                        d,
                        n_clients as u16,
                    );
                    copts.threshold_a = 1;
                    copts.k = k;
                    copts.backend_seed = seed;
                    copts.payload_budget = 16;
                    copts.timeout = Duration::from_millis(300);
                    copts.max_retries = 200;
                    let mut client = ShardedFediacClient::connect(servers, copts).unwrap();
                    let update = synthetic_update(seed, d, client_id, 1);
                    let out = client.run_round(1, &update).unwrap();
                    let updates: Vec<Vec<f32>> =
                        (0..n_clients).map(|c| synthetic_update(seed, d, c, 1)).collect();
                    let (ref_idx, ref_lanes) = reference_round(&updates, seed, 1, k, 1, 12);
                    assert_eq!(out.gia_indices, ref_idx, "N={n_shards}: consensus diverged");
                    assert_eq!(out.aggregate, ref_lanes, "N={n_shards}: aggregate diverged");
                });
            }
        });
        // Pressure = the busiest server's waves + register stalls.
        let worst = shards
            .iter()
            .map(|h| {
                let st = h.stats();
                st.waves + st.register_stalls
            })
            .max()
            .unwrap();
        pressure_per_n.push((n_shards, worst));
        for h in shards {
            h.shutdown();
        }
    }

    let baseline = pressure_per_n[0].1;
    assert!(
        baseline > 0,
        "unsharded baseline saw no register pressure — the scenario is too easy"
    );
    for &(n_shards, worst) in &pressure_per_n[1..] {
        assert!(
            worst < baseline,
            "N={n_shards}: per-server pressure {worst} not strictly below the \
             single-server baseline {baseline}"
        );
    }
}

/// A shard whose sub-model wins no consensus must still close its round
/// (zero-lane completion block + empty aggregate) while the other shards
/// carry the real payload — the mixed empty/non-empty reassembly path.
#[test]
fn shard_with_empty_consensus_still_closes_the_round() {
    let d = 512;
    let n_clients = 2usize;
    let shards = serve_sharded(&ServeOptions::default(), 2).unwrap();
    let servers = endpoints(&shards);
    let seed = 83u64;

    let mut outcomes: Vec<Option<RoundOutcome>> = (0..n_clients).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (client_id, slot) in outcomes.iter_mut().enumerate() {
            let servers = &servers;
            scope.spawn(move || {
                let mut opts = ClientOptions::new(
                    servers[0].clone(),
                    777,
                    client_id as u16,
                    d,
                    n_clients as u16,
                );
                opts.threshold_a = 1;
                opts.k = 8;
                opts.backend_seed = seed;
                // budget 16 → 128-dim blocks; with 2 shards, shard 0 owns
                // blocks 0 and 2, shard 1 owns blocks 1 and 3.
                opts.payload_budget = 16;
                opts.timeout = Duration::from_millis(300);
                opts.max_retries = 200;
                let mut client = ShardedFediacClient::connect(servers, opts).unwrap();
                // Hot |U| only inside block 0 (dims 0..100): the Gumbel
                // vote scorer (∝ |U|) lands every vote there, so shard 1
                // deduces an empty sub-GIA while shard 0 carries k_S.
                let update: Vec<f32> =
                    (0..d).map(|i| if i < 100 { 1.0 } else { 0.0 }).collect();
                *slot = Some(client.run_round(1, &update).unwrap());
            });
        }
    });
    let out = outcomes[0].take().unwrap();
    assert!(!out.gia_indices.is_empty(), "expected consensus in the hot block");
    assert!(
        out.gia_indices.iter().all(|&g| g < 128),
        "votes leaked outside block 0: {:?}",
        out.gia_indices
    );
    // Both shard servers closed the round — including the empty one.
    for (s, h) in shards.iter().enumerate() {
        assert_eq!(h.stats().rounds_completed, 1, "shard {s} never closed its round");
    }
    // Reference math agrees on the non-empty slice.
    let updates: Vec<Vec<f32>> = (0..n_clients)
        .map(|_| (0..d).map(|i| if i < 100 { 1.0 } else { 0.0 }).collect())
        .collect();
    let (ref_idx, ref_lanes) = reference_round(&updates, seed, 1, 8, 1, 12);
    assert_eq!(out.gia_indices, ref_idx);
    assert_eq!(out.aggregate, ref_lanes);
    for h in shards {
        h.shutdown();
    }
}
