//! Seeded adversarial property tests for the configx TOML layer: no
//! document — however mangled — may panic `configx::toml::parse` or
//! `DeployPreset::parse_str`, and every parse-level rejection must be a
//! `Result` carrying 1-based line context, never a silent default.
//!
//! Same style as `tests/wire_fuzz.rs`: a corpus of *valid* documents
//! (the builtin deployment presets), seeded random mutations (byte
//! flips, truncation, unknown-key injection, type swaps, duplicate
//! keys/sections, garbage splices), deterministic replay via
//! `FEDIAC_PROP_SEED`. Volume scales with `FEDIAC_PROP_CASES`.

use fediac::configx::preset::builtin_text;
use fediac::configx::{toml, DeployPreset, BUILTIN_PRESETS};
use fediac::prop_assert;
use fediac::util::{prop, Rng};

/// A random builtin preset document (always valid as written).
fn pick_corpus(rng: &mut Rng) -> &'static str {
    builtin_text(BUILTIN_PRESETS[rng.below(BUILTIN_PRESETS.len())]).unwrap()
}

/// Keys the preset schema types as numbers (targets for type swaps).
const NUMERIC_KEYS: [&str; 11] = [
    "shards",
    "cores",
    "d",
    "rounds",
    "payload",
    "clients_per_job",
    "host_bytes",
    "quorum",
    "phase_deadline_ms",
    "kill_rate",
    "rejoin_delay_ms",
];

/// Apply one random mutation to `text`, returning the mangled document.
fn mutate(rng: &mut Rng, text: &str) -> String {
    match rng.below(6) {
        // Byte flips (may break UTF-8; lossy-decode like a file read of
        // a corrupted config would).
        0 => {
            let mut bytes = text.as_bytes().to_vec();
            for _ in 0..=rng.below(4) {
                if bytes.is_empty() {
                    break;
                }
                let i = rng.below(bytes.len());
                bytes[i] ^= 1 << rng.below(8);
            }
            String::from_utf8_lossy(&bytes).into_owned()
        }
        // Truncation mid-document (partial write / torn download).
        1 => {
            let cut = rng.below(text.len() + 1);
            text.chars().take(cut).collect()
        }
        // Unknown-key injection into a random line position.
        2 => {
            let mut lines: Vec<&str> = text.lines().collect();
            let at = rng.below(lines.len() + 1);
            lines.insert(at, "definitely_not_a_preset_key = 1");
            lines.join("\n")
        }
        // Type swap on a known numeric key.
        3 => {
            let key = NUMERIC_KEYS[rng.below(NUMERIC_KEYS.len())];
            let mut out = String::new();
            for line in text.lines() {
                if line.trim_start().starts_with(key) && line.contains('=') {
                    out.push_str(&format!("{key} = \"not a number\"\n"));
                } else {
                    out.push_str(line);
                    out.push('\n');
                }
            }
            out
        }
        // Duplicate the whole document after itself: every key now
        // appears twice, which the parser must reject (last-one-wins
        // would silently change deployments).
        4 => format!("{text}\n{text}"),
        // Garbage splice: structured noise that is not key = value.
        _ => {
            let garbage = ["[", "= 3", "a = ", "x = [1, 2", "\"unterminated", "[sec", "a b c"];
            let mut lines: Vec<&str> = text.lines().collect();
            let at = rng.below(lines.len() + 1);
            lines.insert(at, garbage[rng.below(garbage.len())]);
            lines.join("\n")
        }
    }
}

#[test]
fn mutated_preset_documents_never_panic_and_parse_errors_carry_line_context() {
    prop::check("configx_mutation", prop::default_cases() * 8, |rng| {
        let original = pick_corpus(rng);
        let mut text = original.to_string();
        for _ in 0..=rng.below(3) {
            text = mutate(rng, &text);
        }
        // Layer 1: the TOML-subset parser. Must never panic; its only
        // error form carries the 1-based offending line.
        if let Err(e) = toml::parse(&text) {
            let msg = e.to_string();
            prop_assert!(
                msg.starts_with("line "),
                "toml error lost its line context: '{msg}'"
            );
        }
        // Layer 2: the preset schema on top. Must never panic either;
        // Ok or a typed ConfigError are both acceptable outcomes.
        let _ = DeployPreset::parse_str("fuzzed", &text);
        Ok(())
    });
}

#[test]
fn every_truncation_point_of_every_builtin_is_panic_free() {
    for name in BUILTIN_PRESETS {
        let text = builtin_text(name).unwrap();
        for cut in 0..text.len() {
            if !text.is_char_boundary(cut) {
                continue;
            }
            let truncated = &text[..cut];
            if let Err(e) = toml::parse(truncated) {
                let msg = e.to_string();
                assert!(
                    msg.starts_with("line "),
                    "{name} truncated at {cut}: error lost line context: '{msg}'"
                );
            }
            let _ = DeployPreset::parse_str(name, truncated);
        }
    }
}

#[test]
fn unknown_keys_are_rejected_not_defaulted() {
    prop::check("configx_unknown_key", prop::default_cases(), |rng| {
        let original = pick_corpus(rng);
        let mut lines: Vec<&str> = original.lines().collect();
        let at = rng.below(lines.len() + 1);
        lines.insert(at, "zzz_injected_key = 42");
        let text = lines.join("\n");
        let res = DeployPreset::parse_str("fuzzed", &text);
        prop_assert!(
            res.is_err(),
            "injected unknown key at line {} was silently accepted",
            at + 1
        );
        let msg = res.unwrap_err().to_string();
        prop_assert!(
            msg.contains("zzz_injected_key") || msg.starts_with("line "),
            "rejection names neither the key nor a line: '{msg}'"
        );
        Ok(())
    });
}

#[test]
fn type_mismatches_on_real_keys_are_errors_not_defaults() {
    prop::check("configx_type_swap", prop::default_cases(), |rng| {
        let original = pick_corpus(rng);
        let key = NUMERIC_KEYS[rng.below(NUMERIC_KEYS.len())];
        if !original.lines().any(|l| l.trim_start().starts_with(key) && l.contains('=')) {
            return Ok(()); // this preset doesn't set the key
        }
        let swapped: String = original
            .lines()
            .map(|line| {
                if line.trim_start().starts_with(key) && line.contains('=') {
                    format!("{key} = \"not a number\"\n")
                } else {
                    format!("{line}\n")
                }
            })
            .collect();
        let res = DeployPreset::parse_str("fuzzed", &swapped);
        prop_assert!(res.is_err(), "string value for numeric '{key}' was accepted");
        Ok(())
    });
}

#[test]
fn duplicate_keys_and_reopened_sections_are_rejected_with_line_context() {
    for name in BUILTIN_PRESETS {
        let text = builtin_text(name).unwrap();
        let doubled = format!("{text}\n{text}");
        let err = toml::parse(&doubled)
            .expect_err("doubled document must trip the duplicate-key check");
        let msg = err.to_string();
        assert!(
            msg.starts_with("line ") && msg.contains("duplicate key"),
            "{name}: expected a line-numbered duplicate-key error, got '{msg}'"
        );
    }
}

#[test]
fn all_builtin_presets_survive_the_fuzzer_untouched() {
    // The corpus itself must stay valid — a mutation test over broken
    // inputs proves nothing.
    for name in BUILTIN_PRESETS {
        let preset = DeployPreset::parse_str(name, builtin_text(name).unwrap())
            .unwrap_or_else(|e| panic!("builtin '{name}' no longer parses: {e}"));
        assert_eq!(preset.name, *name);
    }
}
