//! Cross-module integration tests: the full native-backend stack from
//! config to recorded runs, exercising every algorithm and the paper's
//! qualitative claims at miniature scale.

use fediac::configx::{
    AlgorithmKind, DatasetKind, ExperimentConfig, Partition, PsProfile,
};
use fediac::experiments::{run, RunOptions, Scale};

fn cfg(alg: AlgorithmKind, dataset: DatasetKind, partition: Partition) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(dataset, partition);
    cfg.algorithm = alg;
    cfg.num_clients = 6;
    cfg.rounds = 10;
    cfg.samples_per_client = 60;
    cfg.fediac.threshold_a = 2;
    cfg
}

#[test]
fn fediac_learns_on_every_dataset() {
    for dataset in [
        DatasetKind::Tiny,
        DatasetKind::SynthCifar10,
        DatasetKind::SynthFemnist,
    ] {
        let partition = if dataset == DatasetKind::SynthFemnist {
            Partition::Natural
        } else {
            Partition::Iid
        };
        let rec = run(&cfg(AlgorithmKind::FediAc, dataset, partition), &RunOptions::default())
            .unwrap();
        let first = rec.records.first().unwrap().test_accuracy.unwrap();
        let best = rec.best_accuracy().unwrap();
        // Either clear improvement, or the task was already at ceiling
        // after the bootstrap round (easy synthetic split).
        assert!(
            best > first + 0.05 || best > 0.9,
            "{dataset:?}: no learning ({first:.3} → {best:.3})"
        );
    }
}

#[test]
fn fediac_beats_baselines_on_traffic_at_equal_rounds() {
    // The core claim behind Tables I/II: per round, FediAC moves far less
    // data than SwitchML (dense) and OmniReduce (block-amplified Topk).
    let mut totals = std::collections::BTreeMap::new();
    for alg in [
        AlgorithmKind::FediAc,
        AlgorithmKind::SwitchMl,
        AlgorithmKind::OmniReduce,
    ] {
        let rec = run(
            &cfg(alg, DatasetKind::SynthCifar10, Partition::Iid),
            &RunOptions::default(),
        )
        .unwrap();
        totals.insert(alg.name(), rec.total_traffic().total());
    }
    let fediac = totals["fediac"];
    assert!(
        fediac < totals["switchml"],
        "fediac {fediac} !< switchml {}",
        totals["switchml"]
    );
    assert!(
        fediac < totals["omnireduce"],
        "fediac {fediac} !< omnireduce {}",
        totals["omnireduce"]
    );
}

#[test]
fn low_ps_rounds_take_longer_than_high_ps() {
    let mut base = cfg(AlgorithmKind::SwitchMl, DatasetKind::SynthCifar10, Partition::Iid);
    base.rounds = 3;
    let t_high = run(&base, &RunOptions::default()).unwrap().final_time();
    base.ps = PsProfile::low();
    let t_low = run(&base, &RunOptions::default()).unwrap().final_time();
    assert!(
        t_low > t_high,
        "low-perf PS should be slower: {t_low:.3} !> {t_high:.3}"
    );
}

#[test]
fn noniid_does_not_beat_iid() {
    let iid = run(
        &cfg(AlgorithmKind::FediAc, DatasetKind::SynthCifar10, Partition::Iid),
        &RunOptions::default(),
    )
    .unwrap()
    .best_accuracy()
    .unwrap();
    let mut noniid_cfg = cfg(
        AlgorithmKind::FediAc,
        DatasetKind::SynthCifar10,
        Partition::Dirichlet(0.1),
    );
    noniid_cfg.fediac.threshold_a = 3;
    let noniid = run(&noniid_cfg, &RunOptions::default()).unwrap().best_accuracy().unwrap();
    assert!(
        iid >= noniid - 0.02,
        "strong skew should not beat IID: iid {iid:.3} vs β=0.1 {noniid:.3}"
    );
}

#[test]
fn switch_stats_accumulate_only_for_in_network_algorithms() {
    let rec_fediac = run(
        &cfg(AlgorithmKind::FediAc, DatasetKind::Tiny, Partition::Iid),
        &RunOptions::default(),
    )
    .unwrap();
    let ops: u64 = rec_fediac.records.iter().map(|r| r.agg_ops).sum();
    assert!(ops > 0);
    let rec_avg = run(
        &cfg(AlgorithmKind::FedAvg, DatasetKind::Tiny, Partition::Iid),
        &RunOptions::default(),
    )
    .unwrap();
    let ops: u64 = rec_avg.records.iter().map(|r| r.agg_ops).sum();
    assert_eq!(ops, 0);
}

#[test]
fn scale_apply_keeps_threshold_proportional() {
    let mut cfg = ExperimentConfig::preset(DatasetKind::SynthCifar10, Partition::Iid);
    assert_eq!(cfg.fediac.threshold_a, 3); // 15% of 20
    let scale = Scale { num_clients: 40, ..Scale::quick() };
    scale.apply(&mut cfg);
    assert_eq!(cfg.fediac.threshold_a, 6); // 15% of 40
    cfg.validate().unwrap();
}

#[test]
fn csv_outputs_parse_back() {
    let rec = run(
        &cfg(AlgorithmKind::FediAc, DatasetKind::Tiny, Partition::Iid),
        &RunOptions::default(),
    )
    .unwrap();
    let csv = rec.to_csv();
    let lines: Vec<&str> = csv.trim().lines().collect();
    assert_eq!(lines.len(), rec.records.len() + 1);
    for line in &lines[1..] {
        assert_eq!(line.split(',').count(), 10, "bad row: {line}");
    }
}

#[test]
fn rle_mode_changes_no_accuracy_only_traffic() {
    let mut a = cfg(AlgorithmKind::FediAc, DatasetKind::Tiny, Partition::Iid);
    a.fediac.k_frac = 0.01;
    let plain = run(&a, &RunOptions::default()).unwrap();
    a.fediac.rle_phase1 = true;
    let rle = run(&a, &RunOptions::default()).unwrap();
    // Same votes/GIA → identical accuracy trajectory; RLE only shrinks
    // the phase-1 wire bytes.
    for (x, y) in plain.records.iter().zip(&rle.records) {
        assert_eq!(x.test_accuracy, y.test_accuracy);
    }
    assert!(rle.total_traffic().vote_up_bytes <= plain.total_traffic().vote_up_bytes);
}
