//! End-to-end PJRT tests: the artifact bundle (L2 JAX model + L1 Pallas
//! kernels) executed through the rust runtime, composed with the full
//! FediAC protocol. Skipped (cleanly) when `make artifacts` has not run.

use fediac::configx::{
    AlgorithmKind, BackendKind, DatasetKind, ExperimentConfig, Partition,
};
use fediac::data::synth;
use fediac::experiments::{run, RunOptions};
use fediac::fl::ModelBackend;
use fediac::runtime::{artifacts_available, Manifest, PjrtBackend};

const DIR: &str = "artifacts";

fn skip() -> bool {
    if !artifacts_available(DIR) {
        eprintln!("skipping PJRT e2e test: no artifacts/ bundle (run `make artifacts`)");
        return true;
    }
    false
}

fn tiny_backend(seed: u64) -> PjrtBackend {
    let manifest = Manifest::load(DIR).unwrap();
    let entry = manifest.model("tiny").unwrap();
    let n = 4;
    let data = synth::generate(DatasetKind::Tiny, Partition::Iid, n, 60, seed);
    assert_eq!(entry.feature_len(), data.train.feature_len());
    PjrtBackend::load(DIR, "tiny", data, seed).unwrap()
}

#[test]
fn pjrt_init_is_deterministic_and_sized() {
    if skip() {
        return;
    }
    let mut b = tiny_backend(5);
    let p1 = b.init_params();
    let p2 = b.init_params();
    assert_eq!(p1.len(), b.d());
    assert_eq!(p1, p2);
    assert!(p1.iter().any(|&x| x != 0.0));
}

#[test]
fn pjrt_train_step_reduces_loss() {
    if skip() {
        return;
    }
    let mut b = tiny_backend(6);
    let mut params = b.init_params();
    let mut first = None;
    let mut last = 0.0;
    for round in 0..8 {
        let out = b.local_train(&params, 0, round, 0.05);
        params = out.new_params;
        if first.is_none() {
            first = Some(out.mean_loss);
        }
        last = out.mean_loss;
    }
    assert!(last < first.unwrap(), "PJRT training no signal: {first:?} → {last}");
}

#[test]
fn pjrt_compress_matches_rust_semantics() {
    if skip() {
        return;
    }
    // The Pallas kernel must satisfy the same protocol invariants as the
    // rust mirror: masked lanes zero, residual identity, determinism.
    let mut b = tiny_backend(7);
    let d = b.d();
    let updates: Vec<f32> = (0..d).map(|i| ((i % 13) as f32 - 6.0) * 0.01).collect();
    let gia: Vec<f32> = (0..d).map(|i| if i % 3 == 0 { 1.0 } else { 0.0 }).collect();
    let f = 512.0f32;
    let (q1, e1) = b.compress(&updates, &gia, f, 99);
    let (q2, e2) = b.compress(&updates, &gia, f, 99);
    assert_eq!(q1, q2, "kernel must be deterministic per seed");
    assert_eq!(e1, e2);
    let (q3, _) = b.compress(&updates, &gia, f, 100);
    assert_ne!(q1, q3, "different seeds must differ");
    for l in 0..d {
        if gia[l] == 0.0 {
            assert_eq!(q1[l], 0, "masked lane {l} leaked");
            assert!((e1[l] - updates[l]).abs() < 1e-6);
        } else {
            let lhs = q1[l] as f64 + f as f64 * e1[l] as f64;
            let rhs = f as f64 * updates[l] as f64;
            assert!((lhs - rhs).abs() < 1e-2, "lane {l}: {lhs} vs {rhs}");
        }
    }
}

#[test]
fn pjrt_vote_scores_prefer_magnitude() {
    if skip() {
        return;
    }
    let mut b = tiny_backend(8);
    let d = b.d();
    let mut updates = vec![1e-4f32; d];
    for u in updates.iter_mut().take(20) {
        *u = 5.0;
    }
    let mut hits = vec![0usize; d];
    for seed in 0..30 {
        let scores = b.vote_scores(&updates, seed);
        let top = fediac::compress::top_k_indices(&scores, 40);
        for i in top {
            hits[i] += 1;
        }
    }
    let dominant: usize = hits[..20].iter().sum();
    assert!(dominant >= 20 * 28, "dominant dims voted only {dominant}/600");
}

#[test]
fn pjrt_full_fediac_run() {
    if skip() {
        return;
    }
    // The E10 composition at test scale: FediAC + PJRT + switch + queues.
    let mut cfg = ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid);
    cfg.algorithm = AlgorithmKind::FediAc;
    cfg.backend = BackendKind::Pjrt;
    cfg.num_clients = 4;
    cfg.rounds = 6;
    cfg.samples_per_client = 60;
    cfg.fediac.threshold_a = 2;
    let rec = run(&cfg, &RunOptions { eval_every: 1, ..Default::default() }).unwrap();
    assert_eq!(rec.records.len(), 6);
    let first = rec.records.first().unwrap().test_accuracy.unwrap();
    let best = rec.best_accuracy().unwrap();
    assert!(best > first, "PJRT e2e no learning: {first:.3} → {best:.3}");
    assert!(rec.total_traffic().total() > 0);
}
