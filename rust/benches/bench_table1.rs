//! E2 regenerator: Table I (traffic to target accuracy, high-perf PS)
//! at bench scale.

mod harness;

use fediac::configx::PsProfile;
use fediac::experiments::{tables, RunOptions, Scale};
use harness::time_once;

fn main() {
    let scale = Scale {
        rounds: std::env::var("FEDIAC_BENCH_ROUNDS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(24),
        num_clients: 10,
        samples_per_client: 80,
        eval_every: 2,
        ..Scale::quick()
    };
    let opts = RunOptions::default();
    println!("# bench_table1 — E2 regenerator: Table I, high-performance PS");
    let mut rows = Vec::new();
    for (dataset, partition, target) in tables::scenarios() {
        let label = format!("table1 {}_{}", dataset.name(), partition.name());
        rows.push(time_once(&label, || {
            tables::run_row(dataset, partition, target, PsProfile::high(), &scale, &opts)
                .unwrap()
        }));
    }
    println!("{}", tables::render(&rows, "high"));
}
