//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! * consensus threshold a (a=1 ⇒ union/no-consensus … a=N ⇒ intersection)
//! * quantisation bits b (vs the Corollary-1 auto choice)
//! * phase-1 RLE on/off (§IV-D)
//! * uplink loss rate (end-host retransmission cost)
//!
//! Each row reports final accuracy, total traffic and simulated time at
//! a fixed round budget so the knobs are directly comparable.

mod harness;

use fediac::configx::{AlgorithmKind, DatasetKind, ExperimentConfig, Partition};
use fediac::experiments::{run, RunOptions, Scale};
use harness::time_once;

fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset(DatasetKind::SynthCifar10, Partition::Iid);
    let scale = Scale {
        rounds: std::env::var("FEDIAC_BENCH_ROUNDS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(14),
        num_clients: 10,
        samples_per_client: 80,
        ..Scale::quick()
    };
    scale.apply(&mut cfg);
    cfg.algorithm = AlgorithmKind::FediAc;
    cfg
}

fn report(label: &str, cfg: &ExperimentConfig) {
    let rec = time_once(label, || run(cfg, &RunOptions::default()).unwrap());
    println!(
        "  {:<28} acc={:.4} traffic={:>8.2} MB sim_time={:>8.2} s (vote share {:.1}%)",
        label,
        rec.best_accuracy().unwrap_or(0.0),
        rec.total_traffic().total_mb(),
        rec.final_time(),
        100.0 * (rec.total_traffic().vote_up_bytes + rec.total_traffic().vote_down_bytes)
            as f64
            / rec.total_traffic().total().max(1) as f64,
    );
}

fn main() {
    println!("# bench_ablation — FediAC design-choice ablations\n");

    println!("## consensus threshold a (N=10; a=1 ⇒ no consensus, union)");
    for a in [1usize, 2, 3, 5, 8] {
        let mut cfg = base_cfg();
        cfg.fediac.threshold_a = a;
        report(&format!("a={a}"), &cfg);
    }

    println!("\n## quantisation bits b (auto = Corollary 1)");
    {
        let cfg = base_cfg();
        report("b=auto(cor.1)", &cfg);
    }
    for b in [8usize, 10, 12, 16] {
        let mut cfg = base_cfg();
        cfg.fediac.bits_b = Some(b);
        report(&format!("b={b}"), &cfg);
    }

    println!("\n## phase-1 run-length encoding (§IV-D)");
    for (rle, label) in [(false, "rle=off"), (true, "rle=on")] {
        let mut cfg = base_cfg();
        cfg.fediac.rle_phase1 = rle;
        cfg.fediac.k_frac = 0.02; // sparse votes where RLE pays off
        report(label, &cfg);
    }

    println!("\n## uplink loss rate (retransmission cost)");
    for loss in [0.0, 0.01, 0.05, 0.2] {
        let mut cfg = base_cfg();
        cfg.loss_rate = loss;
        report(&format!("loss={loss}"), &cfg);
    }

    println!("\n## multiple collaborative PSes (§VI future work; low-perf PS)");
    for s in [1usize, 2, 4] {
        let mut cfg = base_cfg();
        cfg.ps = fediac::configx::PsProfile::low();
        cfg.num_switches = s;
        report(&format!("switches={s}"), &cfg);
    }

    println!("\n## vote budget k (fraction of d)");
    for k_frac in [0.01, 0.05, 0.15] {
        let mut cfg = base_cfg();
        cfg.fediac.k_frac = k_frac;
        report(&format!("k={:.0}%d", k_frac * 100.0), &cfg);
    }
}
