//! End-to-end round timing: the L3 wall-clock cost of one FediAC global
//! iteration (native backend) broken down by stage. This is the primary
//! before/after probe for the §Perf optimisation pass.

mod harness;

use fediac::algorithms::make_algorithm;
use fediac::configx::{AlgorithmKind, DatasetKind, ExperimentConfig, Partition};
use fediac::experiments::{build_env, RunOptions};
use fediac::fl::ModelBackend;
use harness::{bench, black_box};

fn main() {
    println!("# bench_round — wall-clock cost of one global iteration (native)");
    let mut cfg = ExperimentConfig::preset(DatasetKind::SynthCifar10, Partition::Iid);
    cfg.algorithm = AlgorithmKind::FediAc;
    cfg.num_clients = 10;
    cfg.rounds = 4;
    cfg.samples_per_client = 100;
    let opts = RunOptions { native_hidden: 64, ..Default::default() };
    let mut env = build_env(&cfg, &opts).unwrap();
    let d = env.d();
    println!("model d = {d}, N = {}", cfg.num_clients);

    // Stage: one client's local training (E=5 SGD iterations).
    let params = env.backend.init_params();
    let s = bench("local_train (1 client, E=5, B=16)", 2, 30, || {
        black_box(env.backend.local_train(&params, 0, 1, 0.05));
    });
    s.print_throughput((5 * 16 * d) as f64, "param-samples");

    // Stage: full-test-set evaluation.
    bench("evaluate (512 test samples)", 1, 10, || {
        black_box(env.backend.evaluate(&params));
    });

    // Stage: full FediAC round (training + vote + GIA + compress + sim).
    let mut env2 = build_env(&cfg, &opts).unwrap();
    let mut alg = make_algorithm(&cfg, env2.d());
    alg.run_round(&mut env2, 0).unwrap(); // bootstrap outside the timer
    let mut round = 1usize;
    bench("fediac full round (N=10)", 1, 12, || {
        black_box(alg.run_round(&mut env2, round).unwrap());
        round += 1;
    });

    // Stage: switchml full round for comparison (dense path).
    let mut env3 = build_env(
        &ExperimentConfig { algorithm: AlgorithmKind::SwitchMl, ..cfg.clone() },
        &opts,
    )
    .unwrap();
    let mut alg3 = make_algorithm(&env3.cfg.clone(), env3.d());
    let mut round3 = 0usize;
    bench("switchml full round (N=10)", 1, 12, || {
        black_box(alg3.run_round(&mut env3, round3).unwrap());
        round3 += 1;
    });
}
