//! E8 + client-side hot-path micro-benchmarks: quantise/sparsify, vote
//! scoring, top-k selection and the §IV-D phase-1 RLE study.

mod harness;

use fediac::compress::{self, rle};
use fediac::util::{BitVec, Rng};
use harness::{bench, black_box};

fn main() {
    println!("# bench_compress — client compression hot path + E8 RLE study");
    let d = 200_000;
    let mut rng = Rng::new(3);
    let updates: Vec<f32> = (0..d).map(|_| (rng.gaussian() * 0.05) as f32).collect();
    let mask: Vec<f32> = (0..d).map(|i| if i % 7 == 0 { 1.0 } else { 0.0 }).collect();
    let f = compress::scale_factor(12, 20, compress::max_abs(&updates));

    let mut qrng = Rng::new(4);
    let s = bench("quantize_sparsify (d=200k)", 5, 60, || {
        black_box(compress::quantize_sparsify(&updates, &mask, f, &mut qrng));
    });
    s.print_throughput(d as f64, "elems");

    let mut vrng = Rng::new(5);
    let s = bench("vote_scores_native (d=200k)", 5, 60, || {
        black_box(compress::vote_scores_native(&updates, &mut vrng));
    });
    s.print_throughput(d as f64, "elems");

    let scores = compress::vote_scores_native(&updates, &mut vrng);
    let s = bench("top_k_indices (d=200k, k=10k)", 5, 60, || {
        black_box(compress::top_k_indices(&scores, 10_000));
    });
    s.print_throughput(d as f64, "elems");

    let s = bench("topk_mask (d=200k, k=2k)", 5, 60, || {
        black_box(compress::topk_mask(&updates, 2_000));
    });
    s.print_throughput(d as f64, "elems");

    // E8: phase-1 index-array sizes, raw bitmap vs RLE vs Golomb–Rice.
    println!("\n# E8 (§IV-D): phase-1 index-array bytes by coding scheme");
    println!("density\traw_bytes\trle_bytes\tgolomb_bytes\tbest");
    for density_pct in [1usize, 5, 10, 25, 50] {
        let k = d * density_pct / 100;
        let mut idx: Vec<usize> = (0..d).collect();
        rng.shuffle(&mut idx);
        let bv = BitVec::from_indices(d, &idx[..k]);
        let raw = bv.payload_bytes();
        let enc = rle::encoded_bytes(&bv);
        let gol = fediac::compress::golomb::encoded_bytes(&bv);
        let best = if raw <= enc && raw <= gol {
            "raw"
        } else if gol <= enc {
            "golomb"
        } else {
            "rle"
        };
        println!("{density_pct}%\t{raw}\t{enc}\t{gol}\t{best}");
    }
    let sparse =
        BitVec::from_indices(d, &(0..d / 100).map(|i| i * 97 % d).collect::<Vec<_>>());
    let s = bench("rle::encode (d=200k, 1% density)", 10, 100, || {
        black_box(rle::encode(&sparse));
    });
    s.print_throughput(d as f64, "bits");
    let encoded = rle::encode(&sparse);
    let s = bench("rle::decode (same)", 10, 100, || {
        black_box(rle::decode(&encoded));
    });
    s.print_throughput(d as f64, "bits");
}
