//! E5 regenerator: Fig. 4 (FediAC accuracy vs voting threshold a across
//! system scales) at bench scale.

mod harness;

use fediac::configx::Partition;
use fediac::experiments::{fig4, RunOptions, Scale};
use harness::time_once;

fn main() {
    let scale = Scale {
        rounds: std::env::var("FEDIAC_BENCH_ROUNDS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(12),
        samples_per_client: 80,
        eval_every: 3,
        ..Scale::quick()
    };
    let opts = RunOptions::default();
    let clients = [8usize, 12, 16];
    println!("# bench_fig4 — E5 regenerator: voting-threshold sweep");
    for (partition, label) in
        [(Partition::Iid, "iid"), (Partition::Dirichlet(0.5), "non-iid")]
    {
        let res = time_once(&format!("fig4 {label}"), || {
            fig4::run_sweep(partition, &clients, &scale, &opts).unwrap()
        });
        println!("{}", fig4::render(&res, label));
    }
}
