//! E1 regenerator: Fig. 2 (accuracy vs wall-clock, all algorithms) at
//! bench scale. Override via env: FEDIAC_BENCH_ROUNDS, FEDIAC_BENCH_N.

mod harness;

use fediac::configx::{DatasetKind, Partition, PsProfile};
use fediac::experiments::{fig2, RunOptions, Scale};
use harness::time_once;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let scale = Scale {
        rounds: env_usize("FEDIAC_BENCH_ROUNDS", 16),
        num_clients: env_usize("FEDIAC_BENCH_N", 10),
        samples_per_client: 80,
        eval_every: 2,
        ..Scale::quick()
    };
    let opts = RunOptions { verbose: false, ..Default::default() };
    println!("# bench_fig2 — E1 regenerator (scaled; see EXPERIMENTS.md)");
    for (dataset, partition) in [
        (DatasetKind::SynthCifar10, Partition::Iid),
        (DatasetKind::SynthCifar10, Partition::Dirichlet(0.5)),
        (DatasetKind::SynthFemnist, Partition::Natural),
    ] {
        for ps in [PsProfile::high(), PsProfile::low()] {
            let label = format!(
                "fig2 {} {} {}ps",
                dataset.name(),
                partition.name(),
                ps.name
            );
            let panel = time_once(&label, || {
                fig2::run_panel(dataset, partition, ps.clone(), &scale, &opts).unwrap()
            });
            for (alg, acc) in fig2::final_accuracies(&panel) {
                let rec = &panel.runs.iter().find(|(a, _)| *a == alg).unwrap().1;
                println!(
                    "  {:<12} final_acc={:.4} sim_time={:>8.2}s traffic={:>8.2} MB",
                    alg.name(),
                    acc,
                    rec.final_time(),
                    rec.total_traffic().total_mb()
                );
            }
        }
    }
}
