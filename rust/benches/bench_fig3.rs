//! E4 regenerator: Fig. 3 (accuracy vs Dirichlet β, FediAC vs libra)
//! at bench scale.

mod harness;

use fediac::configx::PsProfile;
use fediac::experiments::{fig3, RunOptions, Scale};
use harness::time_once;

fn main() {
    let scale = Scale {
        rounds: std::env::var("FEDIAC_BENCH_ROUNDS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(16),
        num_clients: 10,
        samples_per_client: 80,
        eval_every: 2,
        ..Scale::quick()
    };
    let opts = RunOptions::default();
    println!("# bench_fig3 — E4 regenerator: non-IID robustness sweep");
    for ps in [PsProfile::high(), PsProfile::low()] {
        let res = time_once(&format!("fig3 {}ps", ps.name), || {
            fig3::run_sweep(ps.clone(), &scale, &opts, &fig3::BETAS).unwrap()
        });
        println!("{}", fig3::render(&res, &ps.name));
    }
}
