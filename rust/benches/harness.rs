//! Shared timing harness for the bench binaries (criterion is not in the
//! offline vendor set). Measures wall time over warmup + measured
//! iterations and reports min/median/mean/p95 like criterion's summary.

use std::time::Instant;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

impl BenchStats {
    pub fn print(&self) {
        println!(
            "{:<44} median {:>10}  mean {:>10}  min {:>10}  p95 {:>10}  (n={})",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.min_ns),
            fmt_ns(self.p95_ns),
            self.iters
        );
    }

    /// Derived throughput given items processed per iteration.
    pub fn print_throughput(&self, items_per_iter: f64, unit: &str) {
        let per_sec = items_per_iter / (self.median_ns / 1e9);
        println!(
            "{:<44} {:>14.3e} {unit}/s (median)",
            format!("{} [throughput]", self.name),
            per_sec
        );
    }
}

/// Run `f` `iters` times after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = BenchStats {
        name: name.to_string(),
        iters,
        min_ns: samples[0],
        median_ns: samples[samples.len() / 2],
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
        p95_ns: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
    };
    stats.print();
    stats
}

/// Time a single long-running closure (for end-to-end regenerators).
pub fn time_once<T, F: FnOnce() -> T>(name: &str, f: F) -> T {
    let t0 = Instant::now();
    let out = f();
    println!("{:<44} completed in {:.2} s", name, t0.elapsed().as_secs_f64());
    out
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
