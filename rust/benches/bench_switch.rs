//! E9: programmable-switch micro-benchmarks — the L3 hot path.
//!
//! Measures the data-plane primitives every round exercises: vote-bit
//! accumulation, i32 lane accumulation, GIA thresholding and the M/G/1
//! service loop. Throughputs here bound the simulated switch's packets/s;
//! see EXPERIMENTS.md §Perf.

mod harness;

use fediac::configx::PsProfile;
use fediac::switch::{alu, ProgrammableSwitch, RegisterFile, UpdateAggregator, VoteAggregator};
use fediac::util::{BitVec, Rng};
use harness::{bench, black_box};

fn main() {
    println!("# bench_switch — PS data-plane micro-benchmarks (E9)");
    let payload = 1438usize;

    // Vote-bit accumulation: one packet's worth of bits into u16 counters.
    let epb = payload * 8;
    let mut counters = vec![0u16; epb];
    let mut rng = Rng::new(1);
    let mut bits = vec![0u8; payload];
    bits.iter_mut().for_each(|b| *b = (rng.next_u32() & 0xFF) as u8);
    let s = bench("alu::add_vote_bits (1 pkt, 11504 dims)", 50, 400, || {
        alu::add_vote_bits(black_box(&mut counters), black_box(&bits));
    });
    s.print_throughput(epb as f64, "dims");

    // i32 lane accumulation: one packet of 359 int lanes.
    let lanes = payload / 4;
    let mut acc = vec![0i32; lanes];
    let payload_ints: Vec<i32> = (0..lanes).map(|i| i as i32 - 100).collect();
    let s = bench("alu::add_i32_sat (1 pkt, 359 lanes)", 200, 2000, || {
        black_box(alu::add_i32_sat(black_box(&mut acc), black_box(&payload_ints)));
    });
    s.print_throughput(lanes as f64, "lanes");

    // GIA threshold over a full model's counters.
    let d = 200_000;
    let mut big_counters = vec![0u16; d];
    for (i, c) in big_counters.iter_mut().enumerate() {
        *c = (i % 7) as u16;
    }
    let mut gia_bytes = vec![0u8; d.div_ceil(8)];
    let s = bench("alu::threshold_votes (d=200k)", 10, 200, || {
        alu::threshold_votes(black_box(&big_counters), 3, black_box(&mut gia_bytes));
    });
    s.print_throughput(d as f64, "dims");

    // Full VoteAggregator round: N=20 clients × full bitmap.
    let d = 100_000;
    let n = 20;
    let votes: Vec<Vec<u8>> = (0..n)
        .map(|i| {
            let mut r = Rng::new(100 + i as u64);
            let mut idx: Vec<usize> = (0..d).collect();
            r.shuffle(&mut idx);
            BitVec::from_indices(d, &idx[..d / 20]).to_bytes()
        })
        .collect();
    let n_blocks = d.div_ceil(epb);
    let s = bench("VoteAggregator full round (d=100k, N=20)", 3, 30, || {
        let mut rf = RegisterFile::new(d * 2);
        let mut agg = VoteAggregator::new(&mut rf, d, n, 3, epb).unwrap();
        for (client, bytes) in votes.iter().enumerate() {
            for block in 0..n_blocks {
                let lo = block * payload;
                let hi = ((block + 1) * payload).min(bytes.len());
                agg.ingest(client, block, &bytes[lo..hi]);
            }
        }
        black_box(agg.gia());
        agg.release(&mut rf);
    });
    s.print_throughput((n * d) as f64, "votes");

    // Full UpdateAggregator round: N=20 clients × k_s ints.
    let k_s: usize = 20_000;
    let epb_upd = payload * 8 / 12;
    let q: Vec<i32> = (0..k_s).map(|i| (i as i32 % 401) - 200).collect();
    let blocks = k_s.div_ceil(epb_upd);
    let s = bench("UpdateAggregator full round (k_s=20k, N=20)", 5, 50, || {
        let mut rf = RegisterFile::new(k_s * 4);
        let mut agg = UpdateAggregator::new(&mut rf, k_s, n, epb_upd).unwrap();
        for client in 0..n {
            for block in 0..blocks {
                let lo = block * epb_upd;
                let hi = ((block + 1) * epb_upd).min(k_s);
                agg.ingest(client, block, &q[lo..hi]);
            }
        }
        black_box(agg.aggregate()[0]);
        agg.release(&mut rf);
    });
    s.print_throughput((n * k_s) as f64, "ints");

    // Service loop: 10k packets through the M/G/1 queue.
    let s = bench("ProgrammableSwitch::service_packet ×10k", 3, 50, || {
        let mut sw = ProgrammableSwitch::new(PsProfile::high(), 7);
        let mut t = 0.0;
        for i in 0..10_000 {
            t = sw.service_packet(i as f64 * 1e-6);
        }
        black_box(t);
    });
    s.print_throughput(10_000.0, "pkts");
}
