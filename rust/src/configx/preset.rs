//! Deployment presets: one TOML document describes a whole deployment
//! scenario — shards, I/O backend, switch profile, [`JobLimits`],
//! chaos profile and client mix — so `fediac serve|shard-serve|swarm
//! --preset datacenter` replaces a paragraph of flags. CLI flags still
//! win over preset values (the subcommands overlay them afterwards).
//!
//! Four builtin presets ship inside the binary via `include_str!`
//! (the CrabFetch/chabeau pattern); `--preset PATH.toml` loads a
//! user-supplied file through the same strict parser. Unlike the
//! lenient [`ExperimentConfig`] overlay, preset parsing is *strict*:
//! unknown keys, type mismatches and out-of-range values are errors,
//! because presets feed the daemon's admission limits.
//!
//! Presets are hosting-side configuration only — nothing here is
//! wire-visible (PROTOCOL.md §10).
//!
//! [`ExperimentConfig`]: crate::configx::ExperimentConfig
//! [`JobLimits`]: crate::server::JobLimits

use std::time::Duration;

use crate::configx::toml::{self, Table, Value};
use crate::configx::{ConfigError, PsProfile};
use crate::net::{ChaosDirection, ChurnConfig};
use crate::server::JobLimits;

/// Names of the presets compiled into the binary, in listing order.
pub const BUILTIN_PRESETS: [&str; 4] = ["datacenter", "edge", "adversarial", "paper"];

/// The TOML source of a builtin preset, `None` for unknown names.
/// Exposed so the config fuzzer can mutate real preset documents.
pub fn builtin_text(name: &str) -> Option<&'static str> {
    Some(match name {
        "datacenter" => include_str!("presets/datacenter.toml"),
        "edge" => include_str!("presets/edge.toml"),
        "adversarial" => include_str!("presets/adversarial.toml"),
        "paper" => include_str!("presets/paper.toml"),
        _ => return None,
    })
}

/// One direction's packet-chaos knobs as plain preset data
/// (mirrors [`ChaosDirection`], with the hold expressed in ms).
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosKnobs {
    /// Probability a datagram is dropped.
    pub drop: f64,
    /// Probability a datagram is duplicated.
    pub duplicate: f64,
    /// Probability a datagram is held back for reordering.
    pub reorder: f64,
    /// Probability a datagram is bit-corrupted.
    pub corrupt: f64,
    /// Held-datagram queue depth for reordering.
    pub reorder_depth: usize,
    /// Longest a held datagram may wait, in milliseconds.
    pub max_hold_ms: u64,
}

impl Default for ChaosKnobs {
    fn default() -> Self {
        let d = ChaosDirection::default();
        ChaosKnobs {
            drop: 0.0,
            duplicate: 0.0,
            reorder: 0.0,
            corrupt: 0.0,
            reorder_depth: d.reorder_depth,
            max_hold_ms: d.max_hold.as_millis() as u64,
        }
    }
}

impl ChaosKnobs {
    /// True when every fault probability is zero.
    pub fn is_clean(&self) -> bool {
        self.direction().is_clean()
    }

    /// Convert to the runtime [`ChaosDirection`].
    pub fn direction(&self) -> ChaosDirection {
        ChaosDirection {
            drop: self.drop,
            duplicate: self.duplicate,
            reorder: self.reorder,
            corrupt: self.corrupt,
            reorder_depth: self.reorder_depth,
            max_hold: Duration::from_millis(self.max_hold_ms),
        }
    }

    fn from_table(t: &Table, prefix: &str) -> Result<Self, ConfigError> {
        let d = ChaosKnobs::default();
        let knobs = ChaosKnobs {
            drop: get_f64(t, &format!("{prefix}.drop"), d.drop)?,
            duplicate: get_f64(t, &format!("{prefix}.duplicate"), d.duplicate)?,
            reorder: get_f64(t, &format!("{prefix}.reorder"), d.reorder)?,
            corrupt: get_f64(t, &format!("{prefix}.corrupt"), d.corrupt)?,
            reorder_depth: get_usize(t, &format!("{prefix}.depth"), d.reorder_depth)?,
            max_hold_ms: get_u64(t, &format!("{prefix}.hold_ms"), d.max_hold_ms)?,
        };
        for (key, p) in [
            ("drop", knobs.drop),
            ("duplicate", knobs.duplicate),
            ("reorder", knobs.reorder),
            ("corrupt", knobs.corrupt),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(ConfigError::Invalid(format!(
                    "preset key '{prefix}.{key}' must be a probability in [0, 1], got {p}"
                )));
            }
        }
        Ok(knobs)
    }
}

/// Per-job admission limits as plain preset data (mirrors
/// [`JobLimits`], with the deadlines expressed in ms).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PresetLimits {
    /// Host bytes one job may pin across its live rounds.
    pub host_bytes: usize,
    /// Spilled payload bytes one phase of one round may hold.
    pub spill_bytes: usize,
    /// Idle-round register reclamation deadline, in milliseconds.
    pub idle_release_ms: u64,
    /// Full re-serves allowed per source address per round.
    pub reserve_budget: u32,
    /// Quorum-round phase deadline, in milliseconds (PROTOCOL.md §11;
    /// inert while `mix.quorum` is 0).
    pub phase_deadline_ms: u64,
}

impl Default for PresetLimits {
    fn default() -> Self {
        let d = JobLimits::default();
        PresetLimits {
            host_bytes: d.host_bytes,
            spill_bytes: d.spill_bytes,
            idle_release_ms: d.idle_release_after.as_millis() as u64,
            reserve_budget: d.reserve_budget,
            phase_deadline_ms: d.phase_deadline.as_millis() as u64,
        }
    }
}

impl PresetLimits {
    /// Convert to the runtime [`JobLimits`].
    pub fn limits(&self) -> JobLimits {
        JobLimits {
            host_bytes: self.host_bytes,
            spill_bytes: self.spill_bytes,
            idle_release_after: Duration::from_millis(self.idle_release_ms),
            reserve_budget: self.reserve_budget,
            phase_deadline: Duration::from_millis(self.phase_deadline_ms),
        }
    }

    fn from_table(t: &Table) -> Result<Self, ConfigError> {
        let d = PresetLimits::default();
        let limits = PresetLimits {
            host_bytes: get_usize(t, "limits.host_bytes", d.host_bytes)?,
            spill_bytes: get_usize(t, "limits.spill_bytes", d.spill_bytes)?,
            idle_release_ms: get_u64(t, "limits.idle_release_ms", d.idle_release_ms)?,
            reserve_budget: u32::try_from(get_usize(
                t,
                "limits.reserve_budget",
                d.reserve_budget as usize,
            )?)
            .map_err(|_| {
                ConfigError::Invalid("preset key 'limits.reserve_budget' out of range".into())
            })?,
            phase_deadline_ms: get_u64(t, "limits.phase_deadline_ms", d.phase_deadline_ms)?,
        };
        if limits.phase_deadline_ms == 0 {
            return Err(ConfigError::Invalid(
                "preset key 'limits.phase_deadline_ms' must be >= 1".into(),
            ));
        }
        Ok(limits)
    }
}

/// Client-churn plane knobs as plain preset data (mirrors
/// [`ChurnConfig`], with the rejoin delay expressed in ms). `Default`
/// is a quiet plane.
#[derive(Debug, Clone, PartialEq)]
pub struct ChurnKnobs {
    /// Probability a client is killed at any given round start.
    pub kill_rate: f64,
    /// Dark time before a corpse rejoins / a flash-crowd client's join
    /// delay, in milliseconds (0 = every kill is permanent).
    pub rejoin_delay_ms: u64,
    /// Clients (highest ids) whose first Join is delayed.
    pub flash_crowd: u16,
}

impl Default for ChurnKnobs {
    fn default() -> Self {
        let d = ChurnConfig::default();
        ChurnKnobs {
            kill_rate: d.kill_rate,
            rejoin_delay_ms: d.rejoin_delay.as_millis() as u64,
            flash_crowd: d.flash_crowd,
        }
    }
}

impl ChurnKnobs {
    /// True when the plane would touch nobody.
    pub fn is_quiet(&self) -> bool {
        !self.config().enabled()
    }

    /// Convert to the runtime [`ChurnConfig`] (permanence rate keeps
    /// its builtin default — it is not a preset knob).
    pub fn config(&self) -> ChurnConfig {
        ChurnConfig {
            kill_rate: self.kill_rate,
            rejoin_delay: Duration::from_millis(self.rejoin_delay_ms),
            flash_crowd: self.flash_crowd,
            ..ChurnConfig::default()
        }
    }

    fn from_table(t: &Table) -> Result<Self, ConfigError> {
        let d = ChurnKnobs::default();
        let knobs = ChurnKnobs {
            kill_rate: get_f64(t, "churn.kill_rate", d.kill_rate)?,
            rejoin_delay_ms: get_u64(t, "churn.rejoin_delay_ms", d.rejoin_delay_ms)?,
            flash_crowd: get_u16(t, "churn.flash_crowd", d.flash_crowd)?,
        };
        if !(0.0..=1.0).contains(&knobs.kill_rate) {
            return Err(ConfigError::Invalid(format!(
                "preset key 'churn.kill_rate' must be a probability in [0, 1], got {}",
                knobs.kill_rate
            )));
        }
        Ok(knobs)
    }
}

/// The client-fleet shape a preset drives (used by `fediac soak` and as
/// `fediac swarm` defaults; `serve`/`shard-serve` ignore it).
#[derive(Debug, Clone, PartialEq)]
pub struct PresetMix {
    /// Concurrent tenant jobs.
    pub jobs: usize,
    /// Clients per job (the protocol's N).
    pub clients_per_job: u16,
    /// Model dimension d.
    pub d: usize,
    /// FediAC rounds per episode.
    pub rounds: usize,
    /// Per-frame payload budget in bytes.
    pub payload: usize,
    /// Vote fraction k/d.
    pub k_frac: f64,
    /// Consensus vote threshold a.
    pub threshold_a: u16,
    /// Quantisation bit width b.
    pub bits_b: usize,
    /// Client retransmission timeout, in milliseconds.
    pub timeout_ms: u64,
    /// Client retransmission budget per phase.
    pub max_retries: usize,
    /// Host the fleet on the one-thread swarm multiplexer.
    pub swarm: bool,
    /// Total swarm clients (split into jobs of `clients_per_job`).
    pub swarm_clients: usize,
    /// Sockets the swarm spreads jobs over (1..=8).
    pub swarm_sockets: usize,
    /// Quorum Q per job (0 = legacy all-N rounds; PROTOCOL.md §11).
    pub quorum: u16,
}

impl Default for PresetMix {
    fn default() -> Self {
        PresetMix {
            jobs: 2,
            clients_per_job: 3,
            d: 4096,
            rounds: 3,
            payload: crate::wire::DEFAULT_PAYLOAD_BUDGET,
            k_frac: 0.05,
            threshold_a: 2,
            bits_b: 12,
            timeout_ms: 200,
            max_retries: 50,
            swarm: false,
            swarm_clients: 128,
            swarm_sockets: crate::client::swarm::MAX_SWARM_SOCKETS,
            quorum: 0,
        }
    }
}

impl PresetMix {
    fn from_table(t: &Table) -> Result<Self, ConfigError> {
        let d = PresetMix::default();
        let mix = PresetMix {
            jobs: get_usize(t, "mix.jobs", d.jobs)?,
            clients_per_job: get_u16(t, "mix.clients_per_job", d.clients_per_job)?,
            d: get_usize(t, "mix.d", d.d)?,
            rounds: get_usize(t, "mix.rounds", d.rounds)?,
            payload: get_usize(t, "mix.payload", d.payload)?,
            k_frac: get_f64(t, "mix.k_frac", d.k_frac)?,
            threshold_a: get_u16(t, "mix.threshold_a", d.threshold_a)?,
            bits_b: get_usize(t, "mix.bits_b", d.bits_b)?,
            timeout_ms: get_u64(t, "mix.timeout_ms", d.timeout_ms)?,
            max_retries: get_usize(t, "mix.max_retries", d.max_retries)?,
            swarm: get_bool(t, "mix.swarm", d.swarm)?,
            swarm_clients: get_usize(t, "mix.swarm_clients", d.swarm_clients)?,
            swarm_sockets: get_usize(t, "mix.swarm_sockets", d.swarm_sockets)?,
            quorum: get_u16(t, "mix.quorum", d.quorum)?,
        };
        mix.validate()?;
        Ok(mix)
    }

    fn validate(&self) -> Result<(), ConfigError> {
        let bad = |msg: String| Err(ConfigError::Invalid(msg));
        if self.jobs == 0 || self.rounds == 0 || self.d == 0 {
            return bad("preset mix: jobs, rounds and d must all be >= 1".into());
        }
        if self.clients_per_job == 0 {
            return bad("preset key 'mix.clients_per_job' must be >= 1".into());
        }
        if self.threshold_a == 0 || self.threshold_a > self.clients_per_job {
            return bad(format!(
                "preset key 'mix.threshold_a' must be in [1, clients_per_job={}]",
                self.clients_per_job
            ));
        }
        if !(2..=31).contains(&self.bits_b) {
            return bad("preset key 'mix.bits_b' must be in [2, 31]".into());
        }
        if !(0.0..=1.0).contains(&self.k_frac) || self.k_frac == 0.0 {
            return bad("preset key 'mix.k_frac' must be in (0, 1]".into());
        }
        if !(64..=crate::wire::MAX_WIRE_PAYLOAD).contains(&self.payload) {
            return bad(format!(
                "preset key 'mix.payload' must be in [64, {}]",
                crate::wire::MAX_WIRE_PAYLOAD
            ));
        }
        if !(1..=crate::client::swarm::MAX_SWARM_SOCKETS).contains(&self.swarm_sockets) {
            return bad(format!(
                "preset key 'mix.swarm_sockets' must be in [1, {}]",
                crate::client::swarm::MAX_SWARM_SOCKETS
            ));
        }
        if self.swarm && self.swarm_clients == 0 {
            return bad("preset key 'mix.swarm_clients' must be >= 1".into());
        }
        if self.quorum > self.clients_per_job {
            return bad(format!(
                "preset key 'mix.quorum' must be in [0, clients_per_job={}]",
                self.clients_per_job
            ));
        }
        Ok(())
    }
}

/// A complete parsed deployment scenario. See the module docs for the
/// TOML grammar; every field has a default, so `{}` is a valid (if
/// boring) preset.
#[derive(Debug, Clone, PartialEq)]
pub struct DeployPreset {
    /// Preset name (defaults to the `--preset` argument).
    pub name: String,
    /// One-line human description.
    pub summary: String,
    /// I/O backend name: `threaded`, `reactor` or `fleet`.
    pub io: String,
    /// Shard daemons to run (1 = single server).
    pub shards: u8,
    /// Reactor cores for the `fleet` backend (0 = auto-size to the
    /// host). Ignored by the single-socket backends.
    pub cores: usize,
    /// Switch profile name: `high` or `low`.
    pub profile: String,
    /// Register-memory override in bytes (None = profile default).
    pub memory_bytes: Option<usize>,
    /// Per-job admission limits.
    pub limits: PresetLimits,
    /// Chaos lane seed.
    pub chaos_seed: u64,
    /// Uplink (client → server) chaos knobs.
    pub up: ChaosKnobs,
    /// Downlink (server → client) chaos knobs.
    pub down: ChaosKnobs,
    /// Client-fleet shape for soak/swarm.
    pub mix: PresetMix,
    /// Client-churn plane knobs (quiet by default).
    pub churn: ChurnKnobs,
}

/// Every dotted key a preset document may contain; anything else is a
/// hard error (presets feed admission limits — typos must not pass).
const ALLOWED_KEYS: &[&str] = &[
    "name",
    "summary",
    "deploy.io",
    "deploy.shards",
    "deploy.cores",
    "deploy.profile",
    "deploy.memory",
    "limits.host_bytes",
    "limits.spill_bytes",
    "limits.idle_release_ms",
    "limits.reserve_budget",
    "limits.phase_deadline_ms",
    "chaos.seed",
    "chaos.up.drop",
    "chaos.up.duplicate",
    "chaos.up.reorder",
    "chaos.up.corrupt",
    "chaos.up.depth",
    "chaos.up.hold_ms",
    "chaos.down.drop",
    "chaos.down.duplicate",
    "chaos.down.reorder",
    "chaos.down.corrupt",
    "chaos.down.depth",
    "chaos.down.hold_ms",
    "mix.jobs",
    "mix.clients_per_job",
    "mix.d",
    "mix.rounds",
    "mix.payload",
    "mix.k_frac",
    "mix.threshold_a",
    "mix.bits_b",
    "mix.timeout_ms",
    "mix.max_retries",
    "mix.swarm",
    "mix.swarm_clients",
    "mix.swarm_sockets",
    "mix.quorum",
    "churn.kill_rate",
    "churn.rejoin_delay_ms",
    "churn.flash_crowd",
];

impl DeployPreset {
    /// Parse a preset document; `name_hint` names the preset when the
    /// document has no `name` key (and in error messages).
    pub fn parse_str(name_hint: &str, text: &str) -> Result<Self, ConfigError> {
        let t = toml::parse(text)?;
        DeployPreset::from_table(name_hint, &t)
    }

    /// Build a preset from an already-parsed table, strictly: unknown
    /// keys, type mismatches and out-of-range values are all errors.
    pub fn from_table(name_hint: &str, t: &Table) -> Result<Self, ConfigError> {
        for key in t.entries.keys() {
            if !ALLOWED_KEYS.contains(&key.as_str()) {
                return Err(ConfigError::Unknown {
                    field: "preset key",
                    value: key.clone(),
                });
            }
        }
        let io = get_str(t, "deploy.io", "threaded")?;
        if crate::server::IoBackend::parse(&io).is_none() {
            return Err(ConfigError::Invalid(format!(
                "preset key 'deploy.io' must be threaded|reactor|fleet, got '{io}'"
            )));
        }
        let profile = get_str(t, "deploy.profile", "high")?;
        if PsProfile::parse(&profile).is_none() {
            return Err(ConfigError::Invalid(format!(
                "preset key 'deploy.profile' must be high|low, got '{profile}'"
            )));
        }
        let shards = get_usize(t, "deploy.shards", 1)?;
        if !(1..=16).contains(&shards) {
            return Err(ConfigError::Invalid(format!(
                "preset key 'deploy.shards' must be in [1, 16], got {shards}"
            )));
        }
        // 0 = auto-size; explicit counts are bounded by the fleet cap.
        let cores = get_usize(t, "deploy.cores", 0)?;
        if cores > crate::server::fleet::MAX_FLEET_CORES {
            return Err(ConfigError::Invalid(format!(
                "preset key 'deploy.cores' must be in [0, {}], got {cores}",
                crate::server::fleet::MAX_FLEET_CORES
            )));
        }
        let memory_bytes = match t.get("deploy.memory") {
            None => None,
            Some(_) => Some(get_usize(t, "deploy.memory", 0)?),
        };
        let preset = DeployPreset {
            name: get_str(t, "name", name_hint)?,
            summary: get_str(t, "summary", "")?,
            io,
            shards: shards as u8,
            cores,
            profile,
            memory_bytes,
            limits: PresetLimits::from_table(t)?,
            chaos_seed: get_u64(t, "chaos.seed", 0)?,
            up: ChaosKnobs::from_table(t, "chaos.up")?,
            down: ChaosKnobs::from_table(t, "chaos.down")?,
            mix: PresetMix::from_table(t)?,
            churn: ChurnKnobs::from_table(t)?,
        };
        // A churn plane that kills clients needs quorum rounds to keep
        // closing; legacy all-N rounds would stall on the first corpse.
        if preset.churn.kill_rate > 0.0 && preset.mix.quorum == 0 {
            return Err(ConfigError::Invalid(
                "preset churn: 'churn.kill_rate' > 0 requires 'mix.quorum' >= 1 \
                 (all-N rounds cannot close without every client)"
                    .into(),
            ));
        }
        // A sharded deployment needs every shard to own at least one
        // vote block, or the fan-out client has idle shards.
        let vote_blocks = preset.mix.d.div_ceil(8 * preset.mix.payload);
        if vote_blocks < preset.shards as usize {
            return Err(ConfigError::Invalid(format!(
                "preset mix: d={} at payload={} yields {} vote block(s) < {} shards",
                preset.mix.d, preset.mix.payload, vote_blocks, preset.shards
            )));
        }
        Ok(preset)
    }

    /// The switch profile with any `deploy.memory` override applied.
    pub fn ps_profile(&self) -> PsProfile {
        // Name validity was checked in from_table.
        let mut p = PsProfile::parse(&self.profile).unwrap_or_else(PsProfile::high);
        if let Some(m) = self.memory_bytes {
            p.memory_bytes = m;
        }
        p
    }

    /// True when neither chaos direction injects faults.
    pub fn is_clean(&self) -> bool {
        self.up.is_clean() && self.down.is_clean()
    }
}

/// Resolve `--preset NAME`: a builtin name, else a TOML file path.
pub fn load_preset(name: &str) -> Result<DeployPreset, ConfigError> {
    if let Some(text) = builtin_text(name) {
        return DeployPreset::parse_str(name, text);
    }
    if std::path::Path::new(name).is_file() {
        let text = std::fs::read_to_string(name)?;
        let stem = std::path::Path::new(name)
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or(name)
            .to_string();
        return DeployPreset::parse_str(&stem, &text);
    }
    Err(ConfigError::Invalid(format!(
        "unknown preset '{name}' (builtins: {}; or a .toml file path)",
        BUILTIN_PRESETS.join(", ")
    )))
}

// ---- strict typed getters ----------------------------------------------
//
// `Table`'s `*_or` helpers silently fall back to the default on a type
// mismatch, which is right for the lenient experiment overlay and wrong
// here: a preset author who writes `shards = "2"` must hear about it.

fn type_err(key: &str, want: &str, got: &Value) -> ConfigError {
    let found = match got {
        Value::Str(_) => "string",
        Value::Int(_) => "integer",
        Value::Float(_) => "float",
        Value::Bool(_) => "bool",
        Value::Array(_) => "array",
    };
    ConfigError::Invalid(format!("preset key '{key}' must be a {want}, got a {found}"))
}

fn get_str(t: &Table, key: &str, default: &str) -> Result<String, ConfigError> {
    match t.get(key) {
        None => Ok(default.to_string()),
        Some(Value::Str(s)) => Ok(s.clone()),
        Some(other) => Err(type_err(key, "string", other)),
    }
}

fn get_f64(t: &Table, key: &str, default: f64) -> Result<f64, ConfigError> {
    match t.get(key) {
        None => Ok(default),
        Some(v) => v.as_f64().ok_or_else(|| type_err(key, "number", v)),
    }
}

fn get_i64(t: &Table, key: &str) -> Result<Option<i64>, ConfigError> {
    match t.get(key) {
        None => Ok(None),
        Some(Value::Int(i)) => Ok(Some(*i)),
        Some(other) => Err(type_err(key, "integer", other)),
    }
}

fn get_usize(t: &Table, key: &str, default: usize) -> Result<usize, ConfigError> {
    match get_i64(t, key)? {
        None => Ok(default),
        Some(i) => usize::try_from(i).map_err(|_| {
            ConfigError::Invalid(format!("preset key '{key}' must be a non-negative integer"))
        }),
    }
}

fn get_u64(t: &Table, key: &str, default: u64) -> Result<u64, ConfigError> {
    match get_i64(t, key)? {
        None => Ok(default),
        Some(i) => u64::try_from(i).map_err(|_| {
            ConfigError::Invalid(format!("preset key '{key}' must be a non-negative integer"))
        }),
    }
}

fn get_u16(t: &Table, key: &str, default: u16) -> Result<u16, ConfigError> {
    match get_i64(t, key)? {
        None => Ok(default),
        Some(i) => u16::try_from(i).map_err(|_| {
            ConfigError::Invalid(format!("preset key '{key}' must be in [0, 65535]"))
        }),
    }
}

fn get_bool(t: &Table, key: &str, default: bool) -> Result<bool, ConfigError> {
    match t.get(key) {
        None => Ok(default),
        Some(Value::Bool(b)) => Ok(*b),
        Some(other) => Err(type_err(key, "bool", other)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_builtin_parses_and_validates() {
        for name in BUILTIN_PRESETS {
            let text = builtin_text(name).expect("builtin text");
            let p = DeployPreset::parse_str(name, text)
                .unwrap_or_else(|e| panic!("builtin preset '{name}': {e}"));
            assert_eq!(p.name, name, "builtin '{name}' must self-name");
            assert!(!p.summary.is_empty(), "builtin '{name}' needs a summary");
            // Runtime conversions must hold for every builtin.
            let _ = p.ps_profile();
            let _ = p.limits.limits();
            let _ = (p.up.direction(), p.down.direction());
        }
    }

    #[test]
    fn builtins_cover_the_scenario_matrix() {
        let by_name = |n: &str| load_preset(n).unwrap();
        let dc = by_name("datacenter");
        assert_eq!(dc.io, "fleet", "datacenter must exercise the multi-core fleet");
        assert_eq!(dc.cores, 2, "datacenter pins a reproducible fleet size");
        assert!(dc.shards >= 2, "datacenter must exercise the shard plane");
        assert!(dc.is_clean());
        let edge = by_name("edge");
        assert_eq!(edge.shards, 1);
        assert!(!edge.is_clean(), "edge must inject light chaos");
        assert!(edge.mix.swarm, "edge hosts its fleet on the swarm");
        let adv = by_name("adversarial");
        assert!(adv.down.corrupt > 0.0 || adv.up.corrupt > 0.0);
        assert!(adv.memory_bytes.unwrap() < 4096, "adversarial starves registers");
        assert!(!adv.churn.is_quiet(), "adversarial must run the churn plane");
        assert!(adv.mix.quorum >= 1, "churned rounds need a quorum to close");
        assert!(adv.mix.quorum <= adv.mix.clients_per_job);
        assert!(
            adv.limits.limits().phase_deadline < adv.limits.limits().idle_release_after,
            "phase deadline must close rounds before the idle reaper fires"
        );
        let paper = by_name("paper");
        assert_eq!(paper.mix.clients_per_job, 20, "paper §V-A uses N=20");
        assert_eq!(paper.mix.threshold_a, 3);
        assert_eq!(paper.mix.bits_b, 12);
    }

    #[test]
    fn unknown_keys_are_rejected() {
        let err = DeployPreset::parse_str("x", "shardz = 2\n").unwrap_err();
        assert!(err.to_string().contains("shardz"), "{err}");
        let err = DeployPreset::parse_str("x", "[deploy]\nio = \"reactor\"\ntypo = 1\n")
            .unwrap_err();
        assert!(err.to_string().contains("deploy.typo"), "{err}");
    }

    #[test]
    fn type_and_range_mismatches_are_errors_not_defaults() {
        let cases = [
            "[deploy]\nshards = \"2\"\n",
            "[deploy]\nio = 3\n",
            "[deploy]\nio = \"uring\"\n",
            "[deploy]\nshards = 0\n",
            "[deploy]\nshards = 17\n",
            "[deploy]\ncores = \"2\"\n",
            "[deploy]\ncores = 17\n",
            "[deploy]\ncores = -1\n",
            "[chaos.up]\ndrop = 1.5\n",
            "[chaos.down]\ncorrupt = -0.1\n",
            "[mix]\nbits_b = 1\n",
            "[mix]\nthreshold_a = 9\nclients_per_job = 4\n",
            "[mix]\nk_frac = 0.0\n",
            "[mix]\npayload = 7\n",
            "[mix]\nswarm_sockets = 9\n",
            "[limits]\nhost_bytes = -1\n",
            "[limits]\nphase_deadline_ms = 0\n",
            "[mix]\nquorum = 4\nclients_per_job = 3\n",
            "[churn]\nkill_rate = 1.5\n",
            "[churn]\nkill_rate = -0.1\n",
            "[churn]\nkill_rate = 0.2\n", // kills without a quorum stall all-N rounds
            "[churn]\nrejoin_delay_ms = -5\n",
        ];
        for doc in cases {
            assert!(
                DeployPreset::parse_str("x", doc).is_err(),
                "expected rejection of {doc:?}"
            );
        }
    }

    #[test]
    fn sharded_preset_must_give_every_shard_a_vote_block() {
        // d=1024 at payload=1408 is a single vote block — 2 shards can't
        // both own work, so the preset is rejected up front.
        let doc = "[deploy]\nshards = 2\n[mix]\nd = 1024\npayload = 1408\n";
        let err = DeployPreset::parse_str("x", doc).unwrap_err();
        assert!(err.to_string().contains("vote block"), "{err}");
    }

    #[test]
    fn load_preset_falls_back_to_file_paths() {
        let err = load_preset("no-such-preset").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("datacenter"), "{msg}");
        let dir = std::env::temp_dir().join("fediac_preset_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mini.toml");
        std::fs::write(&path, "summary = \"file preset\"\n[deploy]\nio = \"reactor\"\n")
            .unwrap();
        let p = load_preset(path.to_str().unwrap()).unwrap();
        assert_eq!(p.name, "mini");
        assert_eq!(p.io, "reactor");
        std::fs::remove_file(&path).ok();
    }
}
