//! Minimal TOML-subset parser (serde/toml crates unavailable offline).
//!
//! Supported grammar — everything the experiment configs need:
//!
//! ```toml
//! # comment
//! key = "string"            [section]
//! key = 3.14                [section.subsection]
//! key = 42                  key = [1, 2, 3]
//! key = true
//! ```
//!
//! Values are stored flat under dotted keys (`section.sub.key`). No
//! multi-line strings, datetimes or inline tables — configs stay simple.

use std::collections::BTreeMap;

/// Parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// Integer literal.
    Int(i64),
    /// Float literal.
    Float(f64),
    /// `true` / `false`.
    Bool(bool),
    /// `[v, v, …]` of any supported scalars.
    Array(Vec<Value>),
}

impl Value {
    /// The string form, if this is a [`Value::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric form (ints widen to f64).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// Integer form, if this is a [`Value::Int`].
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Boolean form, if this is a [`Value::Bool`].
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse failure with its 1-based line number.
#[derive(Debug, thiserror::Error)]
pub enum TomlError {
    /// Malformed line: (line number, description).
    #[error("line {0}: {1}")]
    Parse(usize, String),
}

/// Flat dotted-key map of parsed values.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Values keyed by dotted path, e.g. `fediac.threshold_a`.
    pub entries: BTreeMap<String, Value>,
}

impl Table {
    /// Look a dotted key up.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    /// String at `key`, or `default`.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default).to_string()
    }

    /// Float (or widened int) at `key`, or `default`.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    /// Unsigned integer at `key`, or `default`.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_i64()).map(|i| i as usize).unwrap_or(default)
    }

    /// u64 at `key`, or `default`.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.as_i64()).map(|i| i as u64).unwrap_or(default)
    }

    /// Bool at `key`, or `default`.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Insert/overwrite a dotted key.
    pub fn set(&mut self, key: &str, value: Value) {
        self.entries.insert(key.to_string(), value);
    }
}

fn parse_scalar(raw: &str, line_no: usize) -> Result<Value, TomlError> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err(TomlError::Parse(line_no, "empty value".into()));
    }
    if let Some(stripped) = raw.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| TomlError::Parse(line_no, "unterminated string".into()))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if raw == "true" {
        return Ok(Value::Bool(true));
    }
    if raw == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = raw.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| TomlError::Parse(line_no, "unterminated array".into()))?;
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_scalar(part, line_no)?);
            }
        }
        return Ok(Value::Array(items));
    }
    if !raw.contains('.') && !raw.contains('e') && !raw.contains('E') {
        if let Ok(i) = raw.parse::<i64>() {
            return Ok(Value::Int(i));
        }
    }
    raw.parse::<f64>()
        .map(Value::Float)
        .map_err(|_| TomlError::Parse(line_no, format!("cannot parse value '{raw}'")))
}

/// Parse a TOML-subset document into a flat dotted-key table.
pub fn parse(text: &str) -> Result<Table, TomlError> {
    let mut table = Table::default();
    let mut section = String::new();
    for (idx, raw_line) in text.lines().enumerate() {
        let line_no = idx + 1;
        // Strip comments that are not inside a string literal.
        let mut in_str = false;
        let mut line = String::new();
        for c in raw_line.chars() {
            if c == '"' {
                in_str = !in_str;
            }
            if c == '#' && !in_str {
                break;
            }
            line.push(c);
        }
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let name = inner
                .strip_suffix(']')
                .ok_or_else(|| TomlError::Parse(line_no, "unterminated section".into()))?;
            section = name.trim().to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| TomlError::Parse(line_no, "expected key = value".into()))?;
        let key = key.trim();
        if key.is_empty() {
            return Err(TomlError::Parse(line_no, "empty key".into()));
        }
        let full_key = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        let parsed = parse_scalar(value, line_no)?;
        if table.entries.insert(full_key.clone(), parsed).is_some() {
            return Err(TomlError::Parse(line_no, format!("duplicate key '{full_key}'")));
        }
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = r#"
            # experiment
            name = "cifar10_iid"
            rounds = 60
            lr = 0.1

            [ps]
            profile = "high"
            agg_mean_s = 3.03e-7
            pipelined = true

            [fediac]
            k_frac = 0.05
            thresholds = [1, 2, 3, 4]
        "#;
        let t = parse(doc).unwrap();
        assert_eq!(t.str_or("name", ""), "cifar10_iid");
        assert_eq!(t.usize_or("rounds", 0), 60);
        assert!((t.f64_or("lr", 0.0) - 0.1).abs() < 1e-12);
        assert_eq!(t.str_or("ps.profile", ""), "high");
        assert!((t.f64_or("ps.agg_mean_s", 0.0) - 3.03e-7).abs() < 1e-18);
        assert!(t.bool_or("ps.pipelined", false));
        match t.get("fediac.thresholds").unwrap() {
            Value::Array(items) => {
                let v: Vec<i64> = items.iter().map(|i| i.as_i64().unwrap()).collect();
                assert_eq!(v, vec![1, 2, 3, 4]);
            }
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn comments_and_hash_in_string() {
        let t = parse("label = \"a#b\"  # trailing\n").unwrap();
        assert_eq!(t.str_or("label", ""), "a#b");
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("x = \n").unwrap_err();
        assert!(err.to_string().contains("line 1"));
        let err = parse("\n\nnonsense\n").unwrap_err();
        assert!(err.to_string().contains("line 3"));
    }

    #[test]
    fn defaults_when_missing() {
        let t = parse("").unwrap();
        assert_eq!(t.usize_or("rounds", 7), 7);
        assert_eq!(t.str_or("x", "d"), "d");
        assert!(!t.bool_or("b", false));
    }

    #[test]
    fn duplicate_keys_are_rejected_with_line_context() {
        // Same bare key twice.
        let err = parse("a = 1\na = 2\n").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 2"), "{msg}");
        assert!(msg.contains("duplicate key 'a'"), "{msg}");
        // Same dotted key reached through a re-opened section.
        let err = parse("[s]\nk = 1\n[s]\nk = 2\n").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("line 4"), "{msg}");
        assert!(msg.contains("duplicate key 's.k'"), "{msg}");
    }

    #[test]
    fn int_vs_float_distinction() {
        let t = parse("a = 3\nb = 3.0\nc = 1e-3\n").unwrap();
        assert_eq!(t.get("a"), Some(&Value::Int(3)));
        assert_eq!(t.get("b"), Some(&Value::Float(3.0)));
        assert_eq!(t.get("c"), Some(&Value::Float(1e-3)));
    }
}
