//! Experiment configuration: typed structs + TOML-subset loader + presets.
//!
//! Every experiment (figures 2–4, tables I–II, examples, benches) is fully
//! described by an [`ExperimentConfig`]; presets reproduce the paper's
//! §V-A settings and can be overridden from TOML files or CLI flags.

pub mod preset;
pub mod toml;

pub use preset::{
    load_preset, ChaosKnobs, ChurnKnobs, DeployPreset, PresetLimits, PresetMix, BUILTIN_PRESETS,
};

use crate::configx::toml::Table;

/// Which dataset generator to use (synthetic stand-ins, DESIGN.md §2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetKind {
    /// 32-feature, 10-class synthetic MLP task (tests/quickstart).
    Tiny,
    /// 16×16×3, 10-class class-Gaussian images (CIFAR-10 stand-in).
    SynthCifar10,
    /// 16×16×3, 100-class (CIFAR-100 stand-in).
    SynthCifar100,
    /// 28×28×1, 62-class writer-sharded images (FEMNIST stand-in).
    SynthFemnist,
}

impl DatasetKind {
    /// Parse a CLI/TOML dataset name; `None` when unrecognised.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "tiny" => DatasetKind::Tiny,
            "cifar10" => DatasetKind::SynthCifar10,
            "cifar100" => DatasetKind::SynthCifar100,
            "femnist" => DatasetKind::SynthFemnist,
            _ => return None,
        })
    }

    /// Canonical lowercase name (the CLI/label form).
    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Tiny => "tiny",
            DatasetKind::SynthCifar10 => "cifar10",
            DatasetKind::SynthCifar100 => "cifar100",
            DatasetKind::SynthFemnist => "femnist",
        }
    }

    /// Label-space size of the dataset.
    pub fn num_classes(&self) -> usize {
        match self {
            DatasetKind::Tiny | DatasetKind::SynthCifar10 => 10,
            DatasetKind::SynthCifar100 => 100,
            DatasetKind::SynthFemnist => 62,
        }
    }

    /// Per-client local training wall time the paper charges (§V-A2):
    /// 0.1 s FEMNIST, 2 s CIFAR-10, 3 s CIFAR-100.
    pub fn local_train_time_s(&self) -> f64 {
        match self {
            DatasetKind::Tiny => 0.05,
            DatasetKind::SynthCifar10 => 2.0,
            DatasetKind::SynthCifar100 => 3.0,
            DatasetKind::SynthFemnist => 0.1,
        }
    }
}

/// Client data partition scheme (§V-A1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    /// Shuffle the training set and split uniformly.
    Iid,
    /// Dirichlet(β) label distributions per client.
    Dirichlet(f64),
    /// FEMNIST's inherent writer-based non-IID.
    Natural,
}

impl Partition {
    /// Human-readable label, e.g. `iid` or `dirichlet(0.5)`.
    pub fn name(&self) -> String {
        match self {
            Partition::Iid => "iid".into(),
            Partition::Dirichlet(beta) => format!("dirichlet({beta})"),
            Partition::Natural => "natural".into(),
        }
    }
}

/// In-network aggregation algorithm under test.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AlgorithmKind {
    /// The paper's two-phase voting/aggregation protocol.
    FediAc,
    /// SwitchML-style dense quantised in-network aggregation.
    SwitchMl,
    /// OmniReduce-style non-zero-block sparse aggregation.
    OmniReduce,
    /// libra-style hot/cold index split (switch + remote server).
    Libra,
    /// Plain parameter-server FedAvg (uncompressed reference).
    FedAvg,
}

impl AlgorithmKind {
    /// Parse a CLI/TOML algorithm name; `None` when unrecognised.
    pub fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "fediac" => AlgorithmKind::FediAc,
            "switchml" => AlgorithmKind::SwitchMl,
            "omnireduce" => AlgorithmKind::OmniReduce,
            "libra" => AlgorithmKind::Libra,
            "fedavg" => AlgorithmKind::FedAvg,
            _ => return None,
        })
    }

    /// Canonical lowercase name (the CLI/label form).
    pub fn name(&self) -> &'static str {
        match self {
            AlgorithmKind::FediAc => "fediac",
            AlgorithmKind::SwitchMl => "switchml",
            AlgorithmKind::OmniReduce => "omnireduce",
            AlgorithmKind::Libra => "libra",
            AlgorithmKind::FedAvg => "fedavg",
        }
    }

    /// Every algorithm, in the paper's presentation order.
    pub const ALL: [AlgorithmKind; 5] = [
        AlgorithmKind::FediAc,
        AlgorithmKind::SwitchMl,
        AlgorithmKind::OmniReduce,
        AlgorithmKind::Libra,
        AlgorithmKind::FedAvg,
    ];
}

/// Programmable-switch performance profile (§V-A2).
#[derive(Debug, Clone, PartialEq)]
pub struct PsProfile {
    /// Profile label ("high" / "low").
    pub name: String,
    /// Mean per-packet aggregation time (s): 3.03e-7 high, 3.03e-6 low.
    pub agg_mean_s: f64,
    /// Jitter std of the Gaussian service model. The paper quotes a
    /// "variance of 2.15e-8"; interpreted as jitter std (a literal
    /// variance of 2.15e-8 s² gives a std of ~147 µs that would drown
    /// both profiles in identical noise — see DESIGN.md §2 note 1).
    pub agg_jitter_s: f64,
    /// Register memory the switch can devote to FL aggregation.
    pub memory_bytes: usize,
}

impl PsProfile {
    /// The paper's high-performance switch profile.
    pub fn high() -> Self {
        PsProfile {
            name: "high".into(),
            agg_mean_s: 3.03e-7,
            agg_jitter_s: 2.15e-8,
            memory_bytes: 1 << 20,
        }
    }

    /// The paper's low-performance switch profile (10× slower service).
    pub fn low() -> Self {
        PsProfile {
            name: "low".into(),
            agg_mean_s: 3.03e-6,
            agg_jitter_s: 2.15e-8,
            memory_bytes: 1 << 20,
        }
    }

    /// Parse a CLI profile name; `None` when unrecognised.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "high" => Some(PsProfile::high()),
            "low" => Some(PsProfile::low()),
            _ => None,
        }
    }
}

/// Learning-rate schedule lr(t) = base / (1 + sqrt(t)/div) (§V-A1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LrSchedule {
    /// Base learning rate at round 0.
    pub base: f64,
    /// Decay divisor: larger means slower decay.
    pub div: f64,
}

impl LrSchedule {
    /// Learning rate for `round`.
    pub fn at(&self, round: usize) -> f64 {
        self.base / (1.0 + (round as f64).sqrt() / self.div)
    }
}

/// Model-execution backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-rust manual-backprop MLP (fast, artifact-free; CI/tests).
    Native,
    /// AOT HLO artifacts executed via the PJRT CPU client (full stack).
    Pjrt,
}

impl BackendKind {
    /// Parse a CLI backend name; `None` when unrecognised.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "native" => Some(BackendKind::Native),
            "pjrt" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }
}

/// FediAC hyper-parameters (§IV, §V-A3).
#[derive(Debug, Clone, PartialEq)]
pub struct FediAcConf {
    /// Votes per client, as a fraction of d (paper: k = 5%·d).
    pub k_frac: f64,
    /// Voting threshold a (paper: 3 for IID/FEMNIST, 4 for non-IID, N=20).
    pub threshold_a: usize,
    /// Quantisation bits b; None ⇒ derive from Corollary 1 in round 1.
    pub bits_b: Option<usize>,
    /// Run-length-encode the phase-1 bitmaps (§IV-D future work).
    pub rle_phase1: bool,
}

impl Default for FediAcConf {
    fn default() -> Self {
        FediAcConf { k_frac: 0.05, threshold_a: 3, bits_b: None, rle_phase1: false }
    }
}

/// Baseline hyper-parameters, fixed to the tuned optima reported in §V-A3.
#[derive(Debug, Clone, PartialEq)]
pub struct BaselineConf {
    /// SwitchML quantisation bits (paper-tuned best: 12).
    pub switchml_bits: usize,
    /// libra Topk fraction (paper-tuned best: 1%·d).
    pub libra_k_frac: f64,
    /// Fraction of parameters libra classifies as hot (switch-aggregated).
    pub libra_hot_frac: f64,
    /// Extra round-trip latency for libra's cold-path remote server (s).
    pub libra_server_rtt_s: f64,
    /// OmniReduce Topk fraction (paper-tuned best: 5%·d).
    pub omni_k_frac: f64,
    /// OmniReduce block size in elements (non-zero block detection).
    pub omni_block_elems: usize,
    /// Give the Topk baselines (libra/OmniReduce) residual error feedback.
    /// The paper's Algorithm 1 carries the residual e only for FediAC and
    /// describes the baselines as plain "sparsified using Topk", so the
    /// faithful default is false; true is an ablation (bench_ablation).
    pub error_feedback: bool,
    /// Remote parameter-server per-packet processing time (s) for libra's
    /// cold path and the FedAvg baseline. An order of magnitude slower
    /// than the low-perf PS — the premise of in-network aggregation.
    pub server_packet_time_s: f64,
    /// One-way client↔server network latency (s).
    pub server_rtt_s: f64,
}

impl Default for BaselineConf {
    fn default() -> Self {
        BaselineConf {
            switchml_bits: 12,
            libra_k_frac: 0.01,
            libra_hot_frac: 0.7,
            libra_server_rtt_s: 0.030,
            omni_k_frac: 0.05,
            omni_block_elems: 256,
            error_feedback: false,
            server_packet_time_s: 3.0e-5,
            server_rtt_s: 0.015,
        }
    }
}

/// Complete description of one experiment run.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Dataset generator.
    pub dataset: DatasetKind,
    /// Client data partition scheme.
    pub partition: Partition,
    /// Aggregation algorithm under test.
    pub algorithm: AlgorithmKind,
    /// Model-execution backend.
    pub backend: BackendKind,
    /// Programmable-switch performance profile.
    pub ps: PsProfile,
    /// Clients N contributing per round.
    pub num_clients: usize,
    /// Local SGD iterations per round (paper: E).
    pub local_iters: usize,
    /// Rounds to run (unless the time limit fires first).
    pub rounds: usize,
    /// Stop once simulated wall-clock exceeds this (paper fig. 3/4: 500 s).
    pub sim_time_limit_s: Option<f64>,
    /// Learning-rate schedule.
    pub lr: LrSchedule,
    /// FediAC hyper-parameters.
    pub fediac: FediAcConf,
    /// Baseline hyper-parameters.
    pub baselines: BaselineConf,
    /// Ethernet payload per packet (paper: 1,500-byte packets, §V-A2).
    pub packet_mtu: usize,
    /// Per-packet protocol header bytes (Eth+IP+UDP+agg header).
    pub packet_header: usize,
    /// Download rate multiplier vs mean client upload rate (paper: 5×).
    pub download_mult: f64,
    /// Per-client samples for synthetic datasets (FEMNIST: 300–400).
    pub samples_per_client: usize,
    /// Testbed dimension scaling: emulate a model `net_scale`× larger on
    /// the wire (client rates ÷ net_scale, PS/server per-packet times ×
    /// net_scale). The paper trains ResNet-18 (d ≈ 11M) while this
    /// testbed runs d ≈ 50k models; net_scale ≈ 200 restores the paper's
    /// communication/computation ratio so the figures' wall-clock shape
    /// is comparable (DESIGN.md §2 note 4). 1.0 = no scaling.
    pub net_scale: f64,
    /// Number of collaborative PSes sharding the index space (§VI future
    /// work: "extend our algorithm to FL systems with multiple
    /// collaborative PSes"). 1 = the paper's single-switch setting.
    pub num_switches: usize,
    /// Uplink packet-loss probability; lost packets are retransmitted
    /// after `retx_timeout_s` (SwitchML's end-host retransmission, §II).
    pub loss_rate: f64,
    /// Retransmission timeout (s).
    pub retx_timeout_s: f64,
    /// Root seed every derived RNG stream mixes in.
    pub seed: u64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: DatasetKind::Tiny,
            partition: Partition::Iid,
            algorithm: AlgorithmKind::FediAc,
            backend: BackendKind::Native,
            ps: PsProfile::high(),
            num_clients: 20,
            local_iters: 5,
            rounds: 50,
            sim_time_limit_s: None,
            lr: LrSchedule { base: 0.1, div: 20.0 },
            fediac: FediAcConf::default(),
            baselines: BaselineConf::default(),
            packet_mtu: 1500,
            packet_header: 62, // Eth(14)+IP(20)+UDP(8)+agg header(20)
            download_mult: 5.0,
            samples_per_client: 350,
            net_scale: 1.0,
            num_switches: 1,
            loss_rate: 0.0,
            retx_timeout_s: 0.05,
            seed: 7,
        }
    }
}

/// Everything that can go wrong building or loading a config.
#[derive(Debug, thiserror::Error)]
pub enum ConfigError {
    /// A field name or enum value is not recognised.
    #[error("unknown {field}: '{value}'")]
    Unknown { field: &'static str, value: String },
    /// A value is recognised but out of range / inconsistent.
    #[error("invalid config: {0}")]
    Invalid(String),
    /// The TOML-subset loader failed.
    #[error(transparent)]
    Toml(#[from] toml::TomlError),
    /// Reading the config file failed.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl ExperimentConfig {
    /// Paper preset for a dataset/partition pair: lr schedule, a-threshold
    /// and local-iteration counts from §V-A1/§V-A3.
    pub fn preset(dataset: DatasetKind, partition: Partition) -> Self {
        let mut cfg = ExperimentConfig { dataset, partition, ..Default::default() };
        cfg.lr = match dataset {
            // ResNet-18 stand-in: 0.1/(1+sqrt(t)/40); CNN: 0.1/(1+sqrt(t)/20).
            DatasetKind::SynthCifar10 | DatasetKind::SynthCifar100 => {
                LrSchedule { base: 0.1, div: 40.0 }
            }
            _ => LrSchedule { base: 0.1, div: 20.0 },
        };
        // §V-A3: a = 3 for FEMNIST / CIFAR*_IID, 4 for CIFAR*_non-IID.
        cfg.fediac.threshold_a = match partition {
            Partition::Dirichlet(_) => 4,
            _ => 3,
        };
        cfg
    }

    /// Overlay a parsed TOML table onto `self` (flat dotted keys).
    pub fn apply_table(&mut self, t: &Table) -> Result<(), ConfigError> {
        if let Some(v) = t.get("dataset").and_then(|v| v.as_str()) {
            self.dataset = DatasetKind::parse(v)
                .ok_or(ConfigError::Unknown { field: "dataset", value: v.into() })?;
        }
        if let Some(v) = t.get("partition").and_then(|v| v.as_str()) {
            self.partition = match v {
                "iid" => Partition::Iid,
                "natural" => Partition::Natural,
                "dirichlet" => Partition::Dirichlet(t.f64_or("beta", 0.5)),
                other => {
                    return Err(ConfigError::Unknown { field: "partition", value: other.into() })
                }
            };
        }
        if let Some(v) = t.get("algorithm").and_then(|v| v.as_str()) {
            self.algorithm = AlgorithmKind::parse(v)
                .ok_or(ConfigError::Unknown { field: "algorithm", value: v.into() })?;
        }
        if let Some(v) = t.get("backend").and_then(|v| v.as_str()) {
            self.backend = BackendKind::parse(v)
                .ok_or(ConfigError::Unknown { field: "backend", value: v.into() })?;
        }
        if let Some(v) = t.get("ps.profile").and_then(|v| v.as_str()) {
            self.ps = PsProfile::parse(v)
                .ok_or(ConfigError::Unknown { field: "ps.profile", value: v.into() })?;
        }
        self.ps.agg_mean_s = t.f64_or("ps.agg_mean_s", self.ps.agg_mean_s);
        self.ps.agg_jitter_s = t.f64_or("ps.agg_jitter_s", self.ps.agg_jitter_s);
        self.ps.memory_bytes = t.usize_or("ps.memory_bytes", self.ps.memory_bytes);
        self.num_clients = t.usize_or("num_clients", self.num_clients);
        self.local_iters = t.usize_or("local_iters", self.local_iters);
        self.rounds = t.usize_or("rounds", self.rounds);
        if let Some(v) = t.get("sim_time_limit_s").and_then(|v| v.as_f64()) {
            self.sim_time_limit_s = Some(v);
        }
        self.lr.base = t.f64_or("lr.base", self.lr.base);
        self.lr.div = t.f64_or("lr.div", self.lr.div);
        self.fediac.k_frac = t.f64_or("fediac.k_frac", self.fediac.k_frac);
        self.fediac.threshold_a = t.usize_or("fediac.threshold_a", self.fediac.threshold_a);
        if let Some(b) = t.get("fediac.bits_b").and_then(|v| v.as_i64()) {
            self.fediac.bits_b = Some(b as usize);
        }
        self.fediac.rle_phase1 = t.bool_or("fediac.rle_phase1", self.fediac.rle_phase1);
        self.baselines.switchml_bits =
            t.usize_or("baselines.switchml_bits", self.baselines.switchml_bits);
        self.baselines.libra_k_frac =
            t.f64_or("baselines.libra_k_frac", self.baselines.libra_k_frac);
        self.baselines.libra_hot_frac =
            t.f64_or("baselines.libra_hot_frac", self.baselines.libra_hot_frac);
        self.baselines.omni_k_frac =
            t.f64_or("baselines.omni_k_frac", self.baselines.omni_k_frac);
        self.baselines.omni_block_elems =
            t.usize_or("baselines.omni_block_elems", self.baselines.omni_block_elems);
        self.baselines.error_feedback =
            t.bool_or("baselines.error_feedback", self.baselines.error_feedback);
        self.packet_mtu = t.usize_or("packet_mtu", self.packet_mtu);
        self.packet_header = t.usize_or("packet_header", self.packet_header);
        self.download_mult = t.f64_or("download_mult", self.download_mult);
        self.samples_per_client = t.usize_or("samples_per_client", self.samples_per_client);
        self.net_scale = t.f64_or("net_scale", self.net_scale);
        self.num_switches = t.usize_or("num_switches", self.num_switches);
        self.loss_rate = t.f64_or("loss_rate", self.loss_rate);
        self.retx_timeout_s = t.f64_or("retx_timeout_s", self.retx_timeout_s);
        self.seed = t.u64_or("seed", self.seed);
        self.validate()
    }

    /// Load and overlay a TOML file.
    pub fn apply_file(&mut self, path: &str) -> Result<(), ConfigError> {
        let text = std::fs::read_to_string(path)?;
        self.apply_table(&toml::parse(&text)?)
    }

    /// Cross-field sanity checks (run after presets + overrides).
    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.num_clients == 0 {
            return Err(ConfigError::Invalid("num_clients must be > 0".into()));
        }
        if self.fediac.threshold_a == 0 || self.fediac.threshold_a > self.num_clients {
            return Err(ConfigError::Invalid(format!(
                "threshold a={} must be in [1, N={}]",
                self.fediac.threshold_a, self.num_clients
            )));
        }
        if !(0.0..=1.0).contains(&self.fediac.k_frac) {
            return Err(ConfigError::Invalid("k_frac must be in [0,1]".into()));
        }
        if let Some(b) = self.fediac.bits_b {
            if !(2..=31).contains(&b) {
                return Err(ConfigError::Invalid(format!("bits_b={b} out of [2,31]")));
            }
        }
        if self.packet_mtu <= self.packet_header {
            return Err(ConfigError::Invalid("packet_mtu must exceed header".into()));
        }
        if self.rounds == 0 && self.sim_time_limit_s.is_none() {
            return Err(ConfigError::Invalid("need rounds > 0 or a time limit".into()));
        }
        if self.net_scale <= 0.0 {
            return Err(ConfigError::Invalid("net_scale must be positive".into()));
        }
        if self.num_switches == 0 || self.num_switches > 64 {
            return Err(ConfigError::Invalid(format!(
                "num_switches {} out of [1, 64]",
                self.num_switches
            )));
        }
        if !(0.0..1.0).contains(&self.loss_rate) {
            return Err(ConfigError::Invalid(format!(
                "loss_rate {} must be in [0, 1)",
                self.loss_rate
            )));
        }
        Ok(())
    }

    /// Usable payload bytes per packet.
    pub fn packet_payload(&self) -> usize {
        self.packet_mtu - self.packet_header
    }

    /// Model name the backend should load (dataset-determined).
    pub fn model_name(&self) -> &'static str {
        self.dataset.name()
    }

    /// One-line human-readable identity for logs/CSV headers.
    pub fn label(&self) -> String {
        format!(
            "{}_{}_{}_{}ps_n{}",
            self.algorithm.name(),
            self.dataset.name(),
            self.partition.name(),
            self.ps.name,
            self.num_clients
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper() {
        let c = ExperimentConfig::preset(DatasetKind::SynthCifar10, Partition::Iid);
        assert_eq!(c.fediac.threshold_a, 3);
        assert_eq!(c.lr.div, 40.0);
        let c = ExperimentConfig::preset(
            DatasetKind::SynthCifar10,
            Partition::Dirichlet(0.5),
        );
        assert_eq!(c.fediac.threshold_a, 4);
        let c = ExperimentConfig::preset(DatasetKind::SynthFemnist, Partition::Natural);
        assert_eq!(c.lr.div, 20.0);
        assert_eq!(c.num_clients, 20);
        assert_eq!(c.local_iters, 5);
        assert_eq!(c.packet_mtu, 1500);
    }

    #[test]
    fn lr_schedule_decays() {
        let lr = LrSchedule { base: 0.1, div: 40.0 };
        assert!((lr.at(0) - 0.1).abs() < 1e-12);
        assert!(lr.at(100) < lr.at(10));
        assert!((lr.at(1600) - 0.1 / 2.0).abs() < 1e-9);
    }

    #[test]
    fn table_overlay() {
        let mut c = ExperimentConfig::default();
        let t = toml::parse(
            "dataset = \"cifar100\"\npartition = \"dirichlet\"\nbeta = 0.3\n\
             algorithm = \"switchml\"\nrounds = 9\n[ps]\nprofile = \"low\"\n\
             [fediac]\nthreshold_a = 4\n[baselines]\nswitchml_bits = 10\n",
        )
        .unwrap();
        c.apply_table(&t).unwrap();
        assert_eq!(c.dataset, DatasetKind::SynthCifar100);
        assert_eq!(c.partition, Partition::Dirichlet(0.3));
        assert_eq!(c.algorithm, AlgorithmKind::SwitchMl);
        assert_eq!(c.rounds, 9);
        assert_eq!(c.ps.name, "low");
        assert!((c.ps.agg_mean_s - 3.03e-6).abs() < 1e-12);
        assert_eq!(c.baselines.switchml_bits, 10);
    }

    #[test]
    fn validation_rejects_bad_threshold() {
        let mut c = ExperimentConfig::default();
        c.fediac.threshold_a = 21; // > N = 20
        assert!(c.validate().is_err());
        c.fediac.threshold_a = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn ps_profiles_paper_values() {
        assert!((PsProfile::high().agg_mean_s - 3.03e-7).abs() < 1e-15);
        assert!((PsProfile::low().agg_mean_s - 3.03e-6).abs() < 1e-15);
        assert_eq!(PsProfile::high().memory_bytes, 1 << 20);
    }

    #[test]
    fn packet_payload_positive() {
        let c = ExperimentConfig::default();
        assert_eq!(c.packet_payload(), 1500 - 62);
    }
}
