//! Stub PJRT backend for builds without the `pjrt` feature.
//!
//! The real [`backend`](super) implementation executes AOT HLO artifacts
//! through the `xla` PJRT bindings, which are only present in toolchains
//! that vendor them. This stub keeps every caller compiling: `load`
//! always errors, and `artifacts_available` reports false for such
//! builds, so runner/tests take the skip path before ever constructing
//! one.

use anyhow::Result;

use crate::data::FederatedData;
use crate::fl::backend::{LocalTrainOutput, ModelBackend};

/// Unconstructible placeholder with the real backend's public surface.
pub struct PjrtBackend {
    _private: (),
}

impl PjrtBackend {
    /// Always fails: this build cannot execute PJRT artifacts.
    pub fn load(_dir: &str, _model: &str, _data: FederatedData, _seed: u64) -> Result<Self> {
        anyhow::bail!(
            "this build has no PJRT runtime — rebuild with `--features pjrt` \
             and the xla bindings vendored (see DESIGN.md)"
        )
    }
}

impl ModelBackend for PjrtBackend {
    fn d(&self) -> usize {
        unreachable!("stub PjrtBackend cannot be constructed")
    }

    fn init_params(&mut self) -> Vec<f32> {
        unreachable!("stub PjrtBackend cannot be constructed")
    }

    fn local_train(
        &mut self,
        _params: &[f32],
        _client: usize,
        _round: usize,
        _lr: f32,
    ) -> LocalTrainOutput {
        unreachable!("stub PjrtBackend cannot be constructed")
    }

    fn evaluate(&mut self, _params: &[f32]) -> (f64, f64) {
        unreachable!("stub PjrtBackend cannot be constructed")
    }

    fn vote_scores(&mut self, _updates: &[f32], _seed: i64) -> Vec<f32> {
        unreachable!("stub PjrtBackend cannot be constructed")
    }

    fn compress(
        &mut self,
        _updates: &[f32],
        _gia: &[f32],
        _f: f32,
        _seed: i64,
    ) -> (Vec<i32>, Vec<f32>) {
        unreachable!("stub PjrtBackend cannot be constructed")
    }

    fn backend_name(&self) -> &'static str {
        "pjrt-stub"
    }
}
