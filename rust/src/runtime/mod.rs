//! PJRT runtime: manifest parsing + artifact loading + the PJRT-backed
//! [`crate::fl::ModelBackend`]. Start-to-finish pattern follows
//! /opt/xla-example/load_hlo (HLO text → compile → execute).

pub mod backend;
pub mod manifest;

pub use backend::PjrtBackend;
pub use manifest::{Manifest, ManifestError, ModelEntry};

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// True when an AOT bundle is present (tests skip PJRT paths otherwise).
pub fn artifacts_available(dir: &str) -> bool {
    std::path::Path::new(dir).join("manifest.json").exists()
}
