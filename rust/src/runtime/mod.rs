//! PJRT runtime: manifest parsing + artifact loading + the PJRT-backed
//! [`crate::fl::ModelBackend`]. Start-to-finish pattern follows
//! /opt/xla-example/load_hlo (HLO text → compile → execute).

// The real backend links the `xla` PJRT bindings, which only exist in
// toolchains that vendor them; default builds compile a stub with the same
// surface so every caller type-checks and PJRT paths skip cleanly.
#[cfg(feature = "pjrt")]
pub mod backend;
#[cfg(not(feature = "pjrt"))]
#[path = "backend_stub.rs"]
pub mod backend;
pub mod manifest;

pub use backend::PjrtBackend;
pub use manifest::{Manifest, ManifestError, ModelEntry};

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// True when an AOT bundle is present AND this build can execute it
/// (tests skip PJRT paths otherwise).
pub fn artifacts_available(dir: &str) -> bool {
    cfg!(feature = "pjrt") && std::path::Path::new(dir).join("manifest.json").exists()
}
