//! Artifact manifest: what the AOT bundle contains and how to call it.
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json`; this module
//! parses and validates it so the rust side never guesses shapes.

use crate::util::json::{self, Json};

/// Everything that can go wrong loading `artifacts/manifest.json`.
#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    /// Reading the file failed.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    /// The file is not well-formed JSON.
    #[error("json: {0}")]
    Json(#[from] json::JsonError),
    /// The JSON does not match the manifest schema.
    #[error("manifest: {0}")]
    Schema(String),
}

/// One tensor in the flat-parameter layout.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorLayout {
    /// Tensor name in the model's parameter tree.
    pub tensor: String,
    /// Tensor shape (row-major).
    pub shape: Vec<usize>,
}

/// One model variant's entry.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    /// Model variant name (manifest key).
    pub name: String,
    /// Flat parameter count.
    pub d: usize,
    /// Input sample shape (H, W, C).
    pub input_shape: Vec<usize>,
    /// Label-space size.
    pub num_classes: usize,
    /// Batch size the train artifact was lowered for.
    pub train_batch: usize,
    /// Batch size the eval artifact was lowered for.
    pub eval_batch: usize,
    /// Local SGD iterations baked into the train artifact.
    pub local_iters: usize,
    /// Flat-vector ↔ tensor mapping, in flattening order.
    pub layout: Vec<TensorLayout>,
    /// artifact kind ("train"/"eval"/"compress"/"vote") → file name.
    pub artifacts: std::collections::BTreeMap<String, String>,
}

impl ModelEntry {
    /// Flat feature length of one sample.
    pub fn feature_len(&self) -> usize {
        self.input_shape.iter().product()
    }

    /// Validate internal consistency (layout sums to d, artifacts present).
    pub fn validate(&self) -> Result<(), ManifestError> {
        let total: usize =
            self.layout.iter().map(|t| t.shape.iter().product::<usize>()).sum();
        if total != self.d {
            return Err(ManifestError::Schema(format!(
                "{}: layout sums to {total}, manifest d = {}",
                self.name, self.d
            )));
        }
        for kind in ["train", "eval", "compress", "vote", "init"] {
            if !self.artifacts.contains_key(kind) {
                return Err(ManifestError::Schema(format!(
                    "{}: missing artifact '{kind}'",
                    self.name
                )));
            }
        }
        Ok(())
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Model entries keyed by variant name.
    pub models: std::collections::BTreeMap<String, ModelEntry>,
}

fn usize_field(obj: &Json, key: &str, ctx: &str) -> Result<usize, ManifestError> {
    obj.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| ManifestError::Schema(format!("{ctx}: missing usize '{key}'")))
}

impl Manifest {
    /// Parse and validate manifest JSON.
    pub fn parse(text: &str) -> Result<Self, ManifestError> {
        let root = json::parse(text)?;
        let fmt = root.get("format").and_then(Json::as_str).unwrap_or("");
        if fmt != "hlo-text-v1" {
            return Err(ManifestError::Schema(format!("unknown format '{fmt}'")));
        }
        let models_json = root
            .get("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| ManifestError::Schema("missing 'models'".into()))?;
        let mut models = std::collections::BTreeMap::new();
        for (name, m) in models_json {
            let layout = m
                .get("layout")
                .and_then(Json::as_arr)
                .ok_or_else(|| ManifestError::Schema(format!("{name}: missing layout")))?
                .iter()
                .map(|t| -> Result<TensorLayout, ManifestError> {
                    Ok(TensorLayout {
                        tensor: t
                            .get("tensor")
                            .and_then(Json::as_str)
                            .ok_or_else(|| {
                                ManifestError::Schema(format!("{name}: tensor name"))
                            })?
                            .to_string(),
                        shape: t
                            .get("shape")
                            .and_then(Json::as_arr)
                            .ok_or_else(|| {
                                ManifestError::Schema(format!("{name}: tensor shape"))
                            })?
                            .iter()
                            .map(|d| d.as_usize().unwrap_or(0))
                            .collect(),
                    })
                })
                .collect::<Result<Vec<_>, _>>()?;
            let artifacts = m
                .get("artifacts")
                .and_then(Json::as_obj)
                .ok_or_else(|| ManifestError::Schema(format!("{name}: artifacts")))?
                .iter()
                .map(|(k, v)| (k.clone(), v.as_str().unwrap_or("").to_string()))
                .collect();
            let entry = ModelEntry {
                name: name.clone(),
                d: usize_field(m, "d", name)?,
                input_shape: m
                    .get("input_shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| ManifestError::Schema(format!("{name}: input_shape")))?
                    .iter()
                    .map(|d| d.as_usize().unwrap_or(0))
                    .collect(),
                num_classes: usize_field(m, "num_classes", name)?,
                train_batch: usize_field(m, "train_batch", name)?,
                eval_batch: usize_field(m, "eval_batch", name)?,
                local_iters: usize_field(m, "local_iters", name)?,
                layout,
                artifacts,
            };
            entry.validate()?;
            models.insert(name.clone(), entry);
        }
        Ok(Manifest { models })
    }

    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &str) -> Result<Self, ManifestError> {
        let path = std::path::Path::new(dir).join("manifest.json");
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Entry for a model variant, or a schema error naming it.
    pub fn model(&self, name: &str) -> Result<&ModelEntry, ManifestError> {
        self.models
            .get(name)
            .ok_or_else(|| ManifestError::Schema(format!("model '{name}' not in manifest")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text-v1",
      "models": {
        "tiny": {
          "name": "tiny", "d": 12, "input_shape": [2], "num_classes": 2,
          "train_batch": 4, "eval_batch": 8, "local_iters": 5,
          "layout": [
            {"tensor": "fc0_w", "shape": [2, 3]},
            {"tensor": "fc0_b", "shape": [3]},
            {"tensor": "fc1_w", "shape": [3, 1]}
          ],
          "artifacts": {"train": "t", "eval": "e", "compress": "c", "vote": "v", "init": "i"},
          "init_params_seed": 0
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let tiny = m.model("tiny").unwrap();
        assert_eq!(tiny.d, 12);
        assert_eq!(tiny.feature_len(), 2);
        assert_eq!(tiny.layout.len(), 3);
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn layout_mismatch_rejected() {
        let bad = SAMPLE.replace("\"d\": 12", "\"d\": 13");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn missing_artifact_rejected() {
        let bad = SAMPLE.replace("\"vote\": \"v\"", "\"votex\": \"v\"");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn unknown_format_rejected() {
        let bad = SAMPLE.replace("hlo-text-v1", "hlo-bin");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn loads_real_bundle_if_present() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(!m.models.is_empty());
            for entry in m.models.values() {
                entry.validate().unwrap();
            }
        }
    }
}
