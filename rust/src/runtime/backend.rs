//! PJRT model backend: the full three-layer stack at runtime.
//!
//! Loads the AOT HLO-text artifacts (`train`/`eval`/`compress`/`vote`/
//! `init`), compiles them once on the PJRT CPU client, and serves the
//! [`ModelBackend`] contract from compiled executables. Python never runs
//! here — the artifacts *are* the L2 JAX model and the L1 Pallas kernels.
//!
//! Interchange is HLO text via `HloModuleProto::from_text_file` (see
//! DESIGN.md §1 for why not serialized protos).

use anyhow::{Context, Result};

use crate::data::FederatedData;
use crate::fl::backend::{LocalTrainOutput, ModelBackend};
use crate::runtime::manifest::{Manifest, ModelEntry};
use crate::util::Rng;

/// PJRT-backed model execution.
pub struct PjrtBackend {
    entry: ModelEntry,
    data: FederatedData,
    train_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
    compress_exe: xla::PjRtLoadedExecutable,
    vote_exe: xla::PjRtLoadedExecutable,
    init_exe: xla::PjRtLoadedExecutable,
    seed: u64,
    // Reused host staging buffers (hot path: one pair per train call).
    feat_buf: Vec<f32>,
    label_buf: Vec<i32>,
}

fn compile(
    client: &xla::PjRtClient,
    dir: &str,
    file: &str,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = std::path::Path::new(dir).join(file);
    let proto = xla::HloModuleProto::from_text_file(&path)
        .with_context(|| format!("loading HLO text {path:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).with_context(|| format!("compiling {file}"))
}

impl PjrtBackend {
    /// Load + compile the artifact bundle for `model` from `dir`.
    pub fn load(dir: &str, model: &str, data: FederatedData, seed: u64) -> Result<Self> {
        let manifest = Manifest::load(dir).context("loading manifest.json")?;
        let entry = manifest.model(model)?.clone();
        anyhow::ensure!(
            entry.feature_len() == data.train.feature_len(),
            "dataset feature_len {} != model input {}",
            data.train.feature_len(),
            entry.feature_len()
        );
        anyhow::ensure!(
            entry.num_classes == data.train.num_classes(),
            "dataset classes {} != model classes {}",
            data.train.num_classes(),
            entry.num_classes
        );
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let get = |kind: &str| -> Result<&str> {
            entry
                .artifacts
                .get(kind)
                .map(|s| s.as_str())
                .ok_or_else(|| anyhow::anyhow!("artifact kind '{kind}' missing"))
        };
        let train_exe = compile(&client, dir, get("train")?)?;
        let eval_exe = compile(&client, dir, get("eval")?)?;
        let compress_exe = compile(&client, dir, get("compress")?)?;
        let vote_exe = compile(&client, dir, get("vote")?)?;
        let init_exe = compile(&client, dir, get("init")?)?;
        Ok(PjrtBackend {
            entry,
            data,
            train_exe,
            eval_exe,
            compress_exe,
            vote_exe,
            init_exe,
            seed,
            feat_buf: Vec::new(),
            label_buf: Vec::new(),
        })
    }

    /// The manifest entry this backend executes.
    pub fn entry(&self) -> &ModelEntry {
        &self.entry
    }

    fn image_dims(&self, leading: &[i64]) -> Vec<i64> {
        let mut dims = leading.to_vec();
        dims.extend(self.entry.input_shape.iter().map(|&d| d as i64));
        dims
    }

    /// Execute an executable and unwrap the outer tuple.
    fn run(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
        let out = exe.execute::<xla::Literal>(args)?;
        Ok(out[0][0].to_literal_sync()?)
    }
}

impl ModelBackend for PjrtBackend {
    fn d(&self) -> usize {
        self.entry.d
    }

    fn init_params(&mut self) -> Vec<f32> {
        let result = Self::run(&self.init_exe, &[]).expect("init artifact failed");
        let flat = result.to_tuple1().and_then(|l| l.to_vec::<f32>()).expect("init output");
        assert_eq!(flat.len(), self.entry.d);
        flat
    }

    fn local_train(
        &mut self,
        params: &[f32],
        client: usize,
        round: usize,
        lr: f32,
    ) -> LocalTrainOutput {
        let e = self.entry.local_iters;
        let b = self.entry.train_batch;
        let flen = self.entry.feature_len();
        let my = &self.data.client_indices[client];
        assert!(!my.is_empty(), "client {client} has no data");
        let mut rng =
            Rng::new(self.seed ^ (client as u64) << 20 ^ (round as u64) << 1 ^ 0xB47C);
        self.feat_buf.resize(e * b * flen, 0.0);
        self.label_buf.resize(e * b, 0);
        let indices: Vec<usize> = (0..e * b).map(|_| my[rng.below(my.len())]).collect();
        self.data.train.fill_batch(&indices, &mut self.feat_buf, &mut self.label_buf);

        let dims = self.image_dims(&[e as i64, b as i64]);
        let images = xla::Literal::vec1(self.feat_buf.as_slice())
            .reshape(&dims)
            .expect("image reshape");
        let labels = xla::Literal::vec1(self.label_buf.as_slice())
            .reshape(&[e as i64, b as i64])
            .expect("label reshape");
        let params_lit = xla::Literal::vec1(params);
        let lr_lit = xla::Literal::scalar(lr);

        let result = Self::run(&self.train_exe, &[params_lit, images, labels, lr_lit])
            .expect("train exec");
        let (new_params, loss) = result.to_tuple2().expect("train tuple");
        LocalTrainOutput {
            new_params: new_params.to_vec::<f32>().expect("params out"),
            mean_loss: loss.to_vec::<f32>().expect("loss out")[0],
        }
    }

    fn evaluate(&mut self, params: &[f32]) -> (f64, f64) {
        let eb = self.entry.eval_batch;
        let flen = self.entry.feature_len();
        let n = self.data.test.len();
        let chunks = n / eb; // remainder trimmed; test sizes are multiples
        assert!(chunks > 0, "test set smaller than eval batch");
        let params_lit = xla::Literal::vec1(params);
        let mut feat = vec![0f32; eb * flen];
        let mut labels = vec![0i32; eb];
        let mut correct = 0i64;
        let mut loss_sum = 0f64;
        for c in 0..chunks {
            let indices: Vec<usize> = (c * eb..(c + 1) * eb).collect();
            self.data.test.fill_batch(&indices, &mut feat, &mut labels);
            let dims = self.image_dims(&[eb as i64]);
            let images =
                xla::Literal::vec1(feat.as_slice()).reshape(&dims).expect("eval reshape");
            let labels_lit = xla::Literal::vec1(labels.as_slice())
                .reshape(&[eb as i64])
                .expect("eval labels");
            let result = Self::run(&self.eval_exe, &[params_lit.clone(), images, labels_lit])
                .expect("eval exec");
            let (c_lit, l_lit) = result.to_tuple2().expect("eval tuple");
            correct += c_lit.to_vec::<i32>().expect("correct")[0] as i64;
            loss_sum += l_lit.to_vec::<f32>().expect("loss")[0] as f64;
        }
        (correct as f64 / (chunks * eb) as f64, loss_sum / chunks as f64)
    }

    fn vote_scores(&mut self, updates: &[f32], seed: i64) -> Vec<f32> {
        let u = xla::Literal::vec1(updates);
        let s = xla::Literal::scalar(seed as i32);
        let result = Self::run(&self.vote_exe, &[u, s]).expect("vote exec");
        result.to_tuple1().and_then(|l| l.to_vec::<f32>()).expect("vote out")
    }

    fn compress(
        &mut self,
        updates: &[f32],
        gia: &[f32],
        f: f32,
        seed: i64,
    ) -> (Vec<i32>, Vec<f32>) {
        let u = xla::Literal::vec1(updates);
        let g = xla::Literal::vec1(gia);
        let f_lit = xla::Literal::scalar(f);
        let s = xla::Literal::scalar(seed as i32);
        let result =
            Self::run(&self.compress_exe, &[u, g, f_lit, s]).expect("compress exec");
        let (q, residual) = result.to_tuple2().expect("compress tuple");
        (
            q.to_vec::<i32>().expect("q out"),
            residual.to_vec::<f32>().expect("residual out"),
        )
    }

    fn backend_name(&self) -> &'static str {
        "pjrt"
    }
}
