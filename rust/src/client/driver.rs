//! The networked FediAC client: one UDP socket, two phases, timeout-based
//! retransmission.
//!
//! A round is: upload vote blocks → await the Golomb-coded GIA broadcast →
//! quantise against the GIA → upload aligned i32 lanes → await the
//! aggregate broadcast. Every wait retransmits the phase's frames (and a
//! `Poll`) on timeout; the server's scoreboards make retransmission
//! idempotent, so the driver is safe on lossy links — the `send_loss`
//! option injects exactly the lossy-uplink behaviour `net::trace`
//! scenarios model in simulation, making them runnable end-to-end.

use std::net::UdpSocket;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::client::protocol;
use crate::compress::{self, golomb};
use crate::server::{JOIN_OK, JOIN_UNKNOWN_JOB};
use crate::util::{BitVec, Rng};
use crate::wire::{
    decode_frame, decode_lanes, encode_frame, update_chunks, vote_chunks, ChunkAssembler,
    Header, JobSpec, WireKind, DEFAULT_PAYLOAD_BUDGET,
};

/// Everything a client needs to participate in one job.
#[derive(Debug, Clone)]
pub struct ClientOptions {
    /// Server address, e.g. "127.0.0.1:7177".
    pub server: String,
    pub job: u32,
    pub client_id: u16,
    /// Total clients N in the job (all must agree).
    pub n_clients: u16,
    /// Model dimension d.
    pub d: usize,
    /// Voting threshold a (server-side; part of the shared spec).
    pub threshold_a: u16,
    /// Votes per client k (paper: 5%·d).
    pub k: usize,
    /// Quantisation bits b (Eq. 1 / Corollary 1).
    pub bits_b: usize,
    /// Payload bytes per data frame (must match across the job).
    pub payload_budget: usize,
    /// Backend seed: fixes the vote/quantisation RNG streams so a wire
    /// round reproduces an in-process round bit-exactly.
    pub backend_seed: u64,
    /// Receive timeout before retransmitting a phase.
    pub timeout: Duration,
    /// Timeouts tolerated per wait before giving up.
    pub max_retries: usize,
    /// Probability of dropping an outgoing datagram (lossy-uplink
    /// emulation for tests; 0.0 = reliable).
    pub send_loss: f64,
}

impl ClientOptions {
    pub fn new(server: impl Into<String>, job: u32, client_id: u16, d: usize, n_clients: u16) -> Self {
        ClientOptions {
            server: server.into(),
            job,
            client_id,
            n_clients,
            d,
            threshold_a: 3,
            k: protocol::votes_per_client(d, 0.05),
            bits_b: 12,
            payload_budget: DEFAULT_PAYLOAD_BUDGET,
            backend_seed: 7,
            timeout: Duration::from_millis(200),
            max_retries: 50,
            send_loss: 0.0,
        }
    }

    /// The job spec this client will register.
    pub fn spec(&self) -> JobSpec {
        JobSpec {
            d: self.d as u32,
            n_clients: self.n_clients,
            threshold_a: self.threshold_a,
            payload_budget: self.payload_budget as u16,
        }
    }
}

/// Cumulative driver counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClientStats {
    /// Frames re-sent after a timeout.
    pub retransmissions: u64,
    /// Frames dropped by the loss injector (never hit the wire).
    pub dropped_sends: u64,
    /// Poll frames sent.
    pub polls: u64,
}

/// Result of one completed FediAC round over the wire.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    pub gia: BitVec,
    /// Ascending selected dimensions (upload order of the lanes).
    pub gia_indices: Vec<usize>,
    /// Global max-|U| the PS folded from all clients (the m in f).
    pub global_max: f32,
    /// Amplification factor f = (2^{b−1} − N)/(N·m).
    pub scale_f: f32,
    /// Aggregated i32 lanes in GIA order.
    pub aggregate: Vec<i32>,
    /// Dequantised aggregate Σq/(N·f), aligned with `gia_indices`.
    pub delta: Vec<f32>,
    /// Residual error e to fold into the next round's update.
    pub residual: Vec<f32>,
    /// Frames retransmitted during this round.
    pub retransmissions: u64,
}

impl RoundOutcome {
    /// Apply w ← w − delta at the selected dimensions, exactly as the
    /// simulated round does (Algorithm 1 line 12).
    pub fn apply(&self, params: &mut [f32]) {
        crate::algorithms::common::apply_sparse_delta(params, &self.gia_indices, &self.delta);
    }
}

/// A connected (joined) FediAC client.
pub struct FediacClient {
    socket: UdpSocket,
    opts: ClientOptions,
    loss_rng: Rng,
    pub stats: ClientStats,
}

impl FediacClient {
    /// Bind an ephemeral socket, connect and register with the server.
    pub fn connect(opts: ClientOptions) -> Result<Self> {
        // `JobSpec` narrows these fields; reject values that would
        // silently truncate (and then disagree with the local chunking).
        anyhow::ensure!(
            opts.payload_budget <= u16::MAX as usize,
            "payload_budget {} exceeds the wire maximum {}",
            opts.payload_budget,
            u16::MAX
        );
        anyhow::ensure!(
            opts.d <= u32::MAX as usize,
            "d {} exceeds the wire maximum {}",
            opts.d,
            u32::MAX
        );
        opts.spec().validate().map_err(|e| anyhow::anyhow!("bad client options: {e}"))?;
        anyhow::ensure!(opts.client_id < opts.n_clients, "client_id out of range");
        anyhow::ensure!(
            (2..=31).contains(&opts.bits_b) && (1i64 << (opts.bits_b - 1)) > opts.n_clients as i64,
            "bits_b={} too small for N={}",
            opts.bits_b,
            opts.n_clients
        );
        let socket = UdpSocket::bind("0.0.0.0:0").context("binding client socket")?;
        socket.connect(&opts.server).with_context(|| format!("connecting to {}", opts.server))?;
        socket.set_read_timeout(Some(opts.timeout))?;
        let loss_rng = Rng::new(opts.backend_seed ^ (opts.client_id as u64) << 40 ^ 0x10_55);
        let mut client = FediacClient { socket, opts, loss_rng, stats: ClientStats::default() };
        client.join()?;
        Ok(client)
    }

    pub fn options(&self) -> &ClientOptions {
        &self.opts
    }

    fn send_datagram(&mut self, bytes: &[u8]) {
        if self.opts.send_loss > 0.0 && self.loss_rng.f64() < self.opts.send_loss {
            self.stats.dropped_sends += 1;
            return;
        }
        let _ = self.socket.send(bytes);
    }

    /// Register with the server (idempotent; re-run on JOIN_UNKNOWN_JOB).
    fn join(&mut self) -> Result<()> {
        let spec = self.opts.spec();
        let frame = encode_frame(
            &Header::control(WireKind::Join, self.opts.job, self.opts.client_id, 0, 0),
            &spec.encode(),
        );
        let mut buf = vec![0u8; 2048];
        let mut timeouts = 0usize;
        self.send_datagram(&frame);
        loop {
            match self.socket.recv(&mut buf) {
                Ok(n) => {
                    let Ok(f) = decode_frame(&buf[..n]) else { continue };
                    if f.header.kind == WireKind::JoinAck && f.header.job == self.opts.job {
                        if f.header.aux == JOIN_OK {
                            return Ok(());
                        }
                        bail!("server refused join: status {}", f.header.aux);
                    }
                    // Stray broadcast from an earlier round — ignore.
                }
                Err(e) if is_timeout(&e) => {
                    timeouts += 1;
                    if timeouts > self.opts.max_retries {
                        bail!("join timed out after {timeouts} attempts");
                    }
                    self.stats.retransmissions += 1;
                    self.send_datagram(&frame);
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    fn vote_frames(&self, round: u32, votes: &BitVec, local_max: f32) -> Vec<Vec<u8>> {
        let chunks = vote_chunks(votes, self.opts.payload_budget);
        let n_blocks = chunks.len() as u32;
        chunks
            .iter()
            .enumerate()
            .map(|(i, (dims, bytes))| {
                let header = Header {
                    kind: WireKind::Vote,
                    client: self.opts.client_id,
                    job: self.opts.job,
                    round,
                    block: i as u32,
                    n_blocks,
                    elems: *dims as u32,
                    aux: local_max.to_bits(),
                };
                encode_frame(&header, bytes)
            })
            .collect()
    }

    fn update_frames(&self, round: u32, lanes: &[i32], f: f32) -> Vec<Vec<u8>> {
        let chunks = update_chunks(lanes, self.opts.payload_budget);
        let n_blocks = chunks.len() as u32;
        chunks
            .iter()
            .enumerate()
            .map(|(i, (n, bytes))| {
                let header = Header {
                    kind: WireKind::Update,
                    client: self.opts.client_id,
                    job: self.opts.job,
                    round,
                    block: i as u32,
                    n_blocks,
                    elems: *n as u32,
                    aux: f.to_bits(),
                };
                encode_frame(&header, bytes)
            })
            .collect()
    }

    /// Upload `frames`, then wait for the complete `want` broadcast of
    /// `round`, retransmitting on every timeout. Returns (reassembled
    /// payload bytes, the broadcast's aux word).
    fn exchange(&mut self, round: u32, frames: &[Vec<u8>], want: WireKind) -> Result<(Vec<u8>, u32)> {
        for f in frames {
            self.send_datagram(f);
        }
        let mut asm: Option<ChunkAssembler> = None;
        let mut aux = 0u32;
        let mut buf = vec![0u8; 65536];
        let mut timeouts = 0usize;
        loop {
            match self.socket.recv(&mut buf) {
                Ok(n) => {
                    let Ok(frame) = decode_frame(&buf[..n]) else { continue };
                    let h = frame.header;
                    if h.job != self.opts.job {
                        continue;
                    }
                    if h.kind == want && h.round == round {
                        let a = asm
                            .get_or_insert_with(|| ChunkAssembler::new(h.n_blocks as usize));
                        aux = h.aux;
                        a.insert(h.block as usize, frame.payload);
                        if a.is_complete() {
                            return Ok((asm.take().unwrap().assemble(), aux));
                        }
                    } else if h.kind == WireKind::JoinAck && h.aux == JOIN_UNKNOWN_JOB {
                        // Server lost (or never had) our registration.
                        self.join()?;
                        self.stats.retransmissions += frames.len() as u64;
                        for f in frames {
                            self.send_datagram(f);
                        }
                    }
                    // NotReady / stale rounds / other phases: keep waiting.
                }
                Err(e) if is_timeout(&e) => {
                    timeouts += 1;
                    if timeouts > self.opts.max_retries {
                        bail!(
                            "client {} timed out waiting for {want:?} of round {round} \
                             after {timeouts} timeouts",
                            self.opts.client_id
                        );
                    }
                    self.stats.retransmissions += frames.len() as u64;
                    for f in frames {
                        self.send_datagram(f);
                    }
                    self.stats.polls += 1;
                    let poll = encode_frame(
                        &Header {
                            kind: WireKind::Poll,
                            client: self.opts.client_id,
                            job: self.opts.job,
                            round,
                            block: 0,
                            n_blocks: 0,
                            elems: 0,
                            aux: want as u32,
                        },
                        &[],
                    );
                    self.send_datagram(&poll);
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Execute both FediAC phases for `round` on this client's update
    /// vector (with any residual already folded in by the caller).
    pub fn run_round(&mut self, round: usize, update: &[f32]) -> Result<RoundOutcome> {
        anyhow::ensure!(
            update.len() == self.opts.d,
            "update dimension {} != d {}",
            update.len(),
            self.opts.d
        );
        let retx_before = self.stats.retransmissions;
        let round_u = round as u32;
        let cid = self.opts.client_id as usize;

        // Phase 1: vote, then receive the GIA.
        let votes =
            protocol::client_vote(update, self.opts.k, self.opts.backend_seed, round, cid);
        let local_max = compress::max_abs(update);
        let vote_frames = self.vote_frames(round_u, &votes, local_max);
        let (gia_bytes, gia_aux) = self.exchange(round_u, &vote_frames, WireKind::Gia)?;
        let gia = golomb::decode(&gia_bytes)
            .ok_or_else(|| anyhow::anyhow!("GIA broadcast failed to Golomb-decode"))?;
        anyhow::ensure!(gia.len() == self.opts.d, "GIA length {} != d", gia.len());
        let global_max = f32::from_bits(gia_aux);

        // Phase 2: quantise against the GIA, upload aligned lanes, receive
        // the aggregate.
        let f = compress::scale_factor(self.opts.bits_b, self.opts.n_clients as usize, global_max);
        let (q, residual) = protocol::client_quantize(
            update,
            &gia.to_f32_mask(),
            f,
            self.opts.backend_seed,
            round,
            cid,
        );
        let gia_indices: Vec<usize> = gia.iter_ones().collect();
        let k_s = gia_indices.len();
        let (aggregate, delta) = if k_s == 0 {
            (Vec::new(), Vec::new())
        } else {
            let selected: Vec<i32> = gia_indices.iter().map(|&g| q[g]).collect();
            let update_frames = self.update_frames(round_u, &selected, f);
            let (agg_bytes, agg_aux) =
                self.exchange(round_u, &update_frames, WireKind::Aggregate)?;
            let lanes = decode_lanes(&agg_bytes)
                .map_err(|e| anyhow::anyhow!("aggregate broadcast: {e}"))?;
            anyhow::ensure!(
                lanes.len() == k_s && agg_aux as usize == k_s,
                "aggregate has {} lanes, expected k_S = {k_s}",
                lanes.len()
            );
            let delta =
                compress::dequantize_aggregate(&lanes, self.opts.n_clients as usize, f);
            (lanes, delta)
        };

        Ok(RoundOutcome {
            gia,
            gia_indices,
            global_max,
            scale_f: f,
            aggregate,
            delta,
            residual,
            retransmissions: self.stats.retransmissions - retx_before,
        })
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::{serve, ServeOptions};

    #[test]
    fn options_produce_valid_spec() {
        let opts = ClientOptions::new("127.0.0.1:1", 3, 0, 1000, 4);
        assert!(opts.spec().validate().is_ok());
        assert_eq!(opts.k, 50);
    }

    #[test]
    fn single_client_round_trip() {
        // N = 1, a = 1: the GIA is exactly this client's vote set and the
        // aggregate is its own quantised upload.
        let handle = serve(&ServeOptions::default()).unwrap();
        let mut opts =
            ClientOptions::new(handle.local_addr().to_string(), 77, 0, 300, 1);
        opts.threshold_a = 1;
        opts.payload_budget = 16; // several blocks per phase
        opts.backend_seed = 42;
        let mut client = FediacClient::connect(opts).unwrap();

        let update: Vec<f32> = (0..300).map(|i| ((i as f32) * 0.1).sin() * 0.01).collect();
        let out = client.run_round(1, &update).unwrap();

        let votes = protocol::client_vote(&update, client.options().k, 42, 1, 0);
        assert_eq!(out.gia, votes, "N=1, a=1 ⇒ GIA = own votes");
        let m = compress::max_abs(&update).max(f32::MIN_POSITIVE);
        assert_eq!(out.global_max, m);
        let f = compress::scale_factor(12, 1, m);
        let (q, _) = protocol::client_quantize(&update, &votes.to_f32_mask(), f, 42, 1, 0);
        let want: Vec<i32> = out.gia_indices.iter().map(|&g| q[g]).collect();
        assert_eq!(out.aggregate, want);
        assert_eq!(out.delta.len(), out.aggregate.len());
        handle.shutdown();
    }
}
