//! The networked FediAC client: one UDP socket, two phases, timeout-based
//! retransmission.
//!
//! A round is: upload vote blocks → await the Golomb-coded GIA broadcast →
//! quantise against the GIA → upload aligned i32 lanes → await the
//! aggregate broadcast. Every wait retransmits the phase's frames (and a
//! `Poll`) on timeout; the server's scoreboards make retransmission
//! idempotent, so the driver is safe on lossy links — the `send_loss`
//! option injects exactly the lossy-uplink behaviour `net::trace`
//! scenarios model in simulation, making them runnable end-to-end, and
//! the `chaos` option interposes a full [`crate::net::chaos`] proxy
//! (loss, duplication, reordering, corruption — both directions).

use std::collections::VecDeque;
use std::net::UdpSocket;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::client::protocol;
use crate::compress::{self, golomb};
use crate::net::chaos::{chaos_proxy, ChaosConfig, ChaosHandle, ChaosProxyOptions, ChaosSnapshot};
use crate::net::poll;
use crate::server::{JOIN_OK, JOIN_UNKNOWN_JOB};
use crate::telemetry::HistSummary;
use crate::util::{BitVec, Rng};
use crate::wire::{
    decode_frame, decode_lanes, encode_frame, encode_lanes_into, update_chunk_bounds,
    vote_chunk_bounds, ChunkAssembler, FrameScratch, Header, JobSpec, ShardPlan, WireKind,
    DEFAULT_PAYLOAD_BUDGET, HEADER_LEN, MAX_DATAGRAM,
};

/// Broadcast frames of the *other* phase kept aside during a wait (see
/// [`FediacClient::exchange`]); bounds memory against a babbling server.
const PENDING_CAP: usize = 256;
/// Frames flushed per `sendmmsg(2)` burst on the upload path, and
/// datagrams drained per `recvmmsg(2)` call on the receive path.
const CLIENT_BATCH: usize = 32;

/// Everything a client needs to participate in one job.
#[derive(Debug, Clone)]
pub struct ClientOptions {
    /// Server address, e.g. "127.0.0.1:7177".
    pub server: String,
    /// Job id shared by every client of the job.
    pub job: u32,
    /// This client's id in `[0, n_clients)`.
    pub client_id: u16,
    /// Total clients N in the job (all must agree).
    pub n_clients: u16,
    /// Model dimension d.
    pub d: usize,
    /// Voting threshold a (server-side; part of the shared spec).
    pub threshold_a: u16,
    /// Votes per client k (paper: 5%·d).
    pub k: usize,
    /// Quantisation bits b (Eq. 1 / Corollary 1).
    pub bits_b: usize,
    /// Payload bytes per data frame (must match across the job).
    pub payload_budget: usize,
    /// Backend seed: fixes the vote/quantisation RNG streams so a wire
    /// round reproduces an in-process round bit-exactly.
    pub backend_seed: u64,
    /// Receive timeout before retransmitting a phase.
    pub timeout: Duration,
    /// Timeouts tolerated per wait before giving up.
    pub max_retries: usize,
    /// Probability of dropping an outgoing datagram (lossy-uplink
    /// emulation for tests; 0.0 = reliable).
    pub send_loss: f64,
    /// Run this client through an in-process chaos proxy: loss,
    /// duplication, bounded reordering and bit corruption in either
    /// direction ([`crate::net::chaos`]). `None` = talk to the server
    /// directly.
    pub chaos: Option<ChaosConfig>,
    /// Which slice of a sharded deployment `server` hosts (PROTOCOL.md
    /// §8). [`ShardPlan::single`] for ordinary single-server jobs; the
    /// sharded fan-out driver ([`crate::client::ShardedFediacClient`])
    /// sets it per endpoint, with `d` already narrowed to the sub-model.
    pub shard: ShardPlan,
}

impl ClientOptions {
    /// Sensible defaults for one client of a job (paper k = 5%·d, b = 12).
    pub fn new(server: impl Into<String>, job: u32, client_id: u16, d: usize, n_clients: u16) -> Self {
        ClientOptions {
            server: server.into(),
            job,
            client_id,
            n_clients,
            d,
            threshold_a: 3,
            k: protocol::votes_per_client(d, 0.05),
            bits_b: 12,
            payload_budget: DEFAULT_PAYLOAD_BUDGET,
            backend_seed: 7,
            timeout: Duration::from_millis(200),
            max_retries: 50,
            send_loss: 0.0,
            chaos: None,
            shard: ShardPlan::single(),
        }
    }

    /// The job spec this client will register.
    pub fn spec(&self) -> JobSpec {
        JobSpec {
            d: self.d as u32,
            n_clients: self.n_clients,
            threshold_a: self.threshold_a,
            payload_budget: self.payload_budget as u16,
            shard: self.shard,
        }
    }
}

/// Cumulative driver counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClientStats {
    /// Frames re-sent after a timeout.
    pub retransmissions: u64,
    /// Frames dropped by the loss injector (never hit the wire).
    pub dropped_sends: u64,
    /// Poll frames sent.
    pub polls: u64,
    /// Mid-round re-registrations after a `JOIN_UNKNOWN_JOB` (e.g. the
    /// server restarted or evicted the job).
    pub rejoins: u64,
    /// Broadcast streams restarted because interleaved frames disagreed
    /// on geometry (`n_blocks`) or the aux word.
    pub stream_resets: u64,
    /// Datagram bytes handed to the socket (after the loss injector) —
    /// the `fediac bench-wire` bytes/round numerator, uplink half.
    pub bytes_sent: u64,
    /// Datagram bytes received from the socket (before decoding).
    pub bytes_received: u64,
    /// Vote-phase round trips as seen from this endpoint: first vote
    /// frame sent → GIA decoded (retransmission cycles included).
    pub vote_rtt_us: HistSummary,
    /// Update-phase round trips: first lane frame sent → aggregate
    /// decoded.
    pub update_rtt_us: HistSummary,
}

impl ClientStats {
    /// Fold another endpoint's counters in — the single place that knows
    /// every field, so multi-endpoint aggregation (the sharded driver)
    /// cannot silently drop a counter added later.
    pub fn add(&mut self, other: &ClientStats) {
        self.retransmissions += other.retransmissions;
        self.dropped_sends += other.dropped_sends;
        self.polls += other.polls;
        self.rejoins += other.rejoins;
        self.stream_resets += other.stream_resets;
        self.bytes_sent += other.bytes_sent;
        self.bytes_received += other.bytes_received;
        self.vote_rtt_us.merge(&other.vote_rtt_us);
        self.update_rtt_us.merge(&other.update_rtt_us);
    }
}

/// Result of one completed FediAC round over the wire.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    /// The round's global important-index bitmap.
    pub gia: BitVec,
    /// Ascending selected dimensions (upload order of the lanes).
    pub gia_indices: Vec<usize>,
    /// Global max-|U| the PS folded from all clients (the m in f).
    pub global_max: f32,
    /// Amplification factor f = (2^{b−1} − N)/(N·m).
    pub scale_f: f32,
    /// Aggregated i32 lanes in GIA order.
    pub aggregate: Vec<i32>,
    /// Dequantised aggregate Σq/(N·f), aligned with `gia_indices`.
    pub delta: Vec<f32>,
    /// Residual error e to fold into the next round's update.
    pub residual: Vec<f32>,
    /// Frames retransmitted during this round.
    pub retransmissions: u64,
}

impl RoundOutcome {
    /// Apply w ← w − delta at the selected dimensions, exactly as the
    /// simulated round does (Algorithm 1 line 12).
    pub fn apply(&self, params: &mut [f32]) {
        crate::algorithms::common::apply_sparse_delta(params, &self.gia_indices, &self.delta);
    }
}

/// A connected (joined) FediAC client.
pub struct FediacClient {
    socket: UdpSocket,
    opts: ClientOptions,
    loss_rng: Rng,
    /// Broadcast frames of this round's other phase, captured while
    /// waiting (an empty-consensus round multicasts GIA and aggregate
    /// back-to-back; reordering can also deliver them interleaved).
    pending: Vec<(Header, Vec<u8>)>,
    /// Keeps the per-client chaos proxy (if any) alive for the client's
    /// lifetime.
    chaos: Option<ChaosHandle>,
    /// Datagram-buffer pool for *outgoing* frames: steady-state rounds
    /// encode into recycled buffers instead of allocating.
    scratch: FrameScratch,
    /// Reused serialisation buffers (vote bitmap bytes / lane bytes).
    bitmap_buf: Vec<u8>,
    lane_buf: Vec<u8>,
    /// Pool of *receive* buffers. These stay at full `recv_len` length
    /// for their whole life (datagram size travels alongside as a
    /// separate count), so reuse never re-zeroes the buffer.
    recv_pool: Vec<Vec<u8>>,
    /// Datagrams drained ahead of need by the batched receive
    /// ([`FediacClient::recv_datagram`]), as `(buffer, datagram_len)`;
    /// served before the socket.
    recv_queue: VecDeque<(Vec<u8>, usize)>,
    /// Reusable `recvmmsg` batch (bounded by [`CLIENT_BATCH`] buffers of
    /// [`FediacClient::recv_buf_len`] bytes each).
    batch: poll::RecvBatch,
    /// Every receive buffer's size, from one constant — see
    /// [`FediacClient::recv_buf_len`].
    recv_len: usize,
    /// Cumulative driver counters.
    pub stats: ClientStats,
}

impl FediacClient {
    /// Receive-buffer size for a job with the given payload budget: the
    /// largest frame the job's server can legitimately emit (header +
    /// one full payload budget), capped by what an IPv4/UDP datagram
    /// can physically carry. Every receive path — join wait, exchange
    /// wait, batched drain — is sized from this ONE derivation;
    /// historically the join path used a hardcoded 2048-byte buffer
    /// that silently truncated (and so dropped) any larger frame
    /// arriving during a re-registration.
    pub(crate) fn recv_buf_len(payload_budget: usize) -> usize {
        (HEADER_LEN + payload_budget).min(MAX_DATAGRAM)
    }
    /// Bind an ephemeral socket, connect and register with the server.
    pub fn connect(opts: ClientOptions) -> Result<Self> {
        // `JobSpec` narrows these fields; reject values that would
        // silently truncate (and then disagree with the local chunking).
        anyhow::ensure!(
            opts.payload_budget <= u16::MAX as usize,
            "payload_budget {} exceeds the wire maximum {}",
            opts.payload_budget,
            u16::MAX
        );
        anyhow::ensure!(
            opts.d <= u32::MAX as usize,
            "d {} exceeds the wire maximum {}",
            opts.d,
            u32::MAX
        );
        opts.spec().validate().map_err(|e| anyhow::anyhow!("bad client options: {e}"))?;
        anyhow::ensure!(opts.client_id < opts.n_clients, "client_id out of range");
        anyhow::ensure!(
            (2..=31).contains(&opts.bits_b) && (1i64 << (opts.bits_b - 1)) > opts.n_clients as i64,
            "bits_b={} too small for N={}",
            opts.bits_b,
            opts.n_clients
        );
        // With chaos configured, interpose an in-process proxy between
        // this client and the server; the handle (and its threads) lives
        // as long as the client.
        let mut target = opts.server.clone();
        let chaos = match opts.chaos {
            Some(config) => {
                let handle = chaos_proxy(&ChaosProxyOptions {
                    listen: "127.0.0.1:0".to_string(),
                    upstream: target.clone(),
                    config,
                })
                .context("starting chaos proxy")?;
                target = handle.local_addr().to_string();
                Some(handle)
            }
            None => None,
        };
        let socket = UdpSocket::bind("0.0.0.0:0").context("binding client socket")?;
        socket.connect(&target).with_context(|| format!("connecting to {target}"))?;
        socket.set_read_timeout(Some(opts.timeout))?;
        let loss_rng = Rng::new(opts.backend_seed ^ (opts.client_id as u64) << 40 ^ 0x10_55);
        let recv_len = Self::recv_buf_len(opts.payload_budget);
        let mut client = FediacClient {
            socket,
            opts,
            loss_rng,
            pending: Vec::new(),
            chaos,
            scratch: FrameScratch::new(),
            bitmap_buf: Vec::new(),
            lane_buf: Vec::new(),
            recv_pool: Vec::new(),
            recv_queue: VecDeque::new(),
            batch: poll::RecvBatch::new(CLIENT_BATCH, recv_len),
            recv_len,
            stats: ClientStats::default(),
        };
        client.join()?;
        Ok(client)
    }

    /// The options this client connected with.
    pub fn options(&self) -> &ClientOptions {
        &self.opts
    }

    /// Chaos-proxy counters, when this client runs behind one.
    pub fn chaos_snapshot(&self) -> Option<ChaosSnapshot> {
        self.chaos.as_ref().map(|h| h.snapshot())
    }

    fn send_datagram(&mut self, bytes: &[u8]) {
        if self.opts.send_loss > 0.0 && self.loss_rng.f64() < self.opts.send_loss {
            self.stats.dropped_sends += 1;
            return;
        }
        // Meter only what actually left the host: send() can fail on a
        // connected UDP socket (ICMP-unreachable surfacing as
        // ECONNRESET, ENOBUFS under load).
        if self.socket.send(bytes).is_ok() {
            self.stats.bytes_sent += bytes.len() as u64;
        }
    }

    /// Upload a phase's frame set, flushing in `sendmmsg` bursts of
    /// [`CLIENT_BATCH`] (a plain per-frame loop off Linux). Loss
    /// injection still decides per frame *before* batching, drawing the
    /// RNG in the same per-frame order as the unbatched path, and bytes
    /// are metered only for frames the kernel confirmed sent — the
    /// batch changes syscall count, nothing observable.
    fn send_frames(&mut self, frames: &[Vec<u8>]) {
        let mut refs: Vec<&[u8]> = Vec::with_capacity(frames.len());
        for f in frames {
            if self.opts.send_loss > 0.0 && self.loss_rng.f64() < self.opts.send_loss {
                self.stats.dropped_sends += 1;
            } else {
                refs.push(f);
            }
        }
        let mut start = 0usize;
        while start < refs.len() {
            let burst = &refs[start..(start + CLIENT_BATCH).min(refs.len())];
            match poll::send_batch_connected(&self.socket, burst) {
                Ok(sent) => {
                    for b in &burst[..sent] {
                        self.stats.bytes_sent += b.len() as u64;
                    }
                    if sent < burst.len() {
                        // The frame after the sent prefix was refused:
                        // skip it (one attempt per frame, like the
                        // unbatched loop) and keep going.
                        start += sent + 1;
                    } else {
                        start += burst.len();
                    }
                }
                // Head frame refused outright; skip it.
                Err(_) => start += 1,
            }
        }
    }

    /// Pop a full-length receive buffer (allocated and zeroed once,
    /// then reused as-is — the kernel overwrites the prefix and the
    /// datagram length travels separately, so reuse costs no memset).
    fn take_recv_buf(&mut self) -> Vec<u8> {
        self.recv_pool.pop().unwrap_or_else(|| vec![0u8; self.recv_len])
    }

    /// Return a receive buffer for reuse (bounded; wrong-length buffers
    /// — impossible today — are dropped rather than poisoning the pool).
    fn give_recv_buf(&mut self, buf: Vec<u8>) {
        if buf.len() == self.recv_len && self.recv_pool.len() < 2 * CLIENT_BATCH {
            self.recv_pool.push(buf);
        }
    }

    /// One received datagram as `(buffer, datagram_len)`, from the
    /// drain queue or the socket. The first datagram blocks up to the
    /// socket timeout (`WouldBlock` / `TimedOut` on expiry, exactly
    /// like a bare `recv`); where `recvmmsg` is native, everything
    /// already queued behind it drains in one extra syscall and feeds
    /// subsequent calls without touching the socket. Buffers come from
    /// (and should return to, via [`FediacClient::give_recv_buf`]) the
    /// receive pool.
    fn recv_datagram(&mut self) -> std::io::Result<(Vec<u8>, usize)> {
        if let Some(pair) = self.recv_queue.pop_front() {
            return Ok(pair);
        }
        let mut first = self.take_recv_buf();
        let n = match self.socket.recv(&mut first) {
            Ok(n) => {
                self.stats.bytes_received += n as u64;
                n
            }
            Err(e) => {
                self.give_recv_buf(first);
                return Err(e);
            }
        };
        if poll::MMSG_NATIVE {
            // Opportunistic nonblocking drain: anything the kernel has
            // already queued comes out with one recvmmsg. (Skipped on
            // platforms where the fallback would block.)
            if let Ok(got) = poll::recv_batch(&self.socket, &mut self.batch) {
                for i in 0..got {
                    let (bytes, _) = self.batch.datagram(i);
                    self.stats.bytes_received += bytes.len() as u64;
                    // Copy into a pooled full-length buffer (batch
                    // buffers are `recv_len`-sized, so this always fits).
                    let mut copy = match self.recv_pool.pop() {
                        Some(b) => b,
                        None => vec![0u8; self.recv_len],
                    };
                    copy[..bytes.len()].copy_from_slice(bytes);
                    self.recv_queue.push_back((copy, bytes.len()));
                }
            }
        }
        Ok((first, n))
    }

    /// The (idempotent) registration frame for this client's job.
    fn join_frame(&self) -> Vec<u8> {
        encode_frame(
            &Header::control(WireKind::Join, self.opts.job, self.opts.client_id, 0, 0),
            &self.opts.spec().encode(),
        )
    }

    /// Initial registration with the server. Mid-round re-registration
    /// does NOT use this loop — `exchange` re-joins inline so broadcast
    /// frames of the awaited round keep counting while the Join is in
    /// flight.
    fn join(&mut self) -> Result<()> {
        let frame = self.join_frame();
        let mut timeouts = 0usize;
        self.send_datagram(&frame);
        loop {
            match self.recv_datagram() {
                Ok((buf, n)) => {
                    let decoded = decode_frame(&buf[..n]).map(|f| f.header);
                    self.give_recv_buf(buf);
                    let Ok(h) = decoded else { continue };
                    if h.kind == WireKind::JoinAck && h.job == self.opts.job {
                        if h.aux == JOIN_OK {
                            return Ok(());
                        }
                        bail!("server refused join: status {}", h.aux);
                    }
                    // Stray broadcast from an earlier round — ignore.
                }
                Err(e) if is_timeout(&e) => {
                    timeouts += 1;
                    if timeouts > self.opts.max_retries {
                        bail!("join timed out after {timeouts} attempts");
                    }
                    self.stats.retransmissions += 1;
                    self.send_datagram(&frame);
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Encode one phase's vote frames into pooled buffers (recycled by
    /// the phase driver once the exchange completes).
    fn vote_frames(&mut self, round: u32, votes: &BitVec, local_max: f32) -> Vec<Vec<u8>> {
        votes.copy_bytes_into(&mut self.bitmap_buf);
        let budget = self.opts.payload_budget;
        let n_blocks = vote_chunk_bounds(votes.len(), budget).count() as u32;
        let mut frames = Vec::with_capacity(n_blocks as usize);
        for (i, (dims, lo, hi)) in vote_chunk_bounds(votes.len(), budget).enumerate() {
            let header = Header {
                kind: WireKind::Vote,
                client: self.opts.client_id,
                job: self.opts.job,
                round,
                block: i as u32,
                n_blocks,
                elems: dims as u32,
                aux: local_max.to_bits(),
            };
            frames.push(self.scratch.encode(&header, &self.bitmap_buf[lo..hi]));
        }
        frames
    }

    /// Encode one phase's update frames into pooled buffers, packing
    /// each block's lanes through one reused serialisation buffer
    /// instead of a fresh `encode_lanes` allocation per block.
    fn update_frames(&mut self, round: u32, lanes: &[i32], f: f32) -> Vec<Vec<u8>> {
        let budget = self.opts.payload_budget;
        let n_blocks = update_chunk_bounds(lanes.len(), budget).count() as u32;
        let mut frames = Vec::with_capacity(n_blocks as usize);
        for (i, (lo, hi)) in update_chunk_bounds(lanes.len(), budget).enumerate() {
            encode_lanes_into(&mut self.lane_buf, &lanes[lo..hi]);
            let header = Header {
                kind: WireKind::Update,
                client: self.opts.client_id,
                job: self.opts.job,
                round,
                block: i as u32,
                n_blocks,
                elems: (hi - lo) as u32,
                aux: f.to_bits(),
            };
            frames.push(self.scratch.encode(&header, &self.lane_buf));
        }
        frames
    }

    /// Largest broadcast block count this job could legitimately need:
    /// the aggregate is at most 4·d lane bytes and the Golomb GIA stays
    /// under 2 bits per dimension plus its header for any density the
    /// server-side Rice parameter produces. A frame declaring more
    /// blocks is forged or stale — sizing the assembler from it would
    /// pin unbounded memory.
    fn max_broadcast_blocks(&self) -> usize {
        (16 + 4 * self.opts.d).div_ceil(self.opts.payload_budget).max(1) + 1
    }

    /// Upload `frames`, then wait for the complete `want` broadcast of
    /// `round`, retransmitting on every timeout. Returns (reassembled
    /// payload bytes, the broadcast's aux word).
    ///
    /// Robustness in this loop (all chaos-matrix-proven):
    /// * mixed streams — a frame disagreeing with the in-progress
    ///   assembly on `n_blocks` or `aux` restarts the assembler instead
    ///   of completing with garbage;
    /// * re-join — a `JOIN_UNKNOWN_JOB` ack triggers an *inline* Join so
    ///   wanted broadcast frames arriving meanwhile still count;
    /// * phase overlap — broadcast frames of this round's other phase
    ///   are stashed in `pending` for the next wait instead of being
    ///   dropped into a retransmission cycle.
    fn exchange(&mut self, round: u32, frames: &[Vec<u8>], want: WireKind) -> Result<(Vec<u8>, u32)> {
        let max_blocks = self.max_broadcast_blocks();
        let mut asm: Option<(ChunkAssembler, u32)> = None;
        // Drain stashed frames from the previous wait of this round.
        self.pending.retain(|(h, _)| h.round == round);
        let (mine, keep): (Vec<_>, Vec<_>) =
            std::mem::take(&mut self.pending).into_iter().partition(|(h, _)| h.kind == want);
        self.pending = keep;
        for (h, payload) in mine {
            if let Some(done) = ingest_chunk(&mut asm, max_blocks, &h, &payload, &mut self.stats)
            {
                return Ok(done);
            }
        }
        self.send_frames(frames);
        let join_frame = self.join_frame();
        let mut rejoining = false;
        let mut timeouts = 0usize;
        loop {
            match self.recv_datagram() {
                Ok((buf, n)) => {
                    // `'done: Some(v)` completes the exchange; any other
                    // path falls through so the buffer recycles first.
                    let done = 'frame: {
                        let Ok(frame) = decode_frame(&buf[..n]) else { break 'frame None };
                        let h = frame.header;
                        if h.job != self.opts.job {
                            break 'frame None;
                        }
                        if h.kind == want && h.round == round {
                            break 'frame ingest_chunk(
                                &mut asm,
                                max_blocks,
                                &h,
                                frame.payload,
                                &mut self.stats,
                            );
                        } else if (h.kind == WireKind::Gia || h.kind == WireKind::Aggregate)
                            && h.round == round
                        {
                            // The other phase's broadcast for this round:
                            // keep it for the next exchange.
                            if self.pending.len() < PENDING_CAP {
                                self.pending.push((h, frame.payload.to_vec()));
                            }
                        } else if h.kind == WireKind::JoinAck {
                            match h.aux {
                                JOIN_UNKNOWN_JOB => {
                                    // Server lost (or never had) our
                                    // registration; re-join without leaving
                                    // this receive loop.
                                    if !rejoining {
                                        rejoining = true;
                                        self.stats.rejoins += 1;
                                        crate::debug!(
                                            "job={} client={} round={round} re-joining after \
                                             UNKNOWN_JOB",
                                            self.opts.job,
                                            self.opts.client_id
                                        );
                                        self.send_datagram(&join_frame);
                                    }
                                }
                                JOIN_OK if rejoining => {
                                    // Re-registered. The server may have lost
                                    // every round state too — re-upload this
                                    // phase's frames.
                                    rejoining = false;
                                    self.stats.retransmissions += frames.len() as u64;
                                    self.send_frames(frames);
                                }
                                JOIN_OK => {} // duplicate ack of an earlier join
                                status if rejoining => {
                                    bail!("server refused re-join: status {status}")
                                }
                                // Unsolicited non-OK ack (spoof or stale):
                                // only a refusal of *our* in-flight re-join
                                // may kill the round.
                                _ => {}
                            }
                        }
                        // NotReady / stale rounds / other phases: keep waiting.
                        None
                    };
                    self.give_recv_buf(buf);
                    if let Some(done) = done {
                        return Ok(done);
                    }
                }
                Err(e) if is_timeout(&e) => {
                    timeouts += 1;
                    if timeouts > self.opts.max_retries {
                        bail!(
                            "client {} timed out waiting for {want:?} of round {round} \
                             after {timeouts} timeouts",
                            self.opts.client_id
                        );
                    }
                    crate::debug!(
                        "job={} client={} round={round} timeout #{timeouts}: retransmitting \
                         {} frames and polling for {want:?}",
                        self.opts.job,
                        self.opts.client_id,
                        frames.len()
                    );
                    if rejoining {
                        // The in-flight Join (or its ack) was lost.
                        self.stats.retransmissions += 1;
                        self.send_datagram(&join_frame);
                    }
                    self.stats.retransmissions += frames.len() as u64;
                    self.send_frames(frames);
                    self.stats.polls += 1;
                    let poll_hdr = Header {
                        kind: WireKind::Poll,
                        client: self.opts.client_id,
                        job: self.opts.job,
                        round,
                        block: 0,
                        n_blocks: 0,
                        elems: 0,
                        aux: want as u32,
                    };
                    let poll_frame = self.scratch.encode(&poll_hdr, &[]);
                    self.send_datagram(&poll_frame);
                    self.scratch.give(poll_frame);
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Run phase 1 over the wire: upload the vote bitmap blocks, await
    /// the Golomb-coded GIA broadcast and return the decoded GIA (over
    /// this endpoint's `d`) plus the server-folded global max-|U|.
    ///
    /// `run_round` drives this with the full-model vote; the sharded
    /// fan-out driver ([`crate::client::ShardedFediacClient`]) calls it
    /// per shard with sub-model bitmaps.
    pub fn vote_phase(
        &mut self,
        round: u32,
        votes: &BitVec,
        local_max: f32,
    ) -> Result<(BitVec, f32)> {
        anyhow::ensure!(
            votes.len() == self.opts.d,
            "vote bitmap length {} != d {}",
            votes.len(),
            self.opts.d
        );
        let t0 = Instant::now();
        let vote_frames = self.vote_frames(round, votes, local_max);
        let exchanged = self.exchange(round, &vote_frames, WireKind::Gia);
        for f in vote_frames {
            self.scratch.give(f);
        }
        let (gia_bytes, gia_aux) = exchanged?;
        self.stats.vote_rtt_us.record_micros(t0.elapsed());
        let gia = golomb::decode_with_limit(&gia_bytes, self.opts.d)
            .ok_or_else(|| anyhow::anyhow!("GIA broadcast failed to Golomb-decode"))?;
        anyhow::ensure!(gia.len() == self.opts.d, "GIA length {} != d", gia.len());
        let global_max = f32::from_bits(gia_aux);
        anyhow::ensure!(
            global_max.is_finite() && global_max > 0.0,
            "GIA broadcast carried a non-finite global max ({global_max})"
        );
        Ok((gia, global_max))
    }

    /// Run phase 2 over the wire: upload the GIA-aligned quantised lanes,
    /// await the aggregate broadcast and return the summed lanes (same
    /// order and length as `lanes`). An empty `lanes` still uploads the
    /// zero-lane completion block and awaits the empty aggregate —
    /// skipping it would leave the two sides disagreeing on whether the
    /// round happened at all.
    pub fn update_phase(&mut self, round: u32, lanes: &[i32], f: f32) -> Result<Vec<i32>> {
        let t0 = Instant::now();
        let update_frames = self.update_frames(round, lanes, f);
        let exchanged = self.exchange(round, &update_frames, WireKind::Aggregate);
        for f in update_frames {
            self.scratch.give(f);
        }
        let (agg_bytes, agg_aux) = exchanged?;
        self.stats.update_rtt_us.record_micros(t0.elapsed());
        let aggregate = decode_lanes(&agg_bytes)
            .map_err(|e| anyhow::anyhow!("aggregate broadcast: {e}"))?;
        anyhow::ensure!(
            aggregate.len() == lanes.len() && agg_aux as usize == lanes.len(),
            "aggregate has {} lanes, expected k_S = {}",
            aggregate.len(),
            lanes.len()
        );
        Ok(aggregate)
    }

    /// Execute both FediAC phases for `round` on this client's update
    /// vector (with any residual already folded in by the caller).
    pub fn run_round(&mut self, round: usize, update: &[f32]) -> Result<RoundOutcome> {
        anyhow::ensure!(
            update.len() == self.opts.d,
            "update dimension {} != d {}",
            update.len(),
            self.opts.d
        );
        let retx_before = self.stats.retransmissions;
        let round_u = round as u32;
        let cid = self.opts.client_id as usize;

        // Phase 1: vote, then receive the GIA.
        let votes =
            protocol::client_vote(update, self.opts.k, self.opts.backend_seed, round, cid);
        let local_max = compress::max_abs(update);
        let (gia, global_max) = self.vote_phase(round_u, &votes, local_max)?;

        // Phase 2: quantise against the GIA, upload aligned lanes, receive
        // the aggregate (phase 2 runs even on an empty consensus — see
        // `update_phase`).
        let f = compress::scale_factor(self.opts.bits_b, self.opts.n_clients as usize, global_max);
        let (q, residual) = protocol::client_quantize(
            update,
            &gia.to_f32_mask(),
            f,
            self.opts.backend_seed,
            round,
            cid,
        );
        let gia_indices: Vec<usize> = gia.iter_ones().collect();
        let selected: Vec<i32> = gia_indices.iter().map(|&g| q[g]).collect();
        let aggregate = self.update_phase(round_u, &selected, f)?;
        let delta = compress::dequantize_aggregate(&aggregate, self.opts.n_clients as usize, f);

        Ok(RoundOutcome {
            gia,
            gia_indices,
            global_max,
            scale_f: f,
            aggregate,
            delta,
            residual,
            retransmissions: self.stats.retransmissions - retx_before,
        })
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Feed one broadcast chunk into the (lazily created) assembler. Frames
/// are cross-checked against the stream in progress: a different
/// `n_blocks` or aux word means two broadcasts are interleaved (a stale
/// or truncated-spec stream mixed with the real one) — the assembler
/// restarts from the newer frame instead of completing with chunks from
/// both. Implausibly large geometry is ignored outright. Returns the
/// reassembled payload and aux once complete.
fn ingest_chunk(
    asm: &mut Option<(ChunkAssembler, u32)>,
    max_blocks: usize,
    h: &Header,
    payload: &[u8],
    stats: &mut ClientStats,
) -> Option<(Vec<u8>, u32)> {
    let n_blocks = h.n_blocks as usize;
    if n_blocks == 0 || n_blocks > max_blocks {
        return None;
    }
    if asm.as_ref().is_some_and(|(a, aux)| a.n_blocks() != n_blocks || *aux != h.aux) {
        stats.stream_resets += 1;
        crate::debug!(
            "job={} round={} {:?} stream reset: interleaved broadcast disagrees on geometry/aux",
            h.job,
            h.round,
            h.kind
        );
        *asm = None;
    }
    let (a, _) = asm.get_or_insert_with(|| (ChunkAssembler::new(n_blocks), h.aux));
    a.insert(h.block as usize, payload);
    if a.is_complete() {
        let (a, aux) = asm.take().expect("assembler just used");
        Some((a.assemble(), aux))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::chaos::ChaosDirection;
    use crate::server::{serve, ServeOptions};
    use crate::wire::byte_chunks;

    #[test]
    fn options_produce_valid_spec() {
        let opts = ClientOptions::new("127.0.0.1:1", 3, 0, 1000, 4);
        assert!(opts.spec().validate().is_ok());
        assert_eq!(opts.k, 50);
    }

    fn bcast_header(n_blocks: u32, block: u32, aux: u32) -> Header {
        Header {
            kind: WireKind::Gia,
            client: u16::MAX,
            job: 1,
            round: 1,
            block,
            n_blocks,
            elems: 0,
            aux,
        }
    }

    #[test]
    fn ingest_chunk_resets_on_mixed_streams() {
        let mut stats = ClientStats::default();
        let data: Vec<u8> = (0..=89u8).collect();
        let chunks = byte_chunks(&data, 30); // 3 chunks
        let mut asm: Option<(ChunkAssembler, u32)> = None;

        // Two chunks of the real stream…
        assert!(ingest_chunk(&mut asm, 100, &bcast_header(3, 0, 7), &chunks[0], &mut stats)
            .is_none());
        assert!(ingest_chunk(&mut asm, 100, &bcast_header(3, 2, 7), &chunks[2], &mut stats)
            .is_none());
        // …then a stale broadcast with different geometry interleaves:
        // the assembler must restart, not mix chunks from both streams.
        assert!(ingest_chunk(&mut asm, 100, &bcast_header(2, 0, 7), &[1, 2], &mut stats)
            .is_none());
        assert_eq!(stats.stream_resets, 1);
        // A frame agreeing on geometry but not on aux also resets.
        assert!(ingest_chunk(&mut asm, 100, &bcast_header(2, 1, 9), &[3, 4], &mut stats)
            .is_none());
        assert_eq!(stats.stream_resets, 2);
        // The real stream, uninterrupted, completes with the right bytes
        // (nothing from the interleaved impostors survives).
        for (i, c) in chunks.iter().enumerate() {
            if let Some(done) =
                ingest_chunk(&mut asm, 100, &bcast_header(3, i as u32, 7), c, &mut stats)
            {
                assert_eq!(i, 2, "completed early");
                assert_eq!(done, (data.clone(), 7));
                assert_eq!(stats.stream_resets, 3);
                return;
            }
        }
        panic!("real stream never completed");
    }

    #[test]
    fn ingest_chunk_ignores_implausible_geometry() {
        let mut stats = ClientStats::default();
        let mut asm: Option<(ChunkAssembler, u32)> = None;
        // A forged frame declaring 2^31 blocks must not size the
        // assembler (that would be a multi-gigabyte allocation).
        let h = bcast_header(1 << 31, 0, 0);
        assert!(ingest_chunk(&mut asm, 64, &h, &[], &mut stats).is_none());
        assert!(asm.is_none());
        assert!(ingest_chunk(&mut asm, 64, &bcast_header(0, 0, 0), &[], &mut stats).is_none());
        assert!(asm.is_none());
    }

    #[test]
    fn recv_buffer_constant_admits_a_max_size_frame() {
        use crate::wire::MAX_WIRE_PAYLOAD;
        // The largest frame a job at this budget can emit must round-trip
        // a real socket through a buffer of exactly the derived size. The
        // old join path hardcoded 2048 bytes, which would have truncated
        // (and so silently dropped) this frame.
        let budget = 60_000usize;
        let frame = encode_frame(
            &Header {
                kind: WireKind::Gia,
                client: u16::MAX,
                job: 1,
                round: 1,
                block: 0,
                n_blocks: 1,
                elems: budget as u32,
                aux: 0,
            },
            &vec![0xAB; budget],
        );
        assert!(frame.len() > 2048, "frame too small to regress the old path");
        assert!(frame.len() <= FediacClient::recv_buf_len(budget));
        let rx = UdpSocket::bind("127.0.0.1:0").unwrap();
        rx.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let tx = UdpSocket::bind("127.0.0.1:0").unwrap();
        tx.send_to(&frame, rx.local_addr().unwrap()).unwrap();
        let mut buf = vec![0u8; FediacClient::recv_buf_len(budget)];
        let (n, _) = rx.recv_from(&mut buf).unwrap();
        assert_eq!(n, frame.len(), "frame truncated by the derived buffer size");
        let decoded = decode_frame(&buf[..n]).unwrap();
        assert_eq!(decoded.payload.len(), budget);
        // The derivation is capped by what UDP/IPv4 can physically carry,
        // so no budget can ever outgrow the buffer.
        assert!(FediacClient::recv_buf_len(MAX_WIRE_PAYLOAD) <= crate::wire::MAX_DATAGRAM);
        assert!(crate::wire::HEADER_LEN + MAX_WIRE_PAYLOAD <= crate::wire::MAX_DATAGRAM);
    }

    #[test]
    fn round_with_frames_beyond_the_old_join_buffer() {
        // End-to-end round whose vote/GIA/aggregate frames all exceed the
        // old 2048-byte join-path buffer: every receive path must use the
        // shared sizing or the round stalls on truncated broadcasts.
        let handle = serve(&ServeOptions::default()).unwrap();
        let mut opts =
            ClientOptions::new(handle.local_addr().to_string(), 81, 0, 80_000, 1);
        opts.threshold_a = 1;
        opts.payload_budget = 4096;
        opts.backend_seed = 21;
        let mut client = FediacClient::connect(opts).unwrap();
        let update: Vec<f32> = (0..80_000).map(|i| ((i as f32) * 0.01).sin() * 0.01).collect();
        let out = client.run_round(1, &update).unwrap();
        assert!(!out.gia_indices.is_empty());
        assert_eq!(out.aggregate.len(), out.gia_indices.len());
        handle.shutdown();
    }

    #[test]
    fn single_client_round_trip() {
        // N = 1, a = 1: the GIA is exactly this client's vote set and the
        // aggregate is its own quantised upload.
        let handle = serve(&ServeOptions::default()).unwrap();
        let mut opts =
            ClientOptions::new(handle.local_addr().to_string(), 77, 0, 300, 1);
        opts.threshold_a = 1;
        opts.payload_budget = 16; // several blocks per phase
        opts.backend_seed = 42;
        let mut client = FediacClient::connect(opts).unwrap();

        let update: Vec<f32> = (0..300).map(|i| ((i as f32) * 0.1).sin() * 0.01).collect();
        let out = client.run_round(1, &update).unwrap();

        let votes = protocol::client_vote(&update, client.options().k, 42, 1, 0);
        assert_eq!(out.gia, votes, "N=1, a=1 ⇒ GIA = own votes");
        let m = compress::max_abs(&update).max(f32::MIN_POSITIVE);
        assert_eq!(out.global_max, m);
        let f = compress::scale_factor(12, 1, m);
        let (q, _) = protocol::client_quantize(&update, &votes.to_f32_mask(), f, 42, 1, 0);
        let want: Vec<i32> = out.gia_indices.iter().map(|&g| q[g]).collect();
        assert_eq!(out.aggregate, want);
        assert_eq!(out.delta.len(), out.aggregate.len());
        handle.shutdown();
    }

    #[test]
    fn chaos_knob_runs_the_client_behind_a_proxy() {
        let handle = serve(&ServeOptions::default()).unwrap();
        let mut opts = ClientOptions::new(handle.local_addr().to_string(), 78, 0, 200, 1);
        opts.threshold_a = 1;
        opts.payload_budget = 16;
        opts.backend_seed = 9;
        opts.timeout = Duration::from_millis(100);
        opts.chaos = Some(ChaosConfig::symmetric(3, ChaosDirection::lossy(0.15, 0.1, 0.2)));
        let mut client = FediacClient::connect(opts).unwrap();

        let update: Vec<f32> = (0..200).map(|i| ((i as f32) * 0.2).cos() * 0.01).collect();
        let out = client.run_round(1, &update).unwrap();
        let votes = protocol::client_vote(&update, client.options().k, 9, 1, 0);
        assert_eq!(out.gia, votes, "chaos changed the consensus");

        let snap = client.chaos_snapshot().expect("proxy attached");
        assert_eq!(snap.flows, 1);
        assert!(snap.up.forwarded > 0 && snap.down.forwarded > 0);
        handle.shutdown();
    }
}
