//! The blocking FediAC client driver: one UDP socket, one
//! [`ClientCore`], timeout-based retransmission.
//!
//! Since the sans-I/O refactor every protocol decision — join/re-join,
//! phase uploads, broadcast reassembly, retransmission and `Poll` —
//! lives in [`crate::client::core`]; this file only owns the socket,
//! the clock and the buffers. A round is: feed the core's emitted
//! frames to the socket (through the optional loss lane), feed received
//! datagrams back to the core, and surface the core's [`Progress`]
//! events as the same public API (`join`/`vote_phase`/`update_phase`/
//! `run_round`) the driver has always had. The server's scoreboards
//! make retransmission idempotent, so the driver is safe on lossy
//! links — the `send_loss` option is now a thin alias for an uplink
//! [`crate::net::chaos::ChaosLane`] with only the drop knob set (one
//! loss implementation in the tree), and the `chaos` option interposes
//! a full [`crate::net::chaos`] proxy (loss, duplication, reordering,
//! corruption — both directions).

use std::collections::VecDeque;
use std::net::UdpSocket;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::client::core::{ClientCore, ClientOutput, ClientStats, CoreConfig, Progress};
use crate::client::protocol;
use crate::compress;
use crate::net::chaos::{
    chaos_proxy, ChaosConfig, ChaosDirection, ChaosHandle, ChaosLane, ChaosProxyOptions,
    ChaosSnapshot,
};
use crate::net::poll;
use crate::util::BitVec;
use crate::wire::{JobSpec, ShardPlan, DEFAULT_PAYLOAD_BUDGET, HEADER_LEN, MAX_DATAGRAM};

/// Frames flushed per `sendmmsg(2)` burst on the upload path, and
/// datagrams drained per `recvmmsg(2)` call on the receive path.
const CLIENT_BATCH: usize = 32;

/// Everything a client needs to participate in one job.
#[derive(Debug, Clone)]
pub struct ClientOptions {
    /// Server address, e.g. "127.0.0.1:7177".
    pub server: String,
    /// Job id shared by every client of the job.
    pub job: u32,
    /// This client's id in `[0, n_clients)`.
    pub client_id: u16,
    /// Total clients N in the job (all must agree).
    pub n_clients: u16,
    /// Model dimension d.
    pub d: usize,
    /// Voting threshold a (server-side; part of the shared spec).
    pub threshold_a: u16,
    /// Votes per client k (paper: 5%·d).
    pub k: usize,
    /// Quantisation bits b (Eq. 1 / Corollary 1).
    pub bits_b: usize,
    /// Payload bytes per data frame (must match across the job).
    pub payload_budget: usize,
    /// Backend seed: fixes the vote/quantisation RNG streams so a wire
    /// round reproduces an in-process round bit-exactly.
    pub backend_seed: u64,
    /// Receive timeout before retransmitting a phase.
    pub timeout: Duration,
    /// Timeouts tolerated per wait before giving up.
    pub max_retries: usize,
    /// Probability of dropping an outgoing datagram (lossy-uplink
    /// emulation for tests; 0.0 = reliable). A config alias for a
    /// drop-only uplink [`ChaosLane`] — the drops land in
    /// [`ClientStats::dropped_sends`] straight from the lane's counters.
    pub send_loss: f64,
    /// Run this client through an in-process chaos proxy: loss,
    /// duplication, bounded reordering and bit corruption in either
    /// direction ([`crate::net::chaos`]). `None` = talk to the server
    /// directly.
    pub chaos: Option<ChaosConfig>,
    /// Which slice of a sharded deployment `server` hosts (PROTOCOL.md
    /// §8). [`ShardPlan::single`] for ordinary single-server jobs; the
    /// sharded fan-out driver ([`crate::client::ShardedFediacClient`])
    /// sets it per endpoint, with `d` already narrowed to the sub-model.
    pub shard: ShardPlan,
    /// Round-closure quorum Q registered with the job (0 = legacy
    /// all-N rounds; see PROTOCOL.md §11). Must match across the job
    /// like every other spec field.
    pub quorum: u16,
}

impl ClientOptions {
    /// Sensible defaults for one client of a job (paper k = 5%·d, b = 12).
    pub fn new(server: impl Into<String>, job: u32, client_id: u16, d: usize, n_clients: u16) -> Self {
        ClientOptions {
            server: server.into(),
            job,
            client_id,
            n_clients,
            d,
            threshold_a: 3,
            k: protocol::votes_per_client(d, 0.05),
            bits_b: 12,
            payload_budget: DEFAULT_PAYLOAD_BUDGET,
            backend_seed: 7,
            timeout: Duration::from_millis(200),
            max_retries: 50,
            send_loss: 0.0,
            chaos: None,
            shard: ShardPlan::single(),
            quorum: 0,
        }
    }

    /// The job spec this client will register.
    pub fn spec(&self) -> JobSpec {
        JobSpec {
            d: self.d as u32,
            n_clients: self.n_clients,
            threshold_a: self.threshold_a,
            payload_budget: self.payload_budget as u16,
            shard: self.shard,
            quorum: self.quorum,
        }
    }

    /// The transport subset of these options, as the [`ClientCore`]
    /// config (drops the server address, round math and chaos knobs —
    /// those belong to whichever driver owns the I/O).
    pub fn core_config(&self) -> CoreConfig {
        CoreConfig {
            job: self.job,
            client_id: self.client_id,
            n_clients: self.n_clients,
            d: self.d,
            threshold_a: self.threshold_a,
            payload_budget: self.payload_budget,
            timeout: self.timeout,
            max_retries: self.max_retries,
            shard: self.shard,
            quorum: self.quorum,
        }
    }
}

/// Result of one completed FediAC round over the wire.
#[derive(Debug, Clone)]
pub struct RoundOutcome {
    /// The round's global important-index bitmap.
    pub gia: BitVec,
    /// Ascending selected dimensions (upload order of the lanes).
    pub gia_indices: Vec<usize>,
    /// Global max-|U| the PS folded from all clients (the m in f).
    pub global_max: f32,
    /// Amplification factor f = (2^{b−1} − N)/(N·m).
    pub scale_f: f32,
    /// Aggregated i32 lanes in GIA order.
    pub aggregate: Vec<i32>,
    /// Dequantised aggregate Σq/(N·f), aligned with `gia_indices`.
    pub delta: Vec<f32>,
    /// Residual error e to fold into the next round's update.
    pub residual: Vec<f32>,
    /// Frames retransmitted during this round.
    pub retransmissions: u64,
}

impl RoundOutcome {
    /// Apply w ← w − delta at the selected dimensions, exactly as the
    /// simulated round does (Algorithm 1 line 12).
    pub fn apply(&self, params: &mut [f32]) {
        crate::algorithms::common::apply_sparse_delta(params, &self.gia_indices, &self.delta);
    }
}

/// A connected (joined) FediAC client: the blocking driver over one
/// [`ClientCore`]. All protocol behaviour lives in the core; this
/// struct only moves bytes and time.
pub struct FediacClient {
    socket: UdpSocket,
    opts: ClientOptions,
    /// The sans-I/O protocol state machine.
    core: ClientCore,
    /// Uplink loss injection (`send_loss` alias): a drop-only
    /// [`ChaosLane`], present only when the knob is nonzero so the
    /// reliable path stays copy-free.
    loss_lane: Option<ChaosLane<()>>,
    /// Keeps the per-client chaos proxy (if any) alive for the client's
    /// lifetime.
    chaos: Option<ChaosHandle>,
    /// Pool of *receive* buffers. These stay at full `recv_len` length
    /// for their whole life (datagram size travels alongside as a
    /// separate count), so reuse never re-zeroes the buffer.
    recv_pool: Vec<Vec<u8>>,
    /// Datagrams drained ahead of need by the batched receive
    /// ([`FediacClient::recv_datagram`]), as `(buffer, datagram_len)`;
    /// served before the socket.
    recv_queue: VecDeque<(Vec<u8>, usize)>,
    /// Reusable `recvmmsg` batch (bounded by [`CLIENT_BATCH`] buffers of
    /// [`FediacClient::recv_buf_len`] bytes each).
    batch: poll::RecvBatch,
    /// Every receive buffer's size, from one constant — see
    /// [`FediacClient::recv_buf_len`].
    recv_len: usize,
    /// Datagram bytes confirmed sent / received by this socket (the
    /// I/O half of [`ClientStats`]; the core owns the protocol half).
    io_bytes_sent: u64,
    io_bytes_received: u64,
    /// Cumulative driver counters, refreshed from the core + the I/O
    /// meters at every public-API boundary.
    pub stats: ClientStats,
}

impl FediacClient {
    /// Receive-buffer size for a job with the given payload budget: the
    /// largest frame the job's server can legitimately emit (header +
    /// one full payload budget), capped by what an IPv4/UDP datagram
    /// can physically carry. Every receive path — join wait, exchange
    /// wait, batched drain — is sized from this ONE derivation;
    /// historically the join path used a hardcoded 2048-byte buffer
    /// that silently truncated (and so dropped) any larger frame
    /// arriving during a re-registration.
    pub(crate) fn recv_buf_len(payload_budget: usize) -> usize {
        (HEADER_LEN + payload_budget).min(MAX_DATAGRAM)
    }
    /// Bind an ephemeral socket, connect and register with the server.
    pub fn connect(opts: ClientOptions) -> Result<Self> {
        // `JobSpec` narrows these fields; reject values that would
        // silently truncate (and then disagree with the local chunking).
        anyhow::ensure!(
            opts.payload_budget <= u16::MAX as usize,
            "payload_budget {} exceeds the wire maximum {}",
            opts.payload_budget,
            u16::MAX
        );
        anyhow::ensure!(
            opts.d <= u32::MAX as usize,
            "d {} exceeds the wire maximum {}",
            opts.d,
            u32::MAX
        );
        opts.spec().validate().map_err(|e| anyhow::anyhow!("bad client options: {e}"))?;
        anyhow::ensure!(opts.client_id < opts.n_clients, "client_id out of range");
        anyhow::ensure!(
            (2..=31).contains(&opts.bits_b) && (1i64 << (opts.bits_b - 1)) > opts.n_clients as i64,
            "bits_b={} too small for N={}",
            opts.bits_b,
            opts.n_clients
        );
        // With chaos configured, interpose an in-process proxy between
        // this client and the server; the handle (and its threads) lives
        // as long as the client.
        let mut target = opts.server.clone();
        let chaos = match opts.chaos {
            Some(config) => {
                let handle = chaos_proxy(&ChaosProxyOptions {
                    listen: "127.0.0.1:0".to_string(),
                    upstream: target.clone(),
                    config,
                })
                .context("starting chaos proxy")?;
                target = handle.local_addr().to_string();
                Some(handle)
            }
            None => None,
        };
        let socket = UdpSocket::bind("0.0.0.0:0").context("binding client socket")?;
        socket.connect(&target).with_context(|| format!("connecting to {target}"))?;
        socket.set_read_timeout(Some(opts.timeout))?;
        // `send_loss` rides the generic chaos lane (drop knob only),
        // seeded exactly as the old bespoke injector was.
        let loss_lane = (opts.send_loss > 0.0).then(|| {
            ChaosLane::new(
                ChaosDirection { drop: opts.send_loss, ..ChaosDirection::clean() },
                opts.backend_seed ^ (opts.client_id as u64) << 40 ^ 0x10_55,
            )
        });
        let recv_len = Self::recv_buf_len(opts.payload_budget);
        let core = ClientCore::new(opts.core_config());
        let mut client = FediacClient {
            socket,
            opts,
            core,
            loss_lane,
            chaos,
            recv_pool: Vec::new(),
            recv_queue: VecDeque::new(),
            batch: poll::RecvBatch::new(CLIENT_BATCH, recv_len),
            recv_len,
            io_bytes_sent: 0,
            io_bytes_received: 0,
            stats: ClientStats::default(),
        };
        client.join()?;
        Ok(client)
    }

    /// The options this client connected with.
    pub fn options(&self) -> &ClientOptions {
        &self.opts
    }

    /// Chaos-proxy counters, when this client runs behind one.
    pub fn chaos_snapshot(&self) -> Option<ChaosSnapshot> {
        self.chaos.as_ref().map(|h| h.snapshot())
    }

    /// Refresh the public `stats` field: protocol counters from the
    /// core, byte meters from the socket path, drops from the loss
    /// lane. Called at every public-API boundary so tests can keep
    /// reading `client.stats` directly.
    fn sync_stats(&mut self) {
        let mut s = self.core.stats;
        s.bytes_sent = self.io_bytes_sent;
        s.bytes_received = self.io_bytes_received;
        s.dropped_sends =
            self.loss_lane.as_ref().map_or(0, |l| l.stats().dropped.load(Ordering::Relaxed));
        self.stats = s;
    }

    /// Transmit the core's emitted frames: per-frame loss-lane verdicts
    /// in emission order, then `sendmmsg` bursts of [`CLIENT_BATCH`] (a
    /// plain loop off Linux). Bytes are metered only for frames the
    /// kernel confirmed sent; a refused frame is skipped (one attempt
    /// per frame), and every buffer goes back to the core's pool.
    fn transmit(&mut self, frames: Vec<Vec<u8>>) {
        if frames.is_empty() {
            return;
        }
        if self.loss_lane.is_some() {
            let now = Instant::now();
            let mut wire: Vec<Vec<u8>> = Vec::with_capacity(frames.len());
            let lane = self.loss_lane.as_mut().expect("just checked");
            for f in &frames {
                // Drop-only lane: 0 or 1 packets out, never held.
                wire.extend(lane.process(f, (), now).into_iter().map(|(pkt, ())| pkt));
            }
            let refs: Vec<&[u8]> = wire.iter().map(|v| v.as_slice()).collect();
            self.send_refs(&refs);
        } else {
            let refs: Vec<&[u8]> = frames.iter().map(|v| v.as_slice()).collect();
            self.send_refs(&refs);
        }
        for f in frames {
            self.core.recycle(f);
        }
    }

    /// Burst-send pre-encoded datagrams on the connected socket,
    /// metering confirmed bytes.
    fn send_refs(&mut self, refs: &[&[u8]]) {
        let mut start = 0usize;
        while start < refs.len() {
            let burst = &refs[start..(start + CLIENT_BATCH).min(refs.len())];
            match poll::send_batch_connected(&self.socket, burst) {
                Ok(sent) => {
                    for b in &burst[..sent] {
                        self.io_bytes_sent += b.len() as u64;
                    }
                    if sent < burst.len() {
                        // The frame after the sent prefix was refused:
                        // skip it (one attempt per frame) and keep going.
                        start += sent + 1;
                    } else {
                        start += burst.len();
                    }
                }
                // Head frame refused outright; skip it.
                Err(_) => start += 1,
            }
        }
    }

    /// Pop a full-length receive buffer (allocated and zeroed once,
    /// then reused as-is — the kernel overwrites the prefix and the
    /// datagram length travels separately, so reuse costs no memset).
    fn take_recv_buf(&mut self) -> Vec<u8> {
        self.recv_pool.pop().unwrap_or_else(|| vec![0u8; self.recv_len])
    }

    /// Return a receive buffer for reuse (bounded; wrong-length buffers
    /// — impossible today — are dropped rather than poisoning the pool).
    fn give_recv_buf(&mut self, buf: Vec<u8>) {
        if buf.len() == self.recv_len && self.recv_pool.len() < 2 * CLIENT_BATCH {
            self.recv_pool.push(buf);
        }
    }

    /// One received datagram as `(buffer, datagram_len)`, from the
    /// drain queue or the socket. The first datagram blocks up to the
    /// socket timeout (`WouldBlock` / `TimedOut` on expiry, exactly
    /// like a bare `recv`); where `recvmmsg` is native, everything
    /// already queued behind it drains in one extra syscall and feeds
    /// subsequent calls without touching the socket. Buffers come from
    /// (and should return to, via [`FediacClient::give_recv_buf`]) the
    /// receive pool.
    fn recv_datagram(&mut self) -> std::io::Result<(Vec<u8>, usize)> {
        if let Some(pair) = self.recv_queue.pop_front() {
            return Ok(pair);
        }
        let mut first = self.take_recv_buf();
        let n = match self.socket.recv(&mut first) {
            Ok(n) => {
                self.io_bytes_received += n as u64;
                n
            }
            Err(e) => {
                self.give_recv_buf(first);
                return Err(e);
            }
        };
        if poll::MMSG_NATIVE {
            // Opportunistic nonblocking drain: anything the kernel has
            // already queued comes out with one recvmmsg. (Skipped on
            // platforms where the fallback would block.)
            if let Ok(got) = poll::recv_batch(&self.socket, &mut self.batch) {
                for i in 0..got {
                    let (bytes, _) = self.batch.datagram(i);
                    self.io_bytes_received += bytes.len() as u64;
                    // Copy into a pooled full-length buffer (batch
                    // buffers are `recv_len`-sized, so this always fits).
                    let mut copy = match self.recv_pool.pop() {
                        Some(b) => b,
                        None => vec![0u8; self.recv_len],
                    };
                    copy[..bytes.len()].copy_from_slice(bytes);
                    self.recv_queue.push_back((copy, bytes.len()));
                }
            }
        }
        Ok((first, n))
    }

    /// Drive the core until it surfaces a progress event: send what it
    /// emits, feed it received datagrams, tick it on socket timeouts.
    /// A [`Progress::Failed`] becomes this driver's error (same
    /// messages the pre-refactor driver produced inline).
    fn drive(&mut self, mut out: ClientOutput) -> Result<Progress> {
        loop {
            self.transmit(std::mem::take(&mut out.frames));
            if let Some(progress) = out.progress.take() {
                self.sync_stats();
                if let Progress::Failed { reason } = progress {
                    bail!(reason);
                }
                return Ok(progress);
            }
            out = match self.recv_datagram() {
                Ok((buf, n)) => {
                    let o = self.core.handle(&buf[..n], Instant::now());
                    self.give_recv_buf(buf);
                    o
                }
                Err(e) if is_timeout(&e) => self.core.on_tick(Instant::now()),
                Err(e) => {
                    self.sync_stats();
                    return Err(e.into());
                }
            };
        }
    }

    /// Initial registration with the server. Mid-round re-registration
    /// does NOT use this path — the core re-joins inline so broadcast
    /// frames of the awaited round keep counting while the Join is in
    /// flight.
    fn join(&mut self) -> Result<()> {
        let out = self.core.start_join(Instant::now());
        match self.drive(out)? {
            Progress::Joined => Ok(()),
            p => bail!("unexpected join progress: {p:?}"),
        }
    }

    /// Run phase 1 over the wire: upload the vote bitmap blocks, await
    /// the Golomb-coded GIA broadcast and return the decoded GIA (over
    /// this endpoint's `d`) plus the server-folded global max-|U|.
    ///
    /// `run_round` drives this with the full-model vote; the sharded
    /// fan-out driver ([`crate::client::ShardedFediacClient`]) calls it
    /// per shard with sub-model bitmaps.
    pub fn vote_phase(
        &mut self,
        round: u32,
        votes: &BitVec,
        local_max: f32,
    ) -> Result<(BitVec, f32)> {
        anyhow::ensure!(
            votes.len() == self.opts.d,
            "vote bitmap length {} != d {}",
            votes.len(),
            self.opts.d
        );
        let out = self.core.start_vote(round, votes, local_max, Instant::now());
        match self.drive(out)? {
            Progress::GiaReady { gia, global_max, .. } => Ok((gia, global_max)),
            p => bail!("unexpected vote-phase progress: {p:?}"),
        }
    }

    /// Run phase 2 over the wire: upload the GIA-aligned quantised lanes,
    /// await the aggregate broadcast and return the summed lanes (same
    /// order and length as `lanes`). An empty `lanes` still uploads the
    /// zero-lane completion block and awaits the empty aggregate —
    /// skipping it would leave the two sides disagreeing on whether the
    /// round happened at all.
    pub fn update_phase(&mut self, round: u32, lanes: &[i32], f: f32) -> Result<Vec<i32>> {
        let out = self.core.start_update(round, lanes, f, Instant::now());
        match self.drive(out)? {
            Progress::AggregateReady { lanes, .. } => Ok(lanes),
            p => bail!("unexpected update-phase progress: {p:?}"),
        }
    }

    /// Execute both FediAC phases for `round` on this client's update
    /// vector (with any residual already folded in by the caller).
    pub fn run_round(&mut self, round: usize, update: &[f32]) -> Result<RoundOutcome> {
        anyhow::ensure!(
            update.len() == self.opts.d,
            "update dimension {} != d {}",
            update.len(),
            self.opts.d
        );
        let retx_before = self.core.stats.retransmissions;
        let round_u = round as u32;
        let cid = self.opts.client_id as usize;

        // Phase 1: vote, then receive the GIA.
        let votes =
            protocol::client_vote(update, self.opts.k, self.opts.backend_seed, round, cid);
        let local_max = compress::max_abs(update);
        let (gia, global_max) = self.vote_phase(round_u, &votes, local_max)?;

        // Phase 2: quantise against the GIA, upload aligned lanes, receive
        // the aggregate (phase 2 runs even on an empty consensus — see
        // `update_phase`).
        let f = compress::scale_factor(self.opts.bits_b, self.opts.n_clients as usize, global_max);
        let (q, residual) = protocol::client_quantize(
            update,
            &gia.to_f32_mask(),
            f,
            self.opts.backend_seed,
            round,
            cid,
        );
        let gia_indices: Vec<usize> = gia.iter_ones().collect();
        let selected: Vec<i32> = gia_indices.iter().map(|&g| q[g]).collect();
        let aggregate = self.update_phase(round_u, &selected, f)?;
        let delta = compress::dequantize_aggregate(&aggregate, self.opts.n_clients as usize, f);

        Ok(RoundOutcome {
            gia,
            gia_indices,
            global_max,
            scale_f: f,
            aggregate,
            delta,
            residual,
            retransmissions: self.core.stats.retransmissions - retx_before,
        })
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::{serve, ServeOptions};
    use crate::wire::{decode_frame, encode_frame, Header, WireKind};

    #[test]
    fn options_produce_valid_spec() {
        let opts = ClientOptions::new("127.0.0.1:1", 3, 0, 1000, 4);
        assert!(opts.spec().validate().is_ok());
        assert_eq!(opts.k, 50);
    }

    #[test]
    fn recv_buffer_constant_admits_a_max_size_frame() {
        use crate::wire::MAX_WIRE_PAYLOAD;
        // The largest frame a job at this budget can emit must round-trip
        // a real socket through a buffer of exactly the derived size. The
        // old join path hardcoded 2048 bytes, which would have truncated
        // (and so silently dropped) this frame.
        let budget = 60_000usize;
        let frame = encode_frame(
            &Header {
                kind: WireKind::Gia,
                client: u16::MAX,
                job: 1,
                round: 1,
                block: 0,
                n_blocks: 1,
                elems: budget as u32,
                aux: 0,
            },
            &vec![0xAB; budget],
        );
        assert!(frame.len() > 2048, "frame too small to regress the old path");
        assert!(frame.len() <= FediacClient::recv_buf_len(budget));
        let rx = UdpSocket::bind("127.0.0.1:0").unwrap();
        rx.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let tx = UdpSocket::bind("127.0.0.1:0").unwrap();
        tx.send_to(&frame, rx.local_addr().unwrap()).unwrap();
        let mut buf = vec![0u8; FediacClient::recv_buf_len(budget)];
        let (n, _) = rx.recv_from(&mut buf).unwrap();
        assert_eq!(n, frame.len(), "frame truncated by the derived buffer size");
        let decoded = decode_frame(&buf[..n]).unwrap();
        assert_eq!(decoded.payload.len(), budget);
        // The derivation is capped by what UDP/IPv4 can physically carry,
        // so no budget can ever outgrow the buffer.
        assert!(FediacClient::recv_buf_len(MAX_WIRE_PAYLOAD) <= crate::wire::MAX_DATAGRAM);
        assert!(crate::wire::HEADER_LEN + MAX_WIRE_PAYLOAD <= crate::wire::MAX_DATAGRAM);
    }

    #[test]
    fn round_with_frames_beyond_the_old_join_buffer() {
        // End-to-end round whose vote/GIA/aggregate frames all exceed the
        // old 2048-byte join-path buffer: every receive path must use the
        // shared sizing or the round stalls on truncated broadcasts.
        let handle = serve(&ServeOptions::default()).unwrap();
        let mut opts =
            ClientOptions::new(handle.local_addr().to_string(), 81, 0, 80_000, 1);
        opts.threshold_a = 1;
        opts.payload_budget = 4096;
        opts.backend_seed = 21;
        let mut client = FediacClient::connect(opts).unwrap();
        let update: Vec<f32> = (0..80_000).map(|i| ((i as f32) * 0.01).sin() * 0.01).collect();
        let out = client.run_round(1, &update).unwrap();
        assert!(!out.gia_indices.is_empty());
        assert_eq!(out.aggregate.len(), out.gia_indices.len());
        handle.shutdown();
    }

    #[test]
    fn single_client_round_trip() {
        // N = 1, a = 1: the GIA is exactly this client's vote set and the
        // aggregate is its own quantised upload.
        let handle = serve(&ServeOptions::default()).unwrap();
        let mut opts =
            ClientOptions::new(handle.local_addr().to_string(), 77, 0, 300, 1);
        opts.threshold_a = 1;
        opts.payload_budget = 16; // several blocks per phase
        opts.backend_seed = 42;
        let mut client = FediacClient::connect(opts).unwrap();

        let update: Vec<f32> = (0..300).map(|i| ((i as f32) * 0.1).sin() * 0.01).collect();
        let out = client.run_round(1, &update).unwrap();

        let votes = protocol::client_vote(&update, client.options().k, 42, 1, 0);
        assert_eq!(out.gia, votes, "N=1, a=1 ⇒ GIA = own votes");
        let m = compress::max_abs(&update).max(f32::MIN_POSITIVE);
        assert_eq!(out.global_max, m);
        let f = compress::scale_factor(12, 1, m);
        let (q, _) = protocol::client_quantize(&update, &votes.to_f32_mask(), f, 42, 1, 0);
        let want: Vec<i32> = out.gia_indices.iter().map(|&g| q[g]).collect();
        assert_eq!(out.aggregate, want);
        assert_eq!(out.delta.len(), out.aggregate.len());
        handle.shutdown();
    }

    #[test]
    fn send_loss_rides_the_chaos_lane() {
        // The `send_loss` alias must inject real drops (visible as
        // retransmissions) and reconcile `dropped_sends` with the
        // underlying lane's own counter.
        let handle = serve(&ServeOptions::default()).unwrap();
        let mut opts = ClientOptions::new(handle.local_addr().to_string(), 79, 0, 400, 1);
        opts.threshold_a = 1;
        opts.payload_budget = 16; // many small frames → many loss draws
        opts.backend_seed = 13;
        opts.timeout = Duration::from_millis(50);
        opts.max_retries = 400;
        opts.send_loss = 0.3;
        let mut client = FediacClient::connect(opts).unwrap();
        let update: Vec<f32> = (0..400).map(|i| ((i as f32) * 0.3).sin() * 0.01).collect();
        for round in 1..=3 {
            client.run_round(round, &update).unwrap();
        }
        assert!(client.stats.dropped_sends > 0, "30% loss over ~75 frames never dropped");
        let lane_drops = client
            .loss_lane
            .as_ref()
            .map(|l| l.stats().dropped.load(Ordering::Relaxed))
            .unwrap();
        assert_eq!(client.stats.dropped_sends, lane_drops, "stats diverged from the lane");
        handle.shutdown();
    }

    #[test]
    fn chaos_knob_runs_the_client_behind_a_proxy() {
        let handle = serve(&ServeOptions::default()).unwrap();
        let mut opts = ClientOptions::new(handle.local_addr().to_string(), 78, 0, 200, 1);
        opts.threshold_a = 1;
        opts.payload_budget = 16;
        opts.backend_seed = 9;
        opts.timeout = Duration::from_millis(100);
        opts.chaos = Some(ChaosConfig::symmetric(3, ChaosDirection::lossy(0.15, 0.1, 0.2)));
        let mut client = FediacClient::connect(opts).unwrap();

        let update: Vec<f32> = (0..200).map(|i| ((i as f32) * 0.2).cos() * 0.01).collect();
        let out = client.run_round(1, &update).unwrap();
        let votes = protocol::client_vote(&update, client.options().k, 9, 1, 0);
        assert_eq!(out.gia, votes, "chaos changed the consensus");

        let snap = client.chaos_snapshot().expect("proxy attached");
        assert_eq!(snap.flows, 1);
        assert!(snap.up.forwarded > 0 && snap.down.forwarded > 0);
        handle.shutdown();
    }
}
