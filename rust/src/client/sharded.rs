//! Sharded fan-out driver: one logical FediAC client talking to N
//! collaborating aggregation servers at once (PROTOCOL.md §8).
//!
//! The round math is *identical* to the single-server
//! [`FediacClient`] — one global vote, one global quantisation — only
//! the transport fans out. Each shard endpoint gets its own blocking
//! thin driver (and thus its own [`crate::client::ClientCore`] protocol
//! state machine); this module owns only the scatter/gather, never the
//! protocol: the vote bitmap is scattered into per-shard
//! sub-bitmaps along the [`ShardLayout`] block-ownership map, each shard
//! runs its two phases concurrently (a thread per endpoint, so one slow
//! or lossy shard overlaps the others' waits), and the full GIA and
//! aggregate reassemble from the per-shard broadcasts. Because
//! thresholding and integer summation are per-dimension, the reassembled
//! round is bit-exact against the single-server wire path and the
//! in-process `algorithms::fediac` round (`tests/wire_shard.rs` proves
//! both, clean and under `net::chaos`).

use std::thread;

use anyhow::Result;

use crate::client::core::ClientStats;
use crate::client::driver::{ClientOptions, FediacClient, RoundOutcome};
use crate::client::protocol;
use crate::compress;
use crate::util::BitVec;
use crate::wire::{ShardLayout, ShardPlan, MAX_SHARDS};

/// A connected sharded client: one [`FediacClient`] per shard endpoint,
/// plus the ownership layout that scatters uploads and gathers
/// broadcasts.
pub struct ShardedFediacClient {
    shards: Vec<FediacClient>,
    layout: ShardLayout,
    /// Base options with the *global* model dimension (`server` names
    /// shard 0's endpoint but is otherwise unused).
    opts: ClientOptions,
}

/// Run one closure per shard client concurrently (a scoped thread per
/// endpoint, so one slow or lossy shard overlaps the others' waits) and
/// collect the results in shard order, failing on the first error.
fn fan_out<T: Send>(
    shards: &mut [FediacClient],
    work: impl Fn(usize, &mut FediacClient) -> Result<T> + Sync,
) -> Result<Vec<T>> {
    let mut results = Vec::with_capacity(shards.len());
    thread::scope(|scope| {
        let work = &work;
        let mut handles = Vec::with_capacity(shards.len());
        for (s, client) in shards.iter_mut().enumerate() {
            handles.push(scope.spawn(move || work(s, client)));
        }
        for h in handles {
            results.push(h.join().expect("shard worker thread panicked"));
        }
    });
    results.into_iter().collect()
}

impl ShardedFediacClient {
    /// Register with every shard endpoint concurrently. `servers[s]`
    /// hosts slice `s`; `base.d` is the full model dimension — each
    /// shard is joined with a [`crate::wire::JobSpec`] narrowed to its
    /// own sub-model and the matching [`ShardPlan`]. Plans in which some
    /// shard owns no vote blocks (more servers than blocks) are refused
    /// up front.
    pub fn connect(servers: &[String], base: ClientOptions) -> Result<Self> {
        let n = servers.len();
        anyhow::ensure!(
            (1..=MAX_SHARDS as usize).contains(&n),
            "shard count {n} must be in [1, {MAX_SHARDS}]"
        );
        let layout = ShardLayout::new(base.d, base.payload_budget, n);
        for s in 0..n {
            anyhow::ensure!(
                layout.shard_dims(s) > 0,
                "shard {s} owns no vote blocks: d={} at budget {} gives only {} blocks for \
                 {n} shards",
                base.d,
                base.payload_budget,
                layout.n_blocks()
            );
        }
        let mut shard_opts = Vec::with_capacity(n);
        for (s, server) in servers.iter().enumerate() {
            let mut o = base.clone();
            o.server = server.clone();
            o.d = layout.shard_dims(s);
            o.shard = ShardPlan { n_shards: n as u8, shard_id: s as u8 };
            if let Some(c) = o.chaos.as_mut() {
                // Decorrelate the per-shard chaos streams, mirroring the
                // proxy's per-flow lane seeding.
                c.seed ^= (s as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            }
            shard_opts.push(o);
        }
        // Concurrent joins: under chaos a single join can take several
        // retransmission cycles, and serialising N of them would stack
        // the timeouts.
        let mut joined: Vec<Result<FediacClient>> = Vec::with_capacity(n);
        thread::scope(|scope| {
            let mut handles = Vec::with_capacity(n);
            for o in shard_opts {
                handles.push(scope.spawn(move || FediacClient::connect(o)));
            }
            for h in handles {
                joined.push(h.join().expect("shard join thread panicked"));
            }
        });
        let shards = joined.into_iter().collect::<Result<Vec<_>>>()?;
        Ok(ShardedFediacClient { shards, layout, opts: base })
    }

    /// Number of shard endpoints this client fans out to.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The block-ownership layout shared with the servers.
    pub fn layout(&self) -> &ShardLayout {
        &self.layout
    }

    /// Per-shard clients (index = shard id), e.g. for per-endpoint
    /// chaos snapshots in tests.
    pub fn shards(&self) -> &[FediacClient] {
        &self.shards
    }

    /// Driver counters summed across every shard endpoint.
    pub fn stats(&self) -> ClientStats {
        let mut total = ClientStats::default();
        for c in &self.shards {
            total.add(&c.stats);
        }
        total
    }

    /// Execute both FediAC phases for `round` across every shard,
    /// returning the same [`RoundOutcome`] a single-server round
    /// produces for the same inputs.
    pub fn run_round(&mut self, round: usize, update: &[f32]) -> Result<RoundOutcome> {
        anyhow::ensure!(
            update.len() == self.opts.d,
            "update dimension {} != d {}",
            update.len(),
            self.opts.d
        );
        let retx_before = self.stats().retransmissions;
        let round_u = round as u32;
        let cid = self.opts.client_id as usize;

        // Phase 1: one global vote, scattered along block ownership and
        // fanned out concurrently; the full GIA reassembles from the
        // per-shard broadcasts.
        let votes =
            protocol::client_vote(update, self.opts.k, self.opts.backend_seed, round, cid);
        let local_max = compress::max_abs(update);
        let sub_votes = self.layout.split_bitmap(&votes);
        let partials = fan_out(&mut self.shards, |s, client| {
            client.vote_phase(round_u, &sub_votes[s], local_max)
        })?;
        let (sub_gias, maxima): (Vec<BitVec>, Vec<f32>) = partials.into_iter().unzip();
        let gia = self
            .layout
            .merge_bitmaps(&sub_gias)
            .map_err(|e| anyhow::anyhow!("reassembling the sharded GIA: {e}"))?;
        // Every shard folds the same per-client maxima (each client
        // reports its full-model max-|U| to every shard), so a
        // disagreement means the shards saw different client sets.
        let global_max = maxima[0];
        for (s, &m) in maxima.iter().enumerate() {
            anyhow::ensure!(
                m == global_max,
                "shard {s} folded global max {m} but shard 0 folded {global_max}: the shards \
                 disagree on the client set"
            );
        }

        // Phase 2: one global quantisation against the reassembled GIA;
        // each selected lane uploads to the shard owning its vote block,
        // and the global aggregate interleaves back from the per-shard
        // sums.
        let f = compress::scale_factor(self.opts.bits_b, self.opts.n_clients as usize, global_max);
        let (q, residual) = protocol::client_quantize(
            update,
            &gia.to_f32_mask(),
            f,
            self.opts.backend_seed,
            round,
            cid,
        );
        let gia_indices: Vec<usize> = gia.iter_ones().collect();
        let lanes_per_shard: Vec<Vec<i32>> = self
            .layout
            .split_selected(&gia)
            .iter()
            .map(|idxs| idxs.iter().map(|&g| q[g]).collect())
            .collect();
        let parts = fan_out(&mut self.shards, |s, client| {
            client.update_phase(round_u, &lanes_per_shard[s], f)
        })?;
        let aggregate = self
            .layout
            .merge_lanes(&gia, &parts)
            .map_err(|e| anyhow::anyhow!("reassembling the sharded aggregate: {e}"))?;
        let delta = compress::dequantize_aggregate(&aggregate, self.opts.n_clients as usize, f);

        Ok(RoundOutcome {
            gia,
            gia_indices,
            global_max,
            scale_f: f,
            aggregate,
            delta,
            residual,
            retransmissions: self.stats().retransmissions - retx_before,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::{serve_sharded, ServeOptions};
    use std::time::Duration;

    #[test]
    fn connect_refuses_empty_shards_and_bad_counts() {
        // d = 64 at budget 8 is one vote block: a second shard would own
        // nothing, and the driver must say so before any socket work.
        let opts = ClientOptions::new("127.0.0.1:1", 3, 0, 64, 1);
        let servers = vec!["127.0.0.1:1".to_string(), "127.0.0.1:2".to_string()];
        let err = ShardedFediacClient::connect(&servers, opts.clone()).unwrap_err();
        assert!(err.to_string().contains("owns no vote blocks"), "{err}");
        let too_many: Vec<String> =
            (0..17).map(|i| format!("127.0.0.1:{}", 100 + i)).collect();
        assert!(ShardedFediacClient::connect(&too_many, opts).is_err());
    }

    #[test]
    fn two_shard_round_trip_matches_single_client_math() {
        // N_clients = 1, a = 1: the reassembled GIA is exactly the
        // client's own vote set and the aggregate its own upload —
        // across two shard servers.
        let handles = serve_sharded(&ServeOptions::default(), 2).unwrap();
        let servers: Vec<String> =
            handles.iter().map(|h| h.local_addr().to_string()).collect();
        let mut opts = ClientOptions::new(servers[0].clone(), 91, 0, 300, 1);
        opts.threshold_a = 1;
        opts.payload_budget = 16; // several blocks per shard
        opts.backend_seed = 13;
        opts.timeout = Duration::from_millis(300);
        let mut client = ShardedFediacClient::connect(&servers, opts).unwrap();
        assert_eq!(client.n_shards(), 2);

        let update: Vec<f32> = (0..300).map(|i| ((i as f32) * 0.13).sin() * 0.01).collect();
        let out = client.run_round(1, &update).unwrap();

        let votes = protocol::client_vote(&update, client.opts.k, 13, 1, 0);
        assert_eq!(out.gia, votes, "N=1, a=1 ⇒ GIA = own votes");
        let m = compress::max_abs(&update).max(f32::MIN_POSITIVE);
        assert_eq!(out.global_max, m);
        let f = compress::scale_factor(12, 1, m);
        let (q, _) = protocol::client_quantize(&update, &votes.to_f32_mask(), f, 13, 1, 0);
        let want: Vec<i32> = out.gia_indices.iter().map(|&g| q[g]).collect();
        assert_eq!(out.aggregate, want);
        // Each shard completed its own round.
        for h in &handles {
            assert_eq!(h.stats().rounds_completed, 1);
        }
        for h in handles {
            h.shutdown();
        }
    }
}
