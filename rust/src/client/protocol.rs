//! Canonical client-side round math, shared by the simulator and the wire.
//!
//! [`crate::algorithms::fediac`] (simulation) and
//! [`crate::client::driver`] (networked) must produce bit-identical vote
//! bitmaps and quantised updates for the same inputs, or the loopback
//! integration tests could not compare a wire round against an in-process
//! round. The seed derivation here mirrors
//! [`crate::fl::NativeBackend::vote_scores`] / `compress` exactly: both
//! mix the backend seed with a per-(round, client) protocol seed and a
//! role constant.

use crate::compress;
use crate::util::{BitVec, Rng};

/// Role constant mixed into the vote-score RNG (see `fl::native`).
const VOTE_MIX: u64 = 0x907e;
/// Role constant mixed into the quantisation RNG (see `fl::native`).
const COMPRESS_MIX: u64 = 0xc049;

/// Votes per client: k = round(k_frac · d), clamped to [1, d] — the same
/// resolution `FediAc::new` applies (paper: k = 5%·d).
pub fn votes_per_client(d: usize, k_frac: f64) -> usize {
    ((k_frac * d as f64).round() as usize).clamp(1, d)
}

/// Protocol seed for phase-1 voting (Algorithm 1 line 5).
pub fn vote_seed(round: usize, client: usize) -> i64 {
    (round as i64) << 24 | client as i64
}

/// Protocol seed for phase-2 quantisation (Algorithm 1 line 9).
pub fn compress_seed(round: usize, client: usize) -> i64 {
    0x5EED_0000 | (round as i64) << 8 | client as i64
}

/// RNG stream for one client's vote scores in one round.
pub fn vote_rng(backend_seed: u64, round: usize, client: usize) -> Rng {
    Rng::new(backend_seed ^ vote_seed(round, client) as u64 ^ VOTE_MIX)
}

/// RNG stream for one client's stochastic quantisation in one round.
pub fn compress_rng(backend_seed: u64, round: usize, client: usize) -> Rng {
    Rng::new(backend_seed ^ compress_seed(round, client) as u64 ^ COMPRESS_MIX)
}

/// Phase 1: the client's k-hot vote bitmap (Gumbel-top-k ∝ |U|).
pub fn client_vote(
    update: &[f32],
    k: usize,
    backend_seed: u64,
    round: usize,
    client: usize,
) -> BitVec {
    let mut rng = vote_rng(backend_seed, round, client);
    let scores = compress::vote_scores_native(update, &mut rng);
    compress::vote_bitmap_from_scores(&scores, k)
}

/// Phase 2: quantise + sparsify against the GIA mask (Eq. 1), returning
/// the integers to upload and the residual to fold into round t+1.
pub fn client_quantize(
    update: &[f32],
    gia_mask: &[f32],
    f: f32,
    backend_seed: u64,
    round: usize,
    client: usize,
) -> (Vec<i32>, Vec<f32>) {
    let mut rng = compress_rng(backend_seed, round, client);
    compress::quantize_sparsify(update, gia_mask, f, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configx::{DatasetKind, Partition};
    use crate::data::synth;
    use crate::fl::{ModelBackend, NativeBackend};

    fn backend(seed: u64) -> NativeBackend {
        let fd = synth::generate(DatasetKind::Tiny, Partition::Iid, 3, 30, seed);
        NativeBackend::new(fd, 8, 2, 8, seed)
    }

    #[test]
    fn matches_native_backend_vote_scores() {
        // The wire client must reproduce exactly what the simulated FediAC
        // round asks the backend for.
        let seed = 11u64;
        let mut b = backend(seed);
        let update: Vec<f32> = (0..b.d()).map(|i| ((i % 17) as f32 - 8.0) * 0.01).collect();
        for (round, client) in [(1usize, 0usize), (3, 2)] {
            let via_backend = b.vote_scores(&update, vote_seed(round, client));
            let mut rng = vote_rng(seed, round, client);
            let direct = compress::vote_scores_native(&update, &mut rng);
            assert_eq!(via_backend, direct, "round {round} client {client}");
        }
    }

    #[test]
    fn matches_native_backend_compress() {
        let seed = 13u64;
        let mut b = backend(seed);
        let d = b.d();
        let update: Vec<f32> = (0..d).map(|i| ((i % 11) as f32 - 5.0) * 0.02).collect();
        let mask: Vec<f32> = (0..d).map(|i| if i % 4 == 0 { 1.0 } else { 0.0 }).collect();
        let f = 300.0f32;
        let (round, client) = (2usize, 1usize);
        let via_backend = b.compress(&update, &mask, f, compress_seed(round, client));
        let direct = client_quantize(&update, &mask, f, seed, round, client);
        assert_eq!(via_backend, direct);
    }

    #[test]
    fn votes_per_client_mirrors_fediac_new() {
        assert_eq!(votes_per_client(1000, 0.05), 50);
        assert_eq!(votes_per_client(10, 0.0), 1); // clamped low
        assert_eq!(votes_per_client(10, 1.0), 10);
        assert_eq!(votes_per_client(3, 0.9), 3); // round(2.7) = 3
    }

    #[test]
    fn vote_bitmap_is_k_hot_and_deterministic() {
        let update: Vec<f32> = (0..500).map(|i| (i as f32 * 0.37).sin()).collect();
        let a = client_vote(&update, 25, 7, 4, 2);
        let b = client_vote(&update, 25, 7, 4, 2);
        assert_eq!(a, b);
        assert_eq!(a.count_ones(), 25);
        let c = client_vote(&update, 25, 7, 4, 3);
        assert_ne!(a, c, "different clients must draw different votes");
    }
}
