//! FediAC client stack: one sans-I/O protocol core, three backends.
//!
//! * [`protocol`] — the deterministic client-side round math (vote
//!   selection and Eq.-1 quantisation with the canonical seed derivation).
//!   [`crate::algorithms::fediac`] drives the *simulated* round through the
//!   same functions, so a networked round and an in-process round produce
//!   bit-identical aggregation content for the same inputs.
//! * [`core`] — the sans-I/O client state machine: join/rejoin, vote
//!   upload, GIA reassembly, quantised-update upload, aggregate
//!   reassembly, timeout retransmission and Poll, all as pure
//!   `handle(frame, now)` / `on_tick(now)` transitions returning
//!   [`core::ClientOutput`] — no sockets, no clocks, no sleeps. Every
//!   wait uses timeout-based retransmission (the server's scoreboards
//!   drop the duplicates), so lossy links only cost time, never
//!   correctness.
//! * [`driver`] — the blocking backend: one [`core::ClientCore`] driven
//!   over one connected UDP socket (one thread per client). This is the
//!   operator-facing `fediac client` path.
//! * [`sharded`] — the multi-server fan-out: one blocking driver per
//!   collaborating shard server along the [`crate::wire::ShardLayout`]
//!   block-ownership map, phases running concurrently per shard and the
//!   GIA/aggregate reassembled from the per-shard broadcasts
//!   (PROTOCOL.md §8).
//! * [`swarm`] — the scale backend: a single-thread multiplexer hosting
//!   thousands of [`core::ClientCore`]s over ≤ 8 sockets (poll(2) +
//!   timer wheel + recvmmsg/sendmmsg), exposed as `fediac swarm` and
//!   `bench-wire --swarm`. Not wire-visible: the server cannot tell a
//!   swarm client from a blocking one.

pub mod core;
pub mod driver;
pub mod protocol;
pub mod sharded;
pub mod swarm;

pub use self::core::{ClientCore, ClientOutput, ClientStats, CoreConfig, Progress};
pub use driver::{ClientOptions, FediacClient, RoundOutcome};
pub use protocol::{client_quantize, client_vote, compress_seed, vote_seed, votes_per_client};
pub use sharded::ShardedFediacClient;
pub use swarm::{plan_fleet, SwarmJobPlan, SwarmOptions, SwarmReport, UpdateSource};
