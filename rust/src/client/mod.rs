//! FediAC client driver: both protocol phases over a real UDP socket.
//!
//! * [`protocol`] — the deterministic client-side round math (vote
//!   selection and Eq.-1 quantisation with the canonical seed derivation).
//!   [`crate::algorithms::fediac`] drives the *simulated* round through the
//!   same functions, so a networked round and an in-process round produce
//!   bit-identical aggregation content for the same inputs.
//! * [`driver`] — the socket state machine: join, upload vote blocks,
//!   await the Golomb-coded GIA broadcast, upload aligned quantised
//!   updates, await the aggregate; every wait uses timeout-based
//!   retransmission (the server's scoreboards drop the duplicates), so
//!   lossy links only cost time, never correctness.
//! * [`sharded`] — the multi-server fan-out: the same round math spread
//!   over N collaborating shard servers along the
//!   [`crate::wire::ShardLayout`] block-ownership map, phases running
//!   concurrently per shard and the GIA/aggregate reassembled from the
//!   per-shard broadcasts (PROTOCOL.md §8).

pub mod driver;
pub mod protocol;
pub mod sharded;

pub use driver::{ClientOptions, ClientStats, FediacClient, RoundOutcome};
pub use protocol::{client_quantize, client_vote, compress_seed, vote_seed, votes_per_client};
pub use sharded::ShardedFediacClient;
