//! The sans-I/O client protocol core: every FediAC client-side protocol
//! decision — join/re-join, vote upload, GIA reassembly, quantised
//! update upload, aggregate reassembly, timeout retransmission and
//! `Poll` — as a pure state machine with **no sockets, clocks or
//! sleeps**, mirroring [`crate::server::Job`] on the other side of the
//! wire.
//!
//! The contract: callers own the I/O and the clock. Feed every received
//! datagram to [`ClientCore::handle`] (or a pre-decoded frame to
//! [`ClientCore::handle_frame`]) with the current time, call
//! [`ClientCore::on_tick`] when the returned deadline arrives, and send
//! whatever [`ClientOutput::frames`] comes back. Phase transitions
//! surface as [`Progress`] events; the round *math* (voting,
//! quantisation — [`crate::client::protocol`]) stays with the caller,
//! which is what keeps one core definition shared by the blocking
//! driver ([`crate::client::FediacClient`]), the sharded fan-out and
//! the swarm multiplexer ([`crate::client::swarm`]) — three backends,
//! one protocol implementation, bit-exact on the wire.
//!
//! Timer semantics match the blocking driver's socket timeout exactly:
//! the retransmit deadline slides to `now + timeout` on **every**
//! datagram received while a wait is armed (even an undecodable one —
//! a blocking `recv` with a fresh timeout behaves the same way), and an
//! expiry past the retry budget fails the client.

use std::time::{Duration, Instant};

use crate::compress::golomb;
use crate::server::{JOIN_OK, JOIN_UNKNOWN_JOB};
use crate::telemetry::HistSummary;
use crate::util::BitVec;
use crate::wire::{
    decode_frame, decode_lanes, update_chunk_bounds, vote_chunk_bounds, ChunkAssembler,
    FrameScratch, Header, JobSpec, ShardPlan, WireKind,
};

/// Broadcast frames of the *other* phase kept aside during a wait (an
/// empty-consensus round multicasts GIA and aggregate back-to-back;
/// reordering can also deliver them interleaved); bounds memory against
/// a babbling server. Overflow is counted in
/// [`ClientStats::pending_dropped`].
pub(crate) const PENDING_CAP: usize = 256;

/// Cumulative client counters. The protocol-visible counters
/// (retransmissions, polls, rejoins, stream resets, pending drops, RTT
/// histograms) are maintained by [`ClientCore`]; the I/O-side counters
/// (bytes, loss-lane drops) by whichever driver owns the sockets.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ClientStats {
    /// Frames re-sent after a timeout.
    pub retransmissions: u64,
    /// Frames dropped by the uplink loss lane (never hit the wire).
    pub dropped_sends: u64,
    /// Poll frames sent.
    pub polls: u64,
    /// Mid-round re-registrations after a `JOIN_UNKNOWN_JOB` (e.g. the
    /// server restarted or evicted the job).
    pub rejoins: u64,
    /// Broadcast streams restarted because interleaved frames disagreed
    /// on geometry (`n_blocks`) or the aux word.
    pub stream_resets: u64,
    /// Sidelined other-phase broadcasts discarded because the pending
    /// stash was full ([`PENDING_CAP`]) — nonzero means a babbling (or
    /// heavily replaying) server overflowed the bound, and the client
    /// may have paid a poll cycle to recover the dropped broadcast.
    pub pending_dropped: u64,
    /// Timeout cycles where the wanted broadcast had already started
    /// arriving — evidence the server quorum-closed the phase without
    /// this client — so the core polled for the rest of the broadcast
    /// instead of retransmitting an upload the server would only drop
    /// (`ServerStats::late_after_close` on the other side).
    pub quorum_resyncs: u64,
    /// Datagram bytes handed to the socket (after the loss lane) — the
    /// `fediac bench-wire` bytes/round numerator, uplink half.
    pub bytes_sent: u64,
    /// Datagram bytes received from the socket (before decoding).
    pub bytes_received: u64,
    /// Vote-phase round trips as seen from this endpoint: first vote
    /// frame sent → GIA decoded (retransmission cycles included).
    pub vote_rtt_us: HistSummary,
    /// Update-phase round trips: first lane frame sent → aggregate
    /// decoded.
    pub update_rtt_us: HistSummary,
}

impl ClientStats {
    /// Fold another endpoint's counters in — the single place that knows
    /// every field, so multi-endpoint aggregation (the sharded driver,
    /// the swarm) cannot silently drop a counter added later.
    pub fn add(&mut self, other: &ClientStats) {
        self.retransmissions += other.retransmissions;
        self.dropped_sends += other.dropped_sends;
        self.polls += other.polls;
        self.rejoins += other.rejoins;
        self.stream_resets += other.stream_resets;
        self.pending_dropped += other.pending_dropped;
        self.quorum_resyncs += other.quorum_resyncs;
        self.bytes_sent += other.bytes_sent;
        self.bytes_received += other.bytes_received;
        self.vote_rtt_us.merge(&other.vote_rtt_us);
        self.update_rtt_us.merge(&other.update_rtt_us);
    }
}

/// Everything the protocol core needs to know about its endpoint — the
/// transport-relevant subset of [`crate::client::ClientOptions`] (no
/// server address, no chaos knobs, no round math parameters).
#[derive(Debug, Clone)]
pub struct CoreConfig {
    /// Job id shared by every client of the job.
    pub job: u32,
    /// This client's id in `[0, n_clients)`.
    pub client_id: u16,
    /// Total clients N in the job (all must agree).
    pub n_clients: u16,
    /// Model dimension d this endpoint uploads (the sub-model d for a
    /// shard endpoint).
    pub d: usize,
    /// Voting threshold a (part of the registered spec).
    pub threshold_a: u16,
    /// Payload bytes per data frame (must match across the job).
    pub payload_budget: usize,
    /// Silence tolerated before a retransmit cycle.
    pub timeout: Duration,
    /// Timeouts tolerated per wait before the client fails.
    pub max_retries: usize,
    /// Which slice of a sharded deployment this endpoint talks to.
    pub shard: ShardPlan,
    /// Round-closure quorum Q registered with the job (0 = legacy
    /// all-N). Besides riding the spec, a nonzero quorum switches the
    /// timeout path to broadcast re-sync: once any chunk of the wanted
    /// broadcast has arrived, the phase evidently closed without this
    /// client, so retransmitting the upload is pure reflection fodder.
    pub quorum: u16,
}

impl CoreConfig {
    /// The job spec this endpoint registers.
    pub fn spec(&self) -> JobSpec {
        JobSpec {
            d: self.d as u32,
            n_clients: self.n_clients,
            threshold_a: self.threshold_a,
            payload_budget: self.payload_budget as u16,
            shard: self.shard,
            quorum: self.quorum,
        }
    }
}

/// A phase-transition event surfaced by the core. At most one per
/// [`ClientOutput`]; `Failed` is terminal (the core goes dead).
#[derive(Debug, Clone)]
pub enum Progress {
    /// The initial registration was acknowledged with `JOIN_OK`.
    Joined,
    /// The vote wait completed: the round's GIA broadcast reassembled,
    /// Golomb-decoded and validated.
    GiaReady {
        /// The round the GIA belongs to.
        round: u32,
        /// The global important-index bitmap over this endpoint's d.
        gia: BitVec,
        /// Server-folded global max-|U| (the m every client derives the
        /// scale factor f from), already checked finite and positive.
        global_max: f32,
    },
    /// The update wait completed: the aggregate broadcast reassembled,
    /// decoded and length-checked against the uploaded lane count.
    AggregateReady {
        /// The round the aggregate belongs to.
        round: u32,
        /// Aggregated i32 lanes in GIA order (length = uploaded k_S).
        lanes: Vec<i32>,
    },
    /// The client is dead: retry budget exhausted, a refused (re-)join,
    /// or an invalid completed broadcast. The reason is the same text
    /// the blocking driver has always surfaced as its error.
    Failed {
        /// Human-readable cause.
        reason: String,
    },
}

/// What one core call asks its driver to do: send `frames` (in order),
/// schedule [`ClientCore::on_tick`] for `timer`, and act on `progress`.
#[derive(Debug, Default)]
pub struct ClientOutput {
    /// Encoded datagrams to transmit, in order. Buffers come from the
    /// core's pool — hand them back via [`ClientCore::recycle`] after
    /// sending to keep steady-state emission allocation-free.
    pub frames: Vec<Vec<u8>>,
    /// When to call [`ClientCore::on_tick`] next (`None`: no wait is
    /// armed). The deadline *slides* on every received datagram; a tick
    /// that arrives early is harmless (the core re-reports the live
    /// deadline and does nothing else).
    pub timer: Option<Instant>,
    /// At most one phase-transition event.
    pub progress: Option<Progress>,
}

/// Where the core is in the protocol.
enum Phase {
    /// Nothing in flight (before `start_join`, between phases, or all
    /// done).
    Idle,
    /// Initial registration: join sent, waiting for the ack.
    Joining,
    /// A phase wait: upload sent, reassembling the `want` broadcast.
    Waiting {
        /// The round being exchanged.
        round: u32,
        /// Broadcast kind that completes this wait (`Gia`/`Aggregate`).
        want: WireKind,
        /// The phase's upload frames, retained for retransmission.
        frames: Vec<Vec<u8>>,
        /// Lanes uploaded (aggregate length check); 0 for a vote wait.
        expect_lanes: usize,
        /// In-progress reassembly, keyed by the stream's aux word.
        asm: Option<(ChunkAssembler, u32)>,
        /// A `JOIN_UNKNOWN_JOB` arrived and our re-join is in flight.
        rejoining: bool,
        /// When the wait began (RTT histogram sample on completion).
        started: Instant,
    },
    /// Terminal: a `Failed` progress was emitted; inputs are ignored.
    Dead,
}

/// The sans-I/O FediAC client state machine. See the module docs for
/// the driving contract.
pub struct ClientCore {
    cfg: CoreConfig,
    phase: Phase,
    /// Earliest time `on_tick` should fire, while a wait is armed.
    deadline: Option<Instant>,
    /// Timeouts burned in the current wait (reset by every `start_*`).
    timeouts: usize,
    /// Registration confirmed at least once.
    joined: bool,
    /// Broadcast frames of the current round's other phase, captured
    /// while waiting (served to the next `start_*` before the wire).
    pending: Vec<(Header, Vec<u8>)>,
    /// Largest broadcast block count this job could legitimately need —
    /// derived once from the config, see `max_broadcast_blocks`.
    max_blocks: usize,
    /// Datagram-buffer pool: steady-state emission recycles buffers
    /// instead of allocating (callers return them via `recycle`).
    scratch: FrameScratch,
    /// Reused serialisation buffers (vote bitmap bytes / lane bytes).
    bitmap_buf: Vec<u8>,
    lane_buf: Vec<u8>,
    /// Protocol-side counters (see [`ClientStats`] for the split).
    pub stats: ClientStats,
}

impl ClientCore {
    /// A fresh core in the idle state. Call [`ClientCore::start_join`]
    /// to begin. The config is trusted (validate upstream — the drivers
    /// run `JobSpec::validate` plus their own range checks).
    pub fn new(cfg: CoreConfig) -> Self {
        // Largest broadcast block count this job could legitimately
        // need: the aggregate is at most 4·d lane bytes and the Golomb
        // GIA stays under 2 bits per dimension plus its header for any
        // density the server-side Rice parameter produces. A frame
        // declaring more blocks is forged or stale — sizing the
        // assembler from it would pin unbounded memory.
        let max_blocks = (16 + 4 * cfg.d).div_ceil(cfg.payload_budget).max(1) + 1;
        ClientCore {
            cfg,
            phase: Phase::Idle,
            deadline: None,
            timeouts: 0,
            joined: false,
            pending: Vec::new(),
            max_blocks,
            scratch: FrameScratch::new(),
            bitmap_buf: Vec::new(),
            lane_buf: Vec::new(),
            stats: ClientStats::default(),
        }
    }

    /// The configuration this core was built with.
    pub fn config(&self) -> &CoreConfig {
        &self.cfg
    }

    /// Registration has been acknowledged at least once.
    pub fn is_joined(&self) -> bool {
        self.joined
    }

    /// A `Failed` progress was emitted; the core ignores further input.
    pub fn is_failed(&self) -> bool {
        matches!(self.phase, Phase::Dead)
    }

    /// The deadline the driver should call [`ClientCore::on_tick`] at
    /// (`None` when no wait is armed) — same contract as
    /// `server::Job::next_timer`.
    pub fn next_timer(&self) -> Option<Instant> {
        self.deadline
    }

    /// The round a phase wait is in progress for, if any. A multiplexer
    /// hosting many cores on one socket uses this to deliver a
    /// broadcast copy only to the clients it can still matter to (the
    /// server fans every broadcast out once per registered client, so
    /// co-hosted clients see each other's copies).
    pub fn waiting_round(&self) -> Option<u32> {
        match &self.phase {
            Phase::Waiting { round, .. } => Some(*round),
            _ => None,
        }
    }

    /// Hand an emitted frame buffer back to the pool after sending.
    pub fn recycle(&mut self, buf: Vec<u8>) {
        self.scratch.give(buf);
    }

    /// Begin the initial registration: emits the Join frame and arms the
    /// retransmit timer. Completion surfaces as [`Progress::Joined`].
    pub fn start_join(&mut self, now: Instant) -> ClientOutput {
        debug_assert!(matches!(self.phase, Phase::Idle), "start_join while busy");
        self.phase = Phase::Joining;
        self.timeouts = 0;
        self.deadline = Some(now + self.cfg.timeout);
        let frame = self.join_datagram();
        ClientOutput { frames: vec![frame], timer: self.deadline, progress: None }
    }

    /// Begin phase 1 of `round`: emits the vote upload (bitmap blocks,
    /// `local_max` in the aux word) and waits for the GIA broadcast.
    /// Completion surfaces as [`Progress::GiaReady`]; if the stash
    /// already holds the whole broadcast, nothing is uploaded at all
    /// (exactly like the blocking driver's pre-send pending drain).
    pub fn start_vote(
        &mut self,
        round: u32,
        votes: &BitVec,
        local_max: f32,
        now: Instant,
    ) -> ClientOutput {
        debug_assert!(matches!(self.phase, Phase::Idle), "start_vote while busy");
        if votes.len() != self.cfg.d {
            let reason = format!("vote bitmap length {} != d {}", votes.len(), self.cfg.d);
            return ClientOutput { frames: Vec::new(), timer: None, progress: Some(self.fail(reason)) };
        }
        let frames = self.vote_frames(round, votes, local_max);
        self.enter_wait(round, WireKind::Gia, frames, 0, now)
    }

    /// Begin phase 2 of `round`: emits the GIA-aligned quantised lane
    /// upload (`f` in the aux word) and waits for the aggregate
    /// broadcast. An empty `lanes` still uploads the zero-lane
    /// completion block and awaits the empty aggregate — skipping it
    /// would leave the two sides disagreeing on whether the round
    /// happened at all. Completion surfaces as
    /// [`Progress::AggregateReady`].
    pub fn start_update(
        &mut self,
        round: u32,
        lanes: &[i32],
        f: f32,
        now: Instant,
    ) -> ClientOutput {
        debug_assert!(matches!(self.phase, Phase::Idle), "start_update while busy");
        let frames = self.update_frames(round, lanes, f);
        self.enter_wait(round, WireKind::Aggregate, frames, lanes.len(), now)
    }

    /// Feed one received datagram. Undecodable bytes still slide the
    /// retransmit deadline (a blocking recv's timeout resets on any
    /// traffic); everything else goes through
    /// [`ClientCore::handle_frame`].
    pub fn handle(&mut self, datagram: &[u8], now: Instant) -> ClientOutput {
        match decode_frame(datagram) {
            Ok(frame) => {
                let h = frame.header;
                self.handle_frame(&h, frame.payload, now)
            }
            Err(_) => {
                self.touch(now);
                ClientOutput { frames: Vec::new(), timer: self.deadline, progress: None }
            }
        }
    }

    /// Feed one already-decoded frame (the swarm decodes each datagram
    /// once, then routes the frame to every addressed core).
    pub fn handle_frame(&mut self, h: &Header, payload: &[u8], now: Instant) -> ClientOutput {
        self.touch(now);
        match self.phase {
            Phase::Dead => ClientOutput { frames: Vec::new(), timer: None, progress: None },
            Phase::Idle => {
                // Between phases. A broadcast landing here (the empty-
                // consensus GIA+aggregate multicast races the caller's
                // next `start_*`) is stashed exactly as it would be
                // mid-wait — the blocking driver gets this for free from
                // its receive queue, which replays queued datagrams into
                // the next exchange.
                if h.job == self.cfg.job
                    && (h.kind == WireKind::Gia || h.kind == WireKind::Aggregate)
                {
                    self.stash(h, payload);
                }
                ClientOutput { frames: Vec::new(), timer: self.deadline, progress: None }
            }
            Phase::Joining => self.handle_joining(h),
            Phase::Waiting { .. } => self.handle_waiting(h, payload, now),
        }
    }

    /// Fire the retransmit timer. Early calls (deadline slid later, or
    /// none armed) report the live deadline and do nothing else; a due
    /// call burns one timeout — failing the client past the budget —
    /// and re-emits the wait's frames plus a `Poll`.
    pub fn on_tick(&mut self, now: Instant) -> ClientOutput {
        let Some(deadline) = self.deadline else {
            return ClientOutput::default();
        };
        if now < deadline {
            return ClientOutput { frames: Vec::new(), timer: Some(deadline), progress: None };
        }
        self.timeouts += 1;
        // Pull the Copy facts out of the phase first so the retransmit
        // actions below can borrow `self` freely.
        enum Due {
            Join,
            Wait { round: u32, want: WireKind, rejoining: bool, n_frames: usize, resync: bool },
        }
        let due = match &self.phase {
            Phase::Joining => Due::Join,
            Phase::Waiting { round, want, rejoining, frames, asm, .. } => Due::Wait {
                round: *round,
                want: *want,
                rejoining: *rejoining,
                n_frames: frames.len(),
                // Quorum jobs: a partially-assembled wanted broadcast
                // proves the phase closed server-side — the round went on
                // without us, so re-uploading only feeds the server's
                // late-after-close counter. Poll for the remaining
                // chunks instead. (Legacy all-N jobs keep the historical
                // retransmit-everything behaviour, bit for bit.)
                resync: self.cfg.quorum > 0 && asm.is_some(),
            },
            _ => unreachable!("deadline armed outside a wait"),
        };
        if self.timeouts > self.cfg.max_retries {
            let reason = match due {
                Due::Join => format!("join timed out after {} attempts", self.timeouts),
                Due::Wait { round, want, .. } => format!(
                    "client {} timed out waiting for {want:?} of round {round} after {} timeouts",
                    self.cfg.client_id, self.timeouts
                ),
            };
            return ClientOutput {
                frames: Vec::new(),
                timer: None,
                progress: Some(self.fail(reason)),
            };
        }
        let mut out_frames = Vec::new();
        match due {
            Due::Join => {
                self.stats.retransmissions += 1;
                out_frames.push(self.join_datagram());
            }
            Due::Wait { round, want, rejoining, n_frames, resync } => {
                crate::debug!(
                    "job={} client={} round={round} timeout #{}: retransmitting {n_frames} \
                     frames and polling for {want:?}",
                    self.cfg.job,
                    self.cfg.client_id,
                    self.timeouts
                );
                if rejoining {
                    // The in-flight Join (or its ack) was lost.
                    self.stats.retransmissions += 1;
                    out_frames.push(self.join_datagram());
                }
                if resync {
                    self.stats.quorum_resyncs += 1;
                } else {
                    self.stats.retransmissions += n_frames as u64;
                    let Phase::Waiting { frames, .. } = &self.phase else { unreachable!() };
                    for f in frames.iter() {
                        out_frames.push(self.scratch.copy(f));
                    }
                }
                self.stats.polls += 1;
                let poll_hdr = Header {
                    kind: WireKind::Poll,
                    client: self.cfg.client_id,
                    job: self.cfg.job,
                    round,
                    block: 0,
                    n_blocks: 0,
                    elems: 0,
                    aux: want as u32,
                };
                out_frames.push(self.scratch.encode(&poll_hdr, &[]));
            }
        }
        self.deadline = Some(now + self.cfg.timeout);
        ClientOutput { frames: out_frames, timer: self.deadline, progress: None }
    }

    // ---- internals --------------------------------------------------------

    /// Slide the retransmit deadline on received traffic (any datagram
    /// while a wait is armed, decodable or not).
    fn touch(&mut self, now: Instant) {
        if self.deadline.is_some() && !matches!(self.phase, Phase::Dead) {
            self.deadline = Some(now + self.cfg.timeout);
        }
    }

    /// Terminal failure: go dead, disarm, emit the reason.
    fn fail(&mut self, reason: String) -> Progress {
        self.phase = Phase::Dead;
        self.deadline = None;
        Progress::Failed { reason }
    }

    /// Sideline a broadcast frame for a later wait (bounded by
    /// [`PENDING_CAP`]; overflow is counted, not silent). An exact
    /// duplicate of a block already stashed is skipped — reassembly is
    /// idempotent, so only the first copy can matter, and swarm-hosted
    /// clients see one fan-out copy per co-hosted client of the job.
    fn stash(&mut self, h: &Header, payload: &[u8]) {
        if self.pending.iter().any(|(p, _)| {
            p.kind == h.kind
                && p.round == h.round
                && p.block == h.block
                && p.n_blocks == h.n_blocks
                && p.aux == h.aux
        }) {
            return;
        }
        if self.pending.len() < PENDING_CAP {
            self.pending.push((*h, payload.to_vec()));
        } else {
            self.stats.pending_dropped += 1;
            crate::debug!(
                "job={} client={} round={} pending stash full: dropping sidelined {:?} broadcast",
                self.cfg.job,
                self.cfg.client_id,
                h.round,
                h.kind
            );
        }
    }

    /// The (idempotent) registration frame for this client's job.
    fn join_datagram(&mut self) -> Vec<u8> {
        let h = Header::control(WireKind::Join, self.cfg.job, self.cfg.client_id, 0, 0);
        self.scratch.encode(&h, &self.cfg.spec().encode())
    }

    /// Encode one phase's vote frames into pooled buffers (retained for
    /// retransmission; recycled when the wait completes).
    fn vote_frames(&mut self, round: u32, votes: &BitVec, local_max: f32) -> Vec<Vec<u8>> {
        votes.copy_bytes_into(&mut self.bitmap_buf);
        let budget = self.cfg.payload_budget;
        let n_blocks = vote_chunk_bounds(votes.len(), budget).count() as u32;
        let mut frames = Vec::with_capacity(n_blocks as usize);
        for (i, (dims, lo, hi)) in vote_chunk_bounds(votes.len(), budget).enumerate() {
            let header = Header {
                kind: WireKind::Vote,
                client: self.cfg.client_id,
                job: self.cfg.job,
                round,
                block: i as u32,
                n_blocks,
                elems: dims as u32,
                aux: local_max.to_bits(),
            };
            frames.push(self.scratch.encode(&header, &self.bitmap_buf[lo..hi]));
        }
        frames
    }

    /// Encode one phase's update frames into pooled buffers, packing
    /// each block's lanes through one reused serialisation buffer.
    fn update_frames(&mut self, round: u32, lanes: &[i32], f: f32) -> Vec<Vec<u8>> {
        let budget = self.cfg.payload_budget;
        let n_blocks = update_chunk_bounds(lanes.len(), budget).count() as u32;
        let mut frames = Vec::with_capacity(n_blocks as usize);
        for (i, (lo, hi)) in update_chunk_bounds(lanes.len(), budget).enumerate() {
            crate::wire::encode_lanes_into(&mut self.lane_buf, &lanes[lo..hi]);
            let header = Header {
                kind: WireKind::Update,
                client: self.cfg.client_id,
                job: self.cfg.job,
                round,
                block: i as u32,
                n_blocks,
                elems: (hi - lo) as u32,
                aux: f.to_bits(),
            };
            frames.push(self.scratch.encode(&header, &self.lane_buf));
        }
        frames
    }

    /// Common wait entry: drain the stash (frames of this round's
    /// `want` kind captured during the previous wait complete the phase
    /// *without any upload*, exactly like the blocking driver's
    /// pre-send pending drain), else emit the upload and arm the timer.
    fn enter_wait(
        &mut self,
        round: u32,
        want: WireKind,
        frames: Vec<Vec<u8>>,
        expect_lanes: usize,
        now: Instant,
    ) -> ClientOutput {
        let mut asm: Option<(ChunkAssembler, u32)> = None;
        // Drain stashed frames from the previous wait of this round.
        self.pending.retain(|(h, _)| h.round == round);
        let (mine, keep): (Vec<_>, Vec<_>) =
            std::mem::take(&mut self.pending).into_iter().partition(|(h, _)| h.kind == want);
        self.pending = keep;
        for (h, payload) in mine {
            if let Some(done) =
                ingest_chunk(&mut asm, self.max_blocks, &h, &payload, &mut self.stats)
            {
                for f in frames {
                    self.scratch.give(f);
                }
                self.deadline = None;
                let progress = self.complete(round, want, expect_lanes, done, now, now);
                return ClientOutput { frames: Vec::new(), timer: None, progress: Some(progress) };
            }
        }
        // Emit pooled copies; the originals stay behind for retransmits.
        let out_frames: Vec<Vec<u8>> = frames.iter().map(|f| self.scratch.copy(f)).collect();
        self.phase =
            Phase::Waiting { round, want, frames, expect_lanes, asm, rejoining: false, started: now };
        self.timeouts = 0;
        self.deadline = Some(now + self.cfg.timeout);
        ClientOutput { frames: out_frames, timer: self.deadline, progress: None }
    }

    /// A completed broadcast: record the RTT, decode and validate, and
    /// surface the phase's event (or a terminal failure — the same
    /// conditions the blocking driver has always treated as fatal).
    fn complete(
        &mut self,
        round: u32,
        want: WireKind,
        expect_lanes: usize,
        (bytes, aux): (Vec<u8>, u32),
        started: Instant,
        now: Instant,
    ) -> Progress {
        match want {
            WireKind::Gia => {
                self.stats.vote_rtt_us.record_micros(now.duration_since(started));
                let Some(gia) = golomb::decode_with_limit(&bytes, self.cfg.d) else {
                    return self.fail("GIA broadcast failed to Golomb-decode".to_string());
                };
                if gia.len() != self.cfg.d {
                    return self.fail(format!("GIA length {} != d", gia.len()));
                }
                let global_max = f32::from_bits(aux);
                if !(global_max.is_finite() && global_max > 0.0) {
                    return self.fail(format!(
                        "GIA broadcast carried a non-finite global max ({global_max})"
                    ));
                }
                Progress::GiaReady { round, gia, global_max }
            }
            WireKind::Aggregate => {
                self.stats.update_rtt_us.record_micros(now.duration_since(started));
                let lanes = match decode_lanes(&bytes) {
                    Ok(l) => l,
                    Err(e) => return self.fail(format!("aggregate broadcast: {e}")),
                };
                if lanes.len() != expect_lanes || aux as usize != expect_lanes {
                    return self.fail(format!(
                        "aggregate has {} lanes, expected k_S = {}",
                        lanes.len(),
                        expect_lanes
                    ));
                }
                Progress::AggregateReady { round, lanes }
            }
            _ => unreachable!("waits only complete on broadcast kinds"),
        }
    }

    /// A frame while in the Joining phase.
    fn handle_joining(&mut self, h: &Header) -> ClientOutput {
        let mut progress = None;
        if h.kind == WireKind::JoinAck && h.job == self.cfg.job {
            if h.aux == JOIN_OK {
                self.joined = true;
                self.phase = Phase::Idle;
                self.deadline = None;
                progress = Some(Progress::Joined);
            } else {
                progress = Some(self.fail(format!("server refused join: status {}", h.aux)));
            }
        }
        // Stray broadcasts from an earlier round — ignore.
        ClientOutput { frames: Vec::new(), timer: self.deadline, progress }
    }

    /// A frame while a phase wait is armed. Robustness here (all
    /// chaos-matrix-proven):
    /// * mixed streams — a frame disagreeing with the in-progress
    ///   assembly on `n_blocks` or `aux` restarts the assembler instead
    ///   of completing with garbage;
    /// * re-join — a `JOIN_UNKNOWN_JOB` ack triggers an *inline* Join so
    ///   wanted broadcast frames arriving meanwhile still count;
    /// * phase overlap — broadcast frames of this round's other phase
    ///   are stashed in `pending` for the next wait instead of being
    ///   dropped into a retransmission cycle.
    fn handle_waiting(&mut self, h: &Header, payload: &[u8], now: Instant) -> ClientOutput {
        let mut out_frames = Vec::new();
        let mut progress = None;

        enum Action {
            Ingest,
            Stash,
            Rejoin,
            Reupload,
            Refuse(u32),
            Ignore,
        }
        let action = {
            let Phase::Waiting { round, want, rejoining, .. } = &self.phase else {
                unreachable!()
            };
            if h.job != self.cfg.job {
                Action::Ignore
            } else if h.kind == *want && h.round == *round {
                Action::Ingest
            } else if (h.kind == WireKind::Gia || h.kind == WireKind::Aggregate)
                && h.round == *round
            {
                // The other phase's broadcast for this round: keep it
                // for the next wait.
                Action::Stash
            } else if h.kind == WireKind::JoinAck {
                match h.aux {
                    JOIN_UNKNOWN_JOB if !*rejoining => Action::Rejoin,
                    // Repeated UNKNOWN_JOB while our re-join is already
                    // in flight: the timer path retransmits the Join.
                    JOIN_UNKNOWN_JOB => Action::Ignore,
                    JOIN_OK if *rejoining => Action::Reupload,
                    JOIN_OK => Action::Ignore, // duplicate ack of an earlier join
                    status if *rejoining => Action::Refuse(status),
                    // Unsolicited non-OK ack (spoof or stale): only a
                    // refusal of *our* in-flight re-join may kill the
                    // round.
                    _ => Action::Ignore,
                }
            } else {
                // NotReady / stale rounds / other phases: keep waiting.
                Action::Ignore
            }
        };

        match action {
            Action::Ignore => {}
            Action::Ingest => {
                let Phase::Waiting { asm, .. } = &mut self.phase else { unreachable!() };
                if let Some(done) =
                    ingest_chunk(asm, self.max_blocks, h, payload, &mut self.stats)
                {
                    let Phase::Waiting { round, want, frames, expect_lanes, started, .. } =
                        std::mem::replace(&mut self.phase, Phase::Idle)
                    else {
                        unreachable!()
                    };
                    for f in frames {
                        self.scratch.give(f);
                    }
                    self.deadline = None;
                    progress = Some(self.complete(round, want, expect_lanes, done, started, now));
                }
            }
            Action::Stash => self.stash(h, payload),
            Action::Rejoin => {
                // Server lost (or never had) our registration; re-join
                // without leaving this wait.
                let Phase::Waiting { round, rejoining, .. } = &mut self.phase else {
                    unreachable!()
                };
                *rejoining = true;
                let round = *round;
                self.stats.rejoins += 1;
                crate::debug!(
                    "job={} client={} round={round} re-joining after UNKNOWN_JOB",
                    self.cfg.job,
                    self.cfg.client_id
                );
                out_frames.push(self.join_datagram());
            }
            Action::Reupload => {
                // Re-registered. The server may have lost every round
                // state too — re-upload this phase's frames.
                let Phase::Waiting { frames, rejoining, .. } = &mut self.phase else {
                    unreachable!()
                };
                *rejoining = false;
                self.stats.retransmissions += frames.len() as u64;
                let Phase::Waiting { frames, .. } = &self.phase else { unreachable!() };
                for f in frames.iter() {
                    out_frames.push(self.scratch.copy(f));
                }
            }
            Action::Refuse(status) => {
                progress = Some(self.fail(format!("server refused re-join: status {status}")));
            }
        }
        ClientOutput { frames: out_frames, timer: self.deadline, progress }
    }
}

/// Feed one broadcast chunk into the (lazily created) assembler. Frames
/// are cross-checked against the stream in progress: a different
/// `n_blocks` or aux word means two broadcasts are interleaved (a stale
/// or truncated-spec stream mixed with the real one) — the assembler
/// restarts from the newer frame instead of completing with chunks from
/// both. Implausibly large geometry is ignored outright. Returns the
/// reassembled payload and aux once complete.
pub(crate) fn ingest_chunk(
    asm: &mut Option<(ChunkAssembler, u32)>,
    max_blocks: usize,
    h: &Header,
    payload: &[u8],
    stats: &mut ClientStats,
) -> Option<(Vec<u8>, u32)> {
    let n_blocks = h.n_blocks as usize;
    if n_blocks == 0 || n_blocks > max_blocks {
        return None;
    }
    if asm.as_ref().is_some_and(|(a, aux)| a.n_blocks() != n_blocks || *aux != h.aux) {
        stats.stream_resets += 1;
        crate::debug!(
            "job={} round={} {:?} stream reset: interleaved broadcast disagrees on geometry/aux",
            h.job,
            h.round,
            h.kind
        );
        *asm = None;
    }
    let (a, _) = asm.get_or_insert_with(|| (ChunkAssembler::new(n_blocks), h.aux));
    a.insert(h.block as usize, payload);
    if a.is_complete() {
        let (a, aux) = asm.take().expect("assembler just used");
        Some((a.assemble(), aux))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::byte_chunks;

    fn bcast_header(n_blocks: u32, block: u32, aux: u32) -> Header {
        Header {
            kind: WireKind::Gia,
            client: u16::MAX,
            job: 1,
            round: 1,
            block,
            n_blocks,
            elems: 0,
            aux,
        }
    }

    #[test]
    fn ingest_chunk_resets_on_mixed_streams() {
        let mut stats = ClientStats::default();
        let data: Vec<u8> = (0..=89u8).collect();
        let chunks = byte_chunks(&data, 30); // 3 chunks
        let mut asm: Option<(ChunkAssembler, u32)> = None;

        // Two chunks of the real stream…
        assert!(ingest_chunk(&mut asm, 100, &bcast_header(3, 0, 7), &chunks[0], &mut stats)
            .is_none());
        assert!(ingest_chunk(&mut asm, 100, &bcast_header(3, 2, 7), &chunks[2], &mut stats)
            .is_none());
        // …then a stale broadcast with different geometry interleaves:
        // the assembler must restart, not mix chunks from both streams.
        assert!(ingest_chunk(&mut asm, 100, &bcast_header(2, 0, 7), &[1, 2], &mut stats)
            .is_none());
        assert_eq!(stats.stream_resets, 1);
        // A frame agreeing on geometry but not on aux also resets.
        assert!(ingest_chunk(&mut asm, 100, &bcast_header(2, 1, 9), &[3, 4], &mut stats)
            .is_none());
        assert_eq!(stats.stream_resets, 2);
        // The real stream, uninterrupted, completes with the right bytes
        // (nothing from the interleaved impostors survives).
        for (i, c) in chunks.iter().enumerate() {
            if let Some(done) =
                ingest_chunk(&mut asm, 100, &bcast_header(3, i as u32, 7), c, &mut stats)
            {
                assert_eq!(i, 2, "completed early");
                assert_eq!(done, (data.clone(), 7));
                assert_eq!(stats.stream_resets, 3);
                return;
            }
        }
        panic!("real stream never completed");
    }

    #[test]
    fn ingest_chunk_ignores_implausible_geometry() {
        let mut stats = ClientStats::default();
        let mut asm: Option<(ChunkAssembler, u32)> = None;
        // A forged frame declaring 2^31 blocks must not size the
        // assembler (that would be a multi-gigabyte allocation).
        let h = bcast_header(1 << 31, 0, 0);
        assert!(ingest_chunk(&mut asm, 64, &h, &[], &mut stats).is_none());
        assert!(asm.is_none());
        assert!(ingest_chunk(&mut asm, 64, &bcast_header(0, 0, 0), &[], &mut stats).is_none());
        assert!(asm.is_none());
    }
}
