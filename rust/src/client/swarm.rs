//! The swarm multiplexer: thousands of [`ClientCore`] instances driven
//! by ONE thread over a configurable handful of UDP sockets.
//!
//! This is the client-side twin of the server's single-thread reactor
//! (`server/reactor.rs`), and the payoff of the sans-I/O split: the
//! blocking driver burns a thread and a socket per client, which proves
//! bit-exactness but not scale; the swarm hosts 10k+ simulated clients
//! on ≤ 8 sockets by multiplexing every core's frames, timers and round
//! math through one event loop —
//!
//! ```text
//!   wait_readable_many(≤8 sockets) ──► recvmmsg drain ──► decode once
//!        ▲                                   │
//!        │                        demux: directed → one core
//!        │                               broadcast → cores waiting on
//!        │                                           that round
//!   TimerWheel (1 entry/client) ◄── ClientOutput{frames,timer,progress}
//!        │                                   │
//!        └── on_tick → retransmit      sendmmsg bursts (per socket)
//! ```
//!
//! Protocol behaviour is *identical* to the blocking driver — both
//! drive the same [`ClientCore`] — so a swarm round is bit-exact
//! against `algorithms::fediac` exactly like a driver round is
//! (asserted in `tests/wire_backend.rs`). Jobs are routed to sockets
//! round-robin; all clients of a job share one socket, so the server's
//! per-client broadcast fan-out lands as n copies on that socket and
//! the demux forwards each copy only to the cores still waiting on the
//! round it belongs to (reassembly is idempotent, duplicates are
//! harmless). Uplink chaos is injected per socket through the same
//! [`ChaosLane`] the blocking driver's `send_loss` alias uses.
//!
//! The swarm is also the vehicle for the client-churn fault plane
//! (`net::churn`): a seeded [`ChurnPlan`] predetermines which clients
//! die at a round boundary or right after their vote upload, which
//! corpses rejoin stale (fresh core, same identity, old round counter —
//! they re-sync from re-served broadcasts instead of contributing),
//! which join late as a flash crowd, and which never come back. Quorum
//! rounds (`SwarmOptions::quorum`, PROTOCOL.md §11) are what keeps the
//! fleet making progress while all of that happens.

use std::collections::HashMap;
use std::net::UdpSocket;
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::client::core::{ClientCore, ClientOutput, ClientStats, CoreConfig, Progress};
use crate::client::driver::RoundOutcome;
use crate::client::protocol;
use crate::compress;
use crate::net::chaos::{ChaosDirection, ChaosLane};
use crate::net::churn::{ChurnConfig, ChurnPlan, ClientChurn};
use crate::net::poll::{self, RecvBatch, TimerWheel};
use crate::telemetry::HistSummary;
use crate::util::{BitVec, Rng};
use crate::wire::{decode_frame, ShardPlan, DEFAULT_PAYLOAD_BUDGET, HEADER_LEN, MAX_DATAGRAM};

/// Most sockets a swarm may spread its jobs over (the ISSUE target:
/// 10k+ clients on a *handful* of sockets).
pub const MAX_SWARM_SOCKETS: usize = 8;
/// Datagrams drained per `recvmmsg` call and frames flushed per
/// `sendmmsg` burst (same batch depth as the blocking driver).
const SWARM_BATCH: usize = 32;
/// Batches drained per socket per loop iteration before yielding to
/// timers and the other sockets (256 datagrams — the reactor's budget).
const RECV_BUDGET_BATCHES: usize = 8;
/// Timer wheel shape: 10 ms × 512 slots, one entry per waiting client
/// (the reactor uses the same granularity for its per-job timers).
const WHEEL_GRANULARITY: Duration = Duration::from_millis(10);
const WHEEL_SLOTS: usize = 512;
/// Longest readiness wait when no timer is armed (keeps the loop
/// responsive to chaos-lane holds and shutdown even when idle).
const IDLE_WAIT: Duration = Duration::from_millis(25);
/// Readiness wait cap while an uplink chaos lane holds reordered
/// frames (they must be released on time, traffic or not).
const HOLD_WAIT: Duration = Duration::from_millis(5);

/// Where a swarm job's per-round client updates come from.
#[derive(Debug, Clone)]
pub enum UpdateSource {
    /// The bench-wire synthetic stream: round r of client c draws from
    /// `Rng::new(backend_seed ^ (c << 32) ^ r)`, scaled Gaussian, with
    /// the client's running residual folded in (Algorithm 1) — byte-for
    /// byte the stream `fediac bench-wire` drives through the blocking
    /// driver, so swarm and driver benches measure the same workload.
    Synthetic,
    /// Explicit updates, indexed `[round - 1][client]`, each of length
    /// d, used exactly as given (no residual folding — the caller owns
    /// the stream, as with [`crate::client::FediacClient::run_round`]).
    Explicit(Vec<Vec<Vec<f32>>>),
}

/// One job (tenant) hosted by the swarm.
#[derive(Debug, Clone)]
pub struct SwarmJobPlan {
    /// Wire job id.
    pub job: u32,
    /// Clients N of this job (all hosted by this swarm).
    pub n_clients: u16,
    /// The job's shared seed root (vote/quantise RNG streams).
    pub backend_seed: u64,
    /// Per-round update streams.
    pub updates: UpdateSource,
}

/// Swarm shape: the fleet, the protocol parameters shared by every job,
/// and the I/O budget.
#[derive(Debug, Clone)]
pub struct SwarmOptions {
    /// Server address, e.g. "127.0.0.1:7177".
    pub server: String,
    /// The hosted jobs. Total clients = Σ `n_clients`.
    pub jobs: Vec<SwarmJobPlan>,
    /// Model dimension d (shared — one fleet, one model shape).
    pub d: usize,
    /// Voting threshold a.
    pub threshold_a: u16,
    /// Votes per client k (paper: 5%·d).
    pub k: usize,
    /// Quantisation bits b.
    pub bits_b: usize,
    /// Payload bytes per data frame.
    pub payload_budget: usize,
    /// Rounds every client executes.
    pub rounds: usize,
    /// UDP sockets to spread jobs over (1..= [`MAX_SWARM_SOCKETS`]).
    pub sockets: usize,
    /// Per-wait silence tolerated before a retransmit cycle.
    pub timeout: Duration,
    /// Timeouts tolerated per wait before a client fails the swarm.
    pub max_retries: usize,
    /// Uplink chaos (loss/dup/reorder/corrupt) applied per socket on
    /// the way out — the swarm-side equivalent of the driver's
    /// `send_loss`/chaos-proxy uplink. `None` = reliable uplink.
    pub uplink_chaos: Option<ChaosDirection>,
    /// Seed for the uplink chaos lanes (decorrelated per socket).
    pub chaos_seed: u64,
    /// Keep every client's [`RoundOutcome`]s for equivalence checks.
    /// Costs memory (outcomes hold the GIA + lanes per round) — leave
    /// off for large fleets.
    pub collect_outcomes: bool,
    /// Quorum Q stamped into every hosted job's spec (0 = legacy all-N
    /// rounds; see PROTOCOL.md §11).
    pub quorum: u16,
    /// Client-churn plane: kills, stale rejoins, flash crowds,
    /// permanent deaths. `None` (or a quiet config) leaves every client
    /// immortal. The lifecycle plan derives from `chaos_seed`, so the
    /// same `(chaos_seed, churn)` replays the same schedule.
    pub churn: Option<ChurnConfig>,
}

impl SwarmOptions {
    /// Defaults matching [`crate::client::ClientOptions::new`] where the
    /// knobs overlap; the fleet starts empty — push [`SwarmJobPlan`]s or
    /// use [`plan_fleet`].
    pub fn new(server: impl Into<String>, d: usize) -> Self {
        SwarmOptions {
            server: server.into(),
            jobs: Vec::new(),
            d,
            threshold_a: 1,
            k: protocol::votes_per_client(d, 0.05),
            bits_b: 12,
            payload_budget: DEFAULT_PAYLOAD_BUDGET,
            rounds: 1,
            sockets: MAX_SWARM_SOCKETS,
            timeout: Duration::from_millis(200),
            max_retries: 50,
            uplink_chaos: None,
            chaos_seed: 0,
            collect_outcomes: false,
            quorum: 0,
            churn: None,
        }
    }
}

/// Carve `total_clients` into bench-wire-shaped jobs: ids `1000 + j`,
/// per-job seed `seed ^ (j << 16)`, `clients_per_job` clients each (the
/// last job takes the remainder), synthetic update streams — the same
/// workload `fediac bench-wire` runs through the blocking driver.
pub fn plan_fleet(total_clients: usize, clients_per_job: u16, seed: u64) -> Vec<SwarmJobPlan> {
    assert!(clients_per_job > 0, "clients_per_job must be > 0");
    let per = clients_per_job as usize;
    let mut plans = Vec::new();
    let mut remaining = total_clients;
    let mut j = 0u64;
    while remaining > 0 {
        let n = remaining.min(per) as u16;
        plans.push(SwarmJobPlan {
            job: 1000 + j as u32,
            n_clients: n,
            backend_seed: seed ^ (j << 16),
            updates: UpdateSource::Synthetic,
        });
        remaining -= n as usize;
        j += 1;
    }
    plans
}

/// What the churn plane actually did during a run (all zero when the
/// plane is off).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChurnSummary {
    /// Clients killed by the plan (round-start and after-vote kills).
    pub kills: usize,
    /// Corpses that came back stale and resumed their run.
    pub rejoins: usize,
    /// Kills that never rejoined (their registration is the server's to
    /// reclaim at the quorum close / idle reap).
    pub permanent_deaths: usize,
    /// Flash-crowd clients whose delayed first Join actually fired.
    pub flash_joins: usize,
    /// Churned clients that later exhausted retries and were written
    /// off as casualties instead of failing the swarm.
    pub stranded: usize,
}

/// What a completed swarm run measured.
#[derive(Debug, Clone)]
pub struct SwarmReport {
    /// Clients hosted (Σ of every job's N).
    pub clients_hosted: usize,
    /// Jobs hosted.
    pub jobs: usize,
    /// Sockets actually used.
    pub sockets_used: usize,
    /// Client-rounds completed (clients_hosted × rounds on success).
    pub rounds_completed: u64,
    /// Wall-clock seconds, join through last aggregate.
    pub wall_s: f64,
    /// Per-client-round end-to-end latency (vote upload → aggregate
    /// decoded), one sample per client per round — the swarm twin of
    /// bench-wire's per-`run_round` histogram.
    pub round_latency: HistSummary,
    /// Folded counters of every hosted client, plus the swarm's socket
    /// byte meters and uplink-lane drops.
    pub stats: ClientStats,
    /// Every client's round outcomes, indexed `[job][client][round-1]`
    /// — only when [`SwarmOptions::collect_outcomes`] was set.
    pub outcomes: Option<Vec<Vec<Vec<RoundOutcome>>>>,
    /// What the churn plane did (zeros when it was off).
    pub churn: ChurnSummary,
}

/// One hosted client: its protocol core plus the round math the
/// blocking driver's `run_round` performs between phases.
struct SwarmClient {
    core: ClientCore,
    job_idx: usize,
    sock_idx: usize,
    cid: u16,
    /// Round currently executing (1-based; 0 = still joining).
    round: usize,
    /// This round's update (residual already folded for synthetic
    /// streams) — kept for the phase-2 quantisation.
    update: Vec<f32>,
    /// Residual carried across rounds (synthetic streams).
    residual: Vec<f32>,
    /// Phase-1 results held while phase 2 is in flight.
    ctx: Option<RoundCtx>,
    /// `core.stats.retransmissions` at round start (per-round delta).
    retx_at_round_start: u64,
    /// When this round's vote upload went out (latency sample).
    round_started: Instant,
    /// An entry for this client is sitting in the timer wheel.
    armed: bool,
    /// All rounds finished (or the client is permanently dead).
    done: bool,
    /// Collected outcomes (only with `collect_outcomes`).
    outcomes: Vec<RoundOutcome>,
    /// This client's predetermined lifecycle (quiet without churn).
    churn: ClientChurn,
    /// Dark: killed, or a flash-crowd first Join still pending.
    dead: bool,
    /// When a dark client comes back (rejoin / delayed first join).
    wake_at: Option<Instant>,
    /// Died once and came back — planned kills never repeat.
    revived: bool,
    /// Stats banked from the core discarded at rejoin.
    banked: ClientStats,
}

/// Phase-1 results a client needs to finish the round at aggregate time.
struct RoundCtx {
    gia: BitVec,
    gia_indices: Vec<usize>,
    global_max: f32,
    scale_f: f32,
    residual_next: Vec<f32>,
}

/// What happened after a client digested an aggregate: the next round's
/// first output, the natural end of its run, or a planned churn death.
enum AfterRound {
    Continue(ClientOutput),
    Finished,
    Dark,
}

impl SwarmClient {
    /// The churn plan kills this client in its current round.
    fn planned_kill(&self) -> bool {
        !self.revived && self.churn.kill_at_round == Some(self.round as u32)
    }

    /// Compute this round's update and votes and start phase 1.
    /// `None` means the churn plan kills the client at this round's
    /// start: it goes dark having sent nothing for the round.
    fn begin_round(&mut self, opts: &SwarmOptions, now: Instant) -> Result<Option<ClientOutput>> {
        if self.planned_kill() && !self.churn.after_vote {
            return Ok(None);
        }
        let plan = &opts.jobs[self.job_idx];
        let round = self.round;
        self.update = match &plan.updates {
            UpdateSource::Synthetic => {
                let seed = plan.backend_seed ^ ((self.cid as u64) << 32) ^ round as u64;
                let mut rng = Rng::new(seed);
                let mut update: Vec<f32> =
                    (0..opts.d).map(|_| (rng.gaussian() * 0.01) as f32).collect();
                for (u, r) in update.iter_mut().zip(&self.residual) {
                    *u += *r;
                }
                update
            }
            UpdateSource::Explicit(rounds) => {
                let per_round = rounds.get(round - 1).with_context(|| {
                    format!("job {} has no explicit updates for round {round}", plan.job)
                })?;
                let u = per_round.get(self.cid as usize).with_context(|| {
                    format!("job {} round {round} has no update for client {}", plan.job, self.cid)
                })?;
                anyhow::ensure!(
                    u.len() == opts.d,
                    "job {} round {round} client {}: update dimension {} != d {}",
                    plan.job,
                    round,
                    self.cid,
                    u.len(),
                    opts.d
                );
                u.clone()
            }
        };
        let votes = protocol::client_vote(
            &self.update,
            opts.k,
            plan.backend_seed,
            round,
            self.cid as usize,
        );
        let local_max = compress::max_abs(&self.update);
        self.retx_at_round_start = self.core.stats.retransmissions;
        self.round_started = now;
        Ok(Some(self.core.start_vote(round as u32, &votes, local_max, now)))
    }

    /// Phase 1 done: quantise against the GIA and start phase 2 —
    /// the same math as the blocking driver's `run_round`.
    fn on_gia(
        &mut self,
        opts: &SwarmOptions,
        gia: BitVec,
        global_max: f32,
        now: Instant,
    ) -> ClientOutput {
        let plan = &opts.jobs[self.job_idx];
        let f = compress::scale_factor(opts.bits_b, plan.n_clients as usize, global_max);
        let (q, residual_next) = protocol::client_quantize(
            &self.update,
            &gia.to_f32_mask(),
            f,
            plan.backend_seed,
            self.round,
            self.cid as usize,
        );
        let gia_indices: Vec<usize> = gia.iter_ones().collect();
        let selected: Vec<i32> = gia_indices.iter().map(|&g| q[g]).collect();
        self.ctx = Some(RoundCtx { gia, gia_indices, global_max, scale_f: f, residual_next });
        self.core.start_update(self.round as u32, &selected, f, now)
    }

    /// Phase 2 done: close the round (residual carry, optional outcome
    /// capture), advance to the next round or finish.
    fn on_aggregate(
        &mut self,
        opts: &SwarmOptions,
        lanes: Vec<i32>,
        latency: &mut HistSummary,
        rounds_completed: &mut u64,
        now: Instant,
    ) -> Result<AfterRound> {
        let plan = &opts.jobs[self.job_idx];
        let ctx = self.ctx.take().expect("aggregate without a phase-1 context");
        latency.record_micros(now.duration_since(self.round_started));
        *rounds_completed += 1;
        if opts.collect_outcomes {
            let delta =
                compress::dequantize_aggregate(&lanes, plan.n_clients as usize, ctx.scale_f);
            self.outcomes.push(RoundOutcome {
                gia: ctx.gia,
                gia_indices: ctx.gia_indices,
                global_max: ctx.global_max,
                scale_f: ctx.scale_f,
                aggregate: lanes,
                delta,
                residual: ctx.residual_next.clone(),
                retransmissions: self.core.stats.retransmissions - self.retx_at_round_start,
            });
        }
        self.residual = ctx.residual_next;
        if self.round >= opts.rounds {
            self.done = true;
            return Ok(AfterRound::Finished);
        }
        self.round += 1;
        Ok(match self.begin_round(opts, now)? {
            Some(out) => AfterRound::Continue(out),
            None => AfterRound::Dark,
        })
    }
}

/// Per-socket I/O state: connected nonblocking socket, receive batch,
/// outgoing frame queue (with owning client, for buffer recycling) and
/// optional uplink chaos lane.
struct SockState {
    socket: UdpSocket,
    batch: RecvBatch,
    /// Outgoing `(frame, owner client idx)` queue, flushed each loop.
    txq: Vec<(Vec<u8>, usize)>,
    lane: Option<ChaosLane<()>>,
}

/// Run the swarm to completion: join every client, execute every round,
/// return the measurements. One thread, no spawns — everything happens
/// on the caller's thread.
pub fn run(opts: &SwarmOptions) -> Result<SwarmReport> {
    // The same admission checks the blocking driver performs, once per
    // shape instead of once per client.
    anyhow::ensure!(!opts.jobs.is_empty(), "swarm has no jobs");
    anyhow::ensure!(
        (1..=MAX_SWARM_SOCKETS).contains(&opts.sockets),
        "sockets must be in [1, {MAX_SWARM_SOCKETS}]"
    );
    anyhow::ensure!(opts.rounds > 0, "rounds must be > 0");
    anyhow::ensure!(
        opts.payload_budget <= u16::MAX as usize,
        "payload_budget {} exceeds the wire maximum {}",
        opts.payload_budget,
        u16::MAX
    );
    anyhow::ensure!(opts.d <= u32::MAX as usize, "d {} exceeds the wire maximum", opts.d);
    for plan in &opts.jobs {
        anyhow::ensure!(plan.n_clients > 0, "job {} has no clients", plan.job);
        anyhow::ensure!(
            (2..=31).contains(&opts.bits_b) && (1i64 << (opts.bits_b - 1)) > plan.n_clients as i64,
            "bits_b={} too small for N={} (job {})",
            opts.bits_b,
            plan.n_clients,
            plan.job
        );
        make_core_config(opts, plan, 0)
            .spec()
            .validate()
            .map_err(|e| anyhow::anyhow!("bad swarm options for job {}: {e}", plan.job))?;
    }

    let sockets_used = opts.sockets.min(opts.jobs.len());
    let recv_len = (HEADER_LEN + opts.payload_budget).min(MAX_DATAGRAM);
    let mut socks: Vec<SockState> = Vec::with_capacity(sockets_used);
    for s in 0..sockets_used {
        let socket = UdpSocket::bind("0.0.0.0:0").context("binding swarm socket")?;
        socket
            .connect(&opts.server)
            .with_context(|| format!("connecting swarm socket to {}", opts.server))?;
        socket.set_nonblocking(true)?;
        // Decorrelate the lanes so co-hosted jobs don't lose the same
        // frames in lockstep.
        let lane = opts
            .uplink_chaos
            .filter(|c| !c.is_clean())
            .map(|c| ChaosLane::new(c, opts.chaos_seed ^ ((s as u64) << 24) ^ 0x5A_4A));
        socks.push(SockState {
            socket,
            batch: RecvBatch::new(SWARM_BATCH, recv_len),
            txq: Vec::new(),
            lane,
        });
    }

    // The churn plan covers the whole fleet by flat client index, so a
    // seed pins every lifecycle regardless of job layout.
    let total_clients: usize = opts.jobs.iter().map(|p| p.n_clients as usize).sum();
    let churn_plan: Option<ChurnPlan> = match &opts.churn {
        Some(cfg) if cfg.enabled() => {
            anyhow::ensure!(
                total_clients <= u16::MAX as usize,
                "churn plane supports at most {} clients, swarm hosts {total_clients}",
                u16::MAX
            );
            Some(ChurnPlan::new(cfg, opts.chaos_seed, total_clients as u16, opts.rounds as u32))
        }
        _ => None,
    };

    // Build the fleet: job j lives on socket j % sockets_used; clients
    // are contiguous in one flat Vec, indexed by `base[job_idx] + cid`.
    let mut clients: Vec<SwarmClient> = Vec::new();
    // job id → (job_idx, first client idx, n_clients).
    let mut jobs_by_id: HashMap<u32, (usize, usize, u16)> = HashMap::new();
    for (job_idx, plan) in opts.jobs.iter().enumerate() {
        let base = clients.len();
        anyhow::ensure!(
            jobs_by_id.insert(plan.job, (job_idx, base, plan.n_clients)).is_none(),
            "duplicate job id {}",
            plan.job
        );
        for cid in 0..plan.n_clients {
            let lifecycle = churn_plan
                .as_ref()
                .map(|p| *p.client(clients.len() as u16))
                .unwrap_or_else(ClientChurn::quiet);
            clients.push(SwarmClient {
                core: ClientCore::new(make_core_config(opts, plan, cid)),
                job_idx,
                sock_idx: job_idx % sockets_used,
                cid,
                round: 0,
                update: Vec::new(),
                residual: vec![0.0f32; opts.d],
                ctx: None,
                retx_at_round_start: 0,
                round_started: Instant::now(),
                armed: false,
                done: false,
                outcomes: Vec::new(),
                churn: lifecycle,
                dead: false,
                wake_at: None,
                revived: false,
                banked: ClientStats::default(),
            });
        }
    }
    let n_clients = clients.len();
    crate::info!(
        "swarm: {} clients across {} jobs on {} sockets, {} rounds",
        n_clients,
        opts.jobs.len(),
        sockets_used,
        opts.rounds
    );

    let started = Instant::now();
    let mut wheel: TimerWheel<usize> = TimerWheel::new(WHEEL_GRANULARITY, WHEEL_SLOTS, started);
    let mut latency = HistSummary::default();
    let mut rounds_completed = 0u64;
    let mut io_bytes_received = 0u64;
    let mut io_bytes_sent = 0u64;
    let mut remaining = n_clients;
    let mut churn_led = ChurnSummary::default();

    // Kick every client's join; the flash crowd parks dark on the
    // wheel instead and piles in `join_delay` later.
    for idx in 0..n_clients {
        if !clients[idx].churn.join_delay.is_zero() {
            let wake = started + clients[idx].churn.join_delay;
            clients[idx].dead = true;
            clients[idx].wake_at = Some(wake);
            wheel.insert(wake, idx);
            clients[idx].armed = true;
            continue;
        }
        let out = clients[idx].core.start_join(started);
        process_output(
            idx,
            out,
            opts,
            &mut clients,
            &mut socks,
            &mut wheel,
            &mut latency,
            &mut rounds_completed,
            &mut remaining,
            &mut churn_led,
            started,
        )?;
    }
    flush_tx(&mut socks, &mut clients, &mut io_bytes_sent);

    let mut ready: Vec<usize> = Vec::with_capacity(sockets_used);
    while remaining > 0 {
        let now = Instant::now();

        // 1. Fire due client timers (retransmit cycles / failures /
        //    churn wake-ups).
        for idx in wheel.pop_due(now) {
            clients[idx].armed = false;
            if clients[idx].done || clients[idx].core.is_failed() {
                continue; // stale entry of a finished client
            }
            let out = if clients[idx].dead {
                let wake = clients[idx].wake_at.expect("dark client without a wake time");
                if now < wake {
                    // The dead core's old protocol deadline fired
                    // first; park until the planned wake.
                    wheel.insert(wake, idx);
                    clients[idx].armed = true;
                    continue;
                }
                revive(idx, &mut clients, opts, &mut churn_led, now)
            } else {
                clients[idx].core.on_tick(now)
            };
            process_output(
                idx,
                out,
                opts,
                &mut clients,
                &mut socks,
                &mut wheel,
                &mut latency,
                &mut rounds_completed,
                &mut remaining,
                &mut churn_led,
                now,
            )?;
        }

        // 2. Release chaos-lane holds whose deadline passed.
        for s in 0..socks.len() {
            if socks[s].lane.as_ref().is_some_and(|l| l.held_len() > 0) {
                let released = socks[s].lane.as_mut().expect("held implies lane").flush_due(now);
                send_wire(&socks[s].socket, released, &mut io_bytes_sent);
            }
        }

        // 3. Drain readable sockets and demux into the cores.
        for s in 0..socks.len() {
            drain_socket(
                s,
                opts,
                &mut clients,
                &mut socks,
                &jobs_by_id,
                &mut wheel,
                &mut latency,
                &mut rounds_completed,
                &mut remaining,
                &mut churn_led,
                &mut io_bytes_received,
            )?;
        }

        // 4. Flush everything the cores emitted this iteration.
        flush_tx(&mut socks, &mut clients, &mut io_bytes_sent);
        if remaining == 0 {
            break;
        }

        // 5. Sleep until traffic, the next timer, or a lane hold.
        let now = Instant::now();
        let mut wait = wheel
            .next_deadline()
            .map(|d| d.saturating_duration_since(now))
            .unwrap_or(IDLE_WAIT)
            .min(IDLE_WAIT);
        if socks.iter().any(|s| s.lane.as_ref().is_some_and(|l| l.held_len() > 0)) {
            wait = wait.min(HOLD_WAIT);
        }
        let refs: Vec<&UdpSocket> = socks.iter().map(|s| &s.socket).collect();
        poll::wait_readable_many(&refs, Some(wait), &mut ready).context("swarm readiness wait")?;
    }

    let wall_s = started.elapsed().as_secs_f64().max(f64::EPSILON);
    let mut stats = ClientStats::default();
    for c in &clients {
        stats.add(&c.banked);
        stats.add(&c.core.stats);
    }
    stats.bytes_sent = io_bytes_sent;
    stats.bytes_received = io_bytes_received;
    stats.dropped_sends = socks
        .iter()
        .filter_map(|s| s.lane.as_ref())
        .map(|l| l.stats().dropped.load(Ordering::Relaxed))
        .sum();
    let outcomes = opts.collect_outcomes.then(|| {
        let mut per_job: Vec<Vec<Vec<RoundOutcome>>> =
            opts.jobs.iter().map(|p| Vec::with_capacity(p.n_clients as usize)).collect();
        for c in clients {
            per_job[c.job_idx].push(c.outcomes);
        }
        per_job
    });
    Ok(SwarmReport {
        clients_hosted: n_clients,
        jobs: opts.jobs.len(),
        sockets_used,
        rounds_completed,
        wall_s,
        round_latency: latency,
        stats,
        outcomes,
        churn: churn_led,
    })
}

/// Take a client dark per its churn plan: it stops sending and
/// receiving. A rejoinable corpse parks on the wheel until its wake
/// time; a permanent death leaves the swarm for good — its server-side
/// registration is the quorum close / idle reap's to reclaim.
fn go_dark(
    idx: usize,
    clients: &mut [SwarmClient],
    wheel: &mut TimerWheel<usize>,
    churn: &mut ChurnSummary,
    remaining: &mut usize,
    now: Instant,
) {
    let c = &mut clients[idx];
    c.dead = true;
    c.ctx = None;
    churn.kills += 1;
    match c.churn.rejoin_after {
        Some(delay) => {
            let wake = now + delay;
            c.wake_at = Some(wake);
            if !c.armed {
                wheel.insert(wake, idx);
                c.armed = true;
            }
        }
        None => {
            churn.permanent_deaths += 1;
            c.done = true;
            *remaining -= 1;
        }
    }
}

/// Bring a dark client back: a flash-crowd client fires its delayed
/// first Join; a corpse rejoins STALE — fresh protocol core, same
/// identity, old round counter — so it re-enters the round it died in,
/// discovers the fleet quorum-closed it, and re-syncs from the
/// re-served broadcasts instead of contributing.
fn revive(
    idx: usize,
    clients: &mut [SwarmClient],
    opts: &SwarmOptions,
    churn: &mut ChurnSummary,
    now: Instant,
) -> ClientOutput {
    let c = &mut clients[idx];
    if c.round == 0 {
        churn.flash_joins += 1;
    } else {
        churn.rejoins += 1;
        c.banked.add(&c.core.stats);
        c.core = ClientCore::new(make_core_config(opts, &opts.jobs[c.job_idx], c.cid));
        c.revived = true;
    }
    c.dead = false;
    c.wake_at = None;
    c.ctx = None;
    c.core.start_join(now)
}

/// The core config for one hosted client.
fn make_core_config(opts: &SwarmOptions, plan: &SwarmJobPlan, cid: u16) -> CoreConfig {
    CoreConfig {
        job: plan.job,
        client_id: cid,
        n_clients: plan.n_clients,
        d: opts.d,
        threshold_a: opts.threshold_a,
        payload_budget: opts.payload_budget,
        timeout: opts.timeout,
        max_retries: opts.max_retries,
        shard: ShardPlan::single(),
        quorum: opts.quorum,
    }
}

/// Drain one socket's receive queue (bounded) and feed every datagram
/// to the cores it concerns: directed frames to their addressed client,
/// broadcast copies to every client of the job still waiting on that
/// round (decode happens ONCE per datagram, not per client).
#[allow(clippy::too_many_arguments)]
fn drain_socket(
    s: usize,
    opts: &SwarmOptions,
    clients: &mut [SwarmClient],
    socks: &mut [SockState],
    jobs_by_id: &HashMap<u32, (usize, usize, u16)>,
    wheel: &mut TimerWheel<usize>,
    latency: &mut HistSummary,
    rounds_completed: &mut u64,
    remaining: &mut usize,
    churn: &mut ChurnSummary,
    io_bytes_received: &mut u64,
) -> Result<()> {
    // Indices to deliver to, computed per datagram (tiny: 1 for a
    // directed frame, the waiting subset of one job for a broadcast).
    let mut targets: Vec<usize> = Vec::new();
    // Payload copy per datagram — the batch buffer can't stay borrowed
    // while the cores (behind `&mut clients`) consume the frame.
    let mut payload_buf: Vec<u8> = Vec::new();
    for _ in 0..RECV_BUDGET_BATCHES {
        let got = {
            let st = &mut socks[s];
            match poll::recv_batch(&st.socket, &mut st.batch) {
                Ok(0) => return Ok(()),
                Ok(n) => n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(()),
                // Connected UDP sockets surface ICMP errors on reads;
                // skip and let retransmission recover.
                Err(_) => return Ok(()),
            }
        };
        let now = Instant::now();
        for i in 0..got {
            targets.clear();
            let h = {
                let (bytes, _) = socks[s].batch.datagram(i);
                *io_bytes_received += bytes.len() as u64;
                let Ok(frame) = decode_frame(bytes) else { continue };
                let h = frame.header;
                let Some(&(_, base, n)) = jobs_by_id.get(&h.job) else { continue };
                if h.client != u16::MAX {
                    // Directed (JoinAck / NotReady): exactly one owner.
                    // Dark clients hear nothing — their NIC is gone.
                    let idx = base + h.client as usize;
                    if h.client < n && !clients[idx].dead {
                        targets.push(idx);
                    }
                } else {
                    // Broadcast copy: every client of the job still
                    // waiting on this round can use it (the rest would
                    // ignore or re-stash a duplicate anyway).
                    for idx in base..base + n as usize {
                        if !clients[idx].dead
                            && clients[idx].core.waiting_round() == Some(h.round)
                        {
                            targets.push(idx);
                        }
                    }
                }
                payload_buf.clear();
                payload_buf.extend_from_slice(frame.payload);
                h
            };
            for &idx in &targets {
                if clients[idx].dead {
                    continue; // went dark while this batch was handled
                }
                let out = clients[idx].core.handle_frame(&h, &payload_buf, now);
                process_output(
                    idx,
                    out,
                    opts,
                    clients,
                    socks,
                    wheel,
                    latency,
                    rounds_completed,
                    remaining,
                    churn,
                    now,
                )?;
            }
        }
    }
    Ok(())
}

/// Act on one [`ClientOutput`]: queue its frames on the owner's socket,
/// keep the one-entry-per-client timer invariant, and chase progress
/// events through the round state machine (a progress usually starts
/// the next phase, which yields another output — loop until quiet).
#[allow(clippy::too_many_arguments)]
fn process_output(
    idx: usize,
    mut out: ClientOutput,
    opts: &SwarmOptions,
    clients: &mut [SwarmClient],
    socks: &mut [SockState],
    wheel: &mut TimerWheel<usize>,
    latency: &mut HistSummary,
    rounds_completed: &mut u64,
    remaining: &mut usize,
    churn: &mut ChurnSummary,
    now: Instant,
) -> Result<()> {
    loop {
        let sock_idx = clients[idx].sock_idx;
        for f in out.frames.drain(..) {
            socks[sock_idx].txq.push((f, idx));
        }
        if let Some(deadline) = out.timer {
            // One wheel entry per client, ever: a stale (early) entry
            // re-arms itself via `on_tick`, so a second insert would
            // only multiply wakeups.
            if !clients[idx].armed {
                wheel.insert(deadline, idx);
                clients[idx].armed = true;
            }
        }
        let Some(progress) = out.progress.take() else { return Ok(()) };
        out = match progress {
            Progress::Joined => {
                let c = &mut clients[idx];
                // A stale rejoiner keeps its old round counter; a
                // first-time join (flash crowd included) starts at 1.
                c.round = c.round.max(1);
                match c.begin_round(opts, now)? {
                    Some(next) => next,
                    None => {
                        go_dark(idx, clients, wheel, churn, remaining, now);
                        return Ok(());
                    }
                }
            }
            Progress::GiaReady { gia, global_max, .. } => {
                let c = &clients[idx];
                if c.planned_kill() && c.churn.after_vote {
                    // Killed mid-upload: the votes went out, the
                    // update never will.
                    go_dark(idx, clients, wheel, churn, remaining, now);
                    return Ok(());
                }
                clients[idx].on_gia(opts, gia, global_max, now)
            }
            Progress::AggregateReady { lanes, .. } => {
                match clients[idx].on_aggregate(opts, lanes, latency, rounds_completed, now)? {
                    AfterRound::Continue(next) => next,
                    AfterRound::Finished => {
                        *remaining -= 1;
                        return Ok(());
                    }
                    AfterRound::Dark => {
                        go_dark(idx, clients, wheel, churn, remaining, now);
                        return Ok(());
                    }
                }
            }
            Progress::Failed { reason } => {
                let c = &mut clients[idx];
                let plan = &opts.jobs[c.job_idx];
                if c.revived || !c.churn.join_delay.is_zero() {
                    // A churned client that fell too far behind the
                    // fleet is a casualty of the fault plane, not a
                    // harness bug: the quorum already closed its
                    // rounds without it.
                    crate::warn!(
                        "swarm client {} of job {} stranded after churn: {reason}",
                        c.cid,
                        plan.job
                    );
                    churn.stranded += 1;
                    c.done = true;
                    *remaining -= 1;
                    return Ok(());
                }
                bail!("swarm client {} of job {}: {reason}", c.cid, plan.job);
            }
        };
    }
}

/// Flush every socket's outgoing queue: uplink chaos verdicts per frame
/// (in emission order), `sendmmsg` bursts, buffers recycled to their
/// owning core.
fn flush_tx(socks: &mut [SockState], clients: &mut [SwarmClient], io_bytes_sent: &mut u64) {
    for st in socks.iter_mut() {
        if st.txq.is_empty() {
            continue;
        }
        let txq = std::mem::take(&mut st.txq);
        if let Some(lane) = st.lane.as_mut() {
            let now = Instant::now();
            let mut wire: Vec<(Vec<u8>, ())> = Vec::with_capacity(txq.len());
            for (f, _) in &txq {
                wire.extend(lane.process(f, (), now));
            }
            send_wire(&st.socket, wire, io_bytes_sent);
        } else {
            let mut start = 0usize;
            let refs: Vec<&[u8]> = txq.iter().map(|(f, _)| f.as_slice()).collect();
            while start < refs.len() {
                let burst = &refs[start..(start + SWARM_BATCH).min(refs.len())];
                match poll::send_batch_connected(&st.socket, burst) {
                    Ok(sent) => {
                        for b in &burst[..sent] {
                            *io_bytes_sent += b.len() as u64;
                        }
                        start += if sent < burst.len() { sent + 1 } else { burst.len() };
                    }
                    Err(_) => start += 1,
                }
            }
        }
        for (f, owner) in txq {
            clients[owner].core.recycle(f);
        }
    }
}

/// Send chaos-lane output (owned copies — they recycle nowhere).
fn send_wire(socket: &UdpSocket, wire: Vec<(Vec<u8>, ())>, io_bytes_sent: &mut u64) {
    let refs: Vec<&[u8]> = wire.iter().map(|(f, ())| f.as_slice()).collect();
    let mut start = 0usize;
    while start < refs.len() {
        let burst = &refs[start..(start + SWARM_BATCH).min(refs.len())];
        match poll::send_batch_connected(socket, burst) {
            Ok(sent) => {
                for b in &burst[..sent] {
                    *io_bytes_sent += b.len() as u64;
                }
                start += if sent < burst.len() { sent + 1 } else { burst.len() };
            }
            Err(_) => start += 1,
        }
    }
}
