//! Small statistics substrate: online moments, percentiles, linear fits.
//!
//! Used by the metrics recorder, the M/G/1 validation tests and the
//! power-law fitter in `theory::power_law`.

/// Welford online mean/variance accumulator.
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// Fresh accumulator (no samples).
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one sample in.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Samples seen so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (NaN before the first sample).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.m2 / self.n as f64 }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample (+∞ before the first).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample (−∞ before the first).
    pub fn max(&self) -> f64 {
        self.max
    }
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(values: &[f64], p: f64) -> f64 {
    assert!(!values.is_empty());
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Arithmetic mean of a slice (NaN when empty).
pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return f64::NAN;
    }
    values.iter().sum::<f64>() / values.len() as f64
}

/// Ordinary least squares y = a + b·x. Returns (intercept a, slope b).
/// The power-law fitter runs this on (log rank, log magnitude).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxx = 0.0;
    let mut sxy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
    }
    let slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
    let _ = n;
    (my - slope * mx, slope)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_stats_basics() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 4.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 - 0.7 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b + 0.7).abs() < 1e-9);
    }
}
