//! Packed 0-1 index arrays — the phase-1 vote payload (§IV step 1).
//!
//! FediAC's entire phase-1 advantage comes from representing each model
//! dimension with a single bit, so this structure is on the hot path:
//! clients build one per round, the PS adds them into vote counters, and
//! the GIA returned to clients is again a `BitVec`.

/// Fixed-length packed bit vector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BitVec {
    len: usize,
    words: Vec<u64>,
}

impl BitVec {
    /// All-zeros bit vector of length `len`.
    pub fn zeros(len: usize) -> Self {
        BitVec { len, words: vec![0u64; len.div_ceil(64)] }
    }

    /// Build from a list of set indices.
    pub fn from_indices(len: usize, indices: &[usize]) -> Self {
        let mut bv = BitVec::zeros(len);
        for &i in indices {
            bv.set(i, true);
        }
        bv
    }

    /// Length in bits (the model dimension d).
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True for a zero-length vector.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    /// Set bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize, value: bool) {
        debug_assert!(i < self.len);
        let (w, b) = (i >> 6, i & 63);
        if value {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    /// Number of set bits.
    pub fn count_ones(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Iterate over the indices of set bits in ascending order.
    pub fn iter_ones(&self) -> impl Iterator<Item = usize> + '_ {
        self.words.iter().enumerate().flat_map(move |(wi, &w)| {
            let mut word = w;
            std::iter::from_fn(move || {
                if word == 0 {
                    return None;
                }
                let b = word.trailing_zeros() as usize;
                word &= word - 1;
                Some((wi << 6) | b)
            })
        })
    }

    /// Raw payload bytes of the array (what goes on the wire in phase 1:
    /// one bit per model dimension, §IV-D "Overhead of Phase 1").
    pub fn payload_bytes(&self) -> usize {
        self.len.div_ceil(8)
    }

    /// Serialise to little-endian bytes (wire format).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.payload_bytes());
        self.copy_bytes_into(&mut out);
        out
    }

    /// Serialise into a reused buffer (cleared first) — the
    /// allocation-free twin of [`BitVec::to_bytes`] for per-round hot
    /// paths (identical bytes).
    pub fn copy_bytes_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.reserve(self.payload_bytes());
        for (wi, w) in self.words.iter().enumerate() {
            let remaining = self.payload_bytes().saturating_sub(wi * 8);
            let take = remaining.min(8);
            out.extend_from_slice(&w.to_le_bytes()[..take]);
        }
    }

    /// Parse from wire bytes.
    pub fn from_bytes(len: usize, bytes: &[u8]) -> Self {
        assert!(bytes.len() >= len.div_ceil(8), "short bitvec payload");
        let mut words = vec![0u64; len.div_ceil(64)];
        for (i, chunk) in bytes[..len.div_ceil(8)].chunks(8).enumerate() {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            words[i] = u64::from_le_bytes(buf);
        }
        let mut bv = BitVec { len, words };
        bv.mask_tail();
        bv
    }

    /// Bitwise OR in place (used by tests and the scoreboard).
    pub fn or_with(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter_mut().zip(&other.words) {
            *a |= b;
        }
    }

    /// Clear any bits beyond `len` in the last word.
    fn mask_tail(&mut self) {
        let tail = self.len & 63;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }

    /// View as a 0.0/1.0 f32 mask (the GIA layout the compress artifact takes).
    pub fn to_f32_mask(&self) -> Vec<f32> {
        (0..self.len).map(|i| if self.get(i) { 1.0 } else { 0.0 }).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_count() {
        let mut bv = BitVec::zeros(130);
        assert_eq!(bv.count_ones(), 0);
        bv.set(0, true);
        bv.set(63, true);
        bv.set(64, true);
        bv.set(129, true);
        assert!(bv.get(0) && bv.get(63) && bv.get(64) && bv.get(129));
        assert!(!bv.get(1) && !bv.get(128));
        assert_eq!(bv.count_ones(), 4);
        bv.set(63, false);
        assert_eq!(bv.count_ones(), 3);
    }

    #[test]
    fn iter_ones_ascending() {
        let idx = [3usize, 17, 64, 65, 100, 127];
        let bv = BitVec::from_indices(128, &idx);
        let got: Vec<usize> = bv.iter_ones().collect();
        assert_eq!(got, idx);
    }

    #[test]
    fn bytes_roundtrip() {
        for len in [1usize, 7, 8, 9, 63, 64, 65, 1000] {
            let idx: Vec<usize> = (0..len).filter(|i| i % 3 == 0).collect();
            let bv = BitVec::from_indices(len, &idx);
            let rt = BitVec::from_bytes(len, &bv.to_bytes());
            assert_eq!(bv, rt, "len {len}");
            assert_eq!(bv.payload_bytes(), len.div_ceil(8));
        }
    }

    #[test]
    fn f32_mask_matches_bits() {
        let bv = BitVec::from_indices(10, &[1, 4, 9]);
        let mask = bv.to_f32_mask();
        assert_eq!(mask, vec![0.0, 1.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn paper_overhead_example() {
        // §IV-D: a 10-million-dimension model costs 1.25 MB in phase 1.
        let bv = BitVec::zeros(10_000_000);
        assert_eq!(bv.payload_bytes(), 1_250_000);
    }
}
