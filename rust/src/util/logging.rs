//! Tiny leveled logger (the `log` facade + a backend are overkill offline).
//!
//! Controlled by `FEDIAC_LOG` ∈ {trace, debug, info, warn, error, off};
//! defaults to `info`. All output goes to stderr so experiment stdout stays
//! machine-parsable (CSV/TSV rows).

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
/// Log severity, ordered; `FEDIAC_LOG` selects the minimum emitted.
pub enum Level {
    /// Per-packet noise.
    Trace = 0,
    /// Per-round diagnostics.
    Debug = 1,
    /// Run-level progress (the default).
    Info = 2,
    /// Unexpected but recoverable conditions.
    Warn = 3,
    /// Failures.
    Error = 4,
    /// Disable all output.
    Off = 5,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialised

fn init_from_env() -> u8 {
    let lvl = match std::env::var("FEDIAC_LOG").as_deref() {
        Ok("trace") => Level::Trace,
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        Ok("off") => Level::Off,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// True when messages at `level` should be emitted.
pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == u8::MAX {
        cur = init_from_env();
    }
    level as u8 >= cur
}

/// Override the level programmatically (tests, quiet benches).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Emit one line to stderr when `level` clears the filter (prefer the
/// `info!`/`debug!`/`warn!` macros, which capture the module path).
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{:5}] {}: {}", format!("{level:?}").to_lowercase(), module, msg);
    }
}

/// Log at [`util::logging::Level::Info`](crate::util::logging::Level).
#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info, module_path!(), format_args!($($arg)+))
    };
}

/// Log at [`util::logging::Level::Debug`](crate::util::logging::Level).
#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)+))
    };
}

/// Log at [`util::logging::Level::Warn`](crate::util::logging::Level).
#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        set_level(Level::Info); // restore default for other tests
    }
}
