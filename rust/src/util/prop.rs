//! Minimal property-based testing harness.
//!
//! `proptest` is not in the offline vendor set, so this module provides the
//! subset the test suite needs: run a property over many seeded-random
//! cases and, on failure, report the exact seed so the case replays
//! deterministically (`FEDIAC_PROP_SEED=<seed> cargo test`).

use super::rng::Rng;

/// Number of cases per property (override with FEDIAC_PROP_CASES).
pub fn default_cases() -> u64 {
    std::env::var("FEDIAC_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `property` over `cases` independent random streams. The property
/// returns `Err(message)` to fail; the panic message includes the replay
/// seed of the failing case.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let forced: Option<u64> = std::env::var("FEDIAC_PROP_SEED")
        .ok()
        .and_then(|v| v.parse().ok());
    if let Some(seed) = forced {
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property '{name}' failed (replay seed {seed}): {msg}");
        }
        return;
    }
    for case in 0..cases {
        let seed = 0xF3D1_AC00 ^ case.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (replay with FEDIAC_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Generator: vector of f32 drawn from N(0, scale).
pub fn gen_updates(rng: &mut Rng, d: usize, scale: f64) -> Vec<f32> {
    (0..d).map(|_| (rng.gaussian() * scale) as f32).collect()
}

/// Generator: dimension sizes around interesting boundaries.
pub fn gen_dim(rng: &mut Rng) -> usize {
    const INTERESTING: [usize; 9] = [1, 2, 7, 63, 64, 65, 500, 1024, 4097];
    INTERESTING[rng.below(INTERESTING.len())]
}

/// Assert helper producing the Err(String) shape `check` expects.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 16, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "replay with FEDIAC_PROP_SEED=")]
    fn check_reports_seed_on_failure() {
        check("always_fails", 4, |_rng| Err("boom".to_string()));
    }

    #[test]
    fn generators_shapes() {
        let mut rng = Rng::new(1);
        let v = gen_updates(&mut rng, 100, 0.05);
        assert_eq!(v.len(), 100);
        for _ in 0..100 {
            assert!(gen_dim(&mut rng) >= 1);
        }
    }
}
