//! Deterministic PRNG + sampling substrate.
//!
//! No external RNG crates are available in the offline vendor set, so the
//! simulator ships its own: a [SplitMix64](https://prng.di.unimi.it/splitmix64.c)
//! seeder feeding an xoshiro256** core, plus the distribution samplers the
//! paper's evaluation needs (Gaussian service times, exponential/Poisson
//! arrivals, Gamma → Dirichlet(β) non-IID label partitions).
//!
//! Everything in the repository derives its randomness from one of these
//! streams, so every experiment is reproducible from a single `u64` seed.

/// xoshiro256** PRNG seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller Gaussian draw.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Derive an independent child stream (e.g. one per client).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Next raw 64-bit output of the xoshiro256** step.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// High 32 bits of the next output.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in (0, 1) — safe for logs.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        loop {
            let x = self.f64();
            if x > 0.0 {
                return x;
            }
        }
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough bound for sim use.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard Gaussian via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        let u1 = self.f64_open();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Gaussian with the given mean/std, truncated at zero from below
    /// (service times must be non-negative; the paper models PS per-packet
    /// aggregation delay as Gaussian with tiny variance).
    pub fn gaussian_pos(&mut self, mean: f64, std: f64) -> f64 {
        (mean + std * self.gaussian()).max(0.0)
    }

    /// Exponential with the given rate λ (inter-arrival of a Poisson process).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64_open().ln() / rate
    }

    /// Poisson-distributed count. Knuth's method for small mean, normal
    /// approximation (rounded, clamped) for large mean.
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean < 30.0 {
            let l = (-mean).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = mean + mean.sqrt() * self.gaussian();
            x.max(0.0).round() as u64
        }
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang, with the α<1 boost.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        debug_assert!(shape > 0.0);
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^{1/a}.
            let g = self.gamma(shape + 1.0);
            return g * self.f64_open().powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.gaussian();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64_open();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v3;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
                return d * v3;
            }
        }
    }

    /// Symmetric Dirichlet(β) sample over `n` categories — the paper's
    /// non-IID label-distribution generator (§V-A1, default β = 0.5).
    pub fn dirichlet(&mut self, beta: f64, n: usize) -> Vec<f64> {
        let mut draws: Vec<f64> = (0..n).map(|_| self.gamma(beta)).collect();
        let sum: f64 = draws.iter().sum();
        if sum <= 0.0 {
            // Degenerate numerical case: fall back to one-hot.
            let hot = self.below(n);
            draws.iter_mut().for_each(|x| *x = 0.0);
            draws[hot] = 1.0;
            return draws;
        }
        draws.iter_mut().for_each(|x| *x /= sum);
        draws
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Gumbel(0,1) draw, used by the native vote path.
    pub fn gumbel(&mut self) -> f64 {
        -(-self.f64_open().ln()).ln()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Rng::new(1);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(2);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(3);
        let rate = 4.0;
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(4);
        for &m in &[0.5, 5.0, 80.0] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.poisson(m) as f64).sum::<f64>() / n as f64;
            assert!((mean - m).abs() < 0.05 * m.max(1.0), "mean {mean} vs {m}");
        }
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(5);
        for &shape in &[0.3, 1.0, 4.5] {
            let n = 30_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < 0.06 * shape.max(1.0), "{mean} vs {shape}");
        }
    }

    #[test]
    fn dirichlet_is_simplex() {
        let mut r = Rng::new(6);
        for &beta in &[0.1, 0.5, 5.0] {
            let p = r.dirichlet(beta, 10);
            assert_eq!(p.len(), 10);
            assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_concentration_effect() {
        // Small β ⇒ spiky distributions (max share near 1), large β ⇒ flat.
        let mut r = Rng::new(7);
        let spiky: f64 = (0..200)
            .map(|_| r.dirichlet(0.1, 10).iter().cloned().fold(0.0, f64::max))
            .sum::<f64>()
            / 200.0;
        let flat: f64 = (0..200)
            .map(|_| r.dirichlet(50.0, 10).iter().cloned().fold(0.0, f64::max))
            .sum::<f64>()
            / 200.0;
        assert!(spiky > 0.6, "spiky {spiky}");
        assert!(flat < 0.2, "flat {flat}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }
}
