//! Shared substrates: PRNG + distributions, packed bit arrays, statistics,
//! property-test harness and logging. All hand-rolled — the offline vendor
//! set has no rand/proptest/log crates (see DESIGN.md §2 substitutions).

pub mod bitvec;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;

pub use bitvec::BitVec;
pub use rng::Rng;
pub use stats::OnlineStats;
