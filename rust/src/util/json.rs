//! Minimal JSON parser (serde_json unavailable offline).
//!
//! Full JSON value model, recursive-descent parsing, good-enough error
//! positions. Only consumed for `artifacts/manifest.json`, but complete
//! enough to parse any well-formed document.

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (keys sorted).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Object member by key (`None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Array element by index (`None` on non-arrays).
    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    /// The string form, if this is a [`Json::Str`].
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric form, if this is a [`Json::Num`].
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number truncated to usize (manifest dimension fields).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// The array elements, if this is a [`Json::Arr`].
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The member map, if this is a [`Json::Obj`].
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse failure with its byte position.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    /// Byte offset of the failure in the input.
    pub pos: usize,
    /// What went wrong there.
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { pos: self.pos, msg: msg.into() })
    }

    fn skip_ws(&mut self) {
        while matches!(self.bytes.get(self.pos), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn keyword(&mut self, kw: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(kw.as_bytes()) {
            self.pos += kw.len();
            Ok(val)
        } else {
            self.err(format!("expected '{kw}'"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| JsonError { pos: start, msg: "invalid utf8".into() })?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { pos: start, msg: format!("bad number '{text}'") })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or(JsonError { pos: self.pos, msg: "short \\u".into() })?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).unwrap_or(""),
                                16,
                            )
                            .map_err(|_| JsonError { pos: self.pos, msg: "bad \\u".into() })?;
                            out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(c) => {
                    // Copy the full UTF-8 sequence.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let chunk = self
                        .bytes
                        .get(self.pos..self.pos + len)
                        .ok_or(JsonError { pos: self.pos, msg: "truncated utf8".into() })?;
                    out.push_str(std::str::from_utf8(chunk).map_err(|_| JsonError {
                        pos: self.pos,
                        msg: "invalid utf8".into(),
                    })?);
                    self.pos += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            map.insert(key, self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn nested_document() {
        let doc = r#"{"models": {"tiny": {"d": 2762, "layout": [{"shape": [3, 4]}], "ok": true}}}"#;
        let v = parse(doc).unwrap();
        let tiny = v.get("models").unwrap().get("tiny").unwrap();
        assert_eq!(tiny.get("d").unwrap().as_usize(), Some(2762));
        let shape = tiny.get("layout").unwrap().idx(0).unwrap().get("shape").unwrap();
        let dims: Vec<usize> =
            shape.as_arr().unwrap().iter().map(|j| j.as_usize().unwrap()).collect();
        assert_eq!(dims, vec![3, 4]);
    }

    #[test]
    fn arrays_and_empties() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        let v = parse("[1, [2, 3], {\"x\": []}]").unwrap();
        assert_eq!(v.idx(1).unwrap().idx(0).unwrap().as_f64(), Some(2.0));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn errors() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("123 456").is_err());
        assert!(parse("\"open").is_err());
    }

    #[test]
    fn real_manifest_roundtrip() {
        // Parse the actual manifest if artifacts exist (no-op otherwise).
        if let Ok(text) = std::fs::read_to_string(
            concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json"),
        ) {
            let v = parse(&text).unwrap();
            assert!(v.get("models").is_some());
        }
    }
}
