//! Data substrate: synthetic federated corpora (CIFAR/FEMNIST stand-ins),
//! IID/Dirichlet/natural partitioners and the dataset container.

pub mod dataset;
pub mod dirichlet;
pub mod synth;

pub use dataset::{Dataset, FederatedData};
pub use dirichlet::{partition_dirichlet, partition_iid, partition_natural};
pub use synth::{feature_shape, generate, generate_with, SynthConfig};
