//! Synthetic dataset generators (CIFAR-10/100 + FEMNIST stand-ins).
//!
//! No network access exists in this environment, so the paper's corpora
//! are replaced by learnable class-conditional Gaussian image generators
//! (DESIGN.md §2 substitution 3):
//!
//! * each class c has a fixed random template T_c;
//! * a sample is T_c·s + ε with signal scale s and pixel noise ε;
//! * FEMNIST additionally applies a per-writer style shift so that the
//!   natural (writer-based) partition is inherently non-IID, and each
//!   writer holds 300–400 samples (§V-A1).
//!
//! The generators are deterministic in the seed and reproduce the
//! qualitative structure the figures compare: monotone learning curves
//! whose speed degrades with compression error and label skew.

use crate::configx::{DatasetKind, Partition};
use crate::data::dataset::{Dataset, FederatedData};
use crate::data::dirichlet::{partition_dirichlet, partition_iid, partition_natural};
use crate::util::Rng;

/// Shape metadata for a dataset kind.
pub fn feature_shape(kind: DatasetKind) -> (usize, &'static str) {
    match kind {
        DatasetKind::Tiny => (32, "32"),
        DatasetKind::SynthCifar10 | DatasetKind::SynthCifar100 => (16 * 16 * 3, "16x16x3"),
        DatasetKind::SynthFemnist => (28 * 28, "28x28x1"),
    }
}

/// Per-class template, lazily generated from a class-indexed seed so that
/// train and test sets share templates without storing the whole corpus.
fn class_template(kind: DatasetKind, class: usize, feature_len: usize) -> Vec<f32> {
    let mut rng = Rng::new(0xC1A5_5E5E ^ ((kind as u64) << 32) ^ class as u64);
    (0..feature_len).map(|_| rng.gaussian() as f32).collect()
}

/// Generation knobs.
#[derive(Debug, Clone, Copy)]
pub struct SynthConfig {
    /// Signal-to-noise: sample = template·signal + N(0, noise).
    pub signal: f32,
    /// Additive Gaussian noise std.
    pub noise: f32,
    /// Per-writer style-shift strength (FEMNIST only).
    pub style: f32,
    /// Held-out test samples to generate.
    pub test_samples: usize,
}

impl Default for SynthConfig {
    fn default() -> Self {
        SynthConfig { signal: 0.35, noise: 2.0, style: 0.45, test_samples: 512 }
    }
}

impl SynthConfig {
    /// Per-dataset difficulty calibration. The image stand-ins are tuned
    /// so a round-0 model is far from ceiling and 40–80 rounds produce a
    /// full learning curve (matching the paper's figure dynamics); the
    /// 32-feature `tiny` task stays easy so unit tests converge fast.
    pub fn for_kind(kind: DatasetKind) -> Self {
        match kind {
            DatasetKind::Tiny => {
                SynthConfig { signal: 1.0, noise: 0.6, ..Default::default() }
            }
            DatasetKind::SynthCifar100 => {
                // 100 classes share the feature space: keep a bit more
                // signal so the curve rises within the round budget.
                SynthConfig { signal: 0.7, noise: 1.8, ..Default::default() }
            }
            DatasetKind::SynthFemnist => {
                // 62 classes + writer style shifts are already hard; keep
                // the per-pixel noise moderate.
                SynthConfig { signal: 0.7, noise: 1.1, ..Default::default() }
            }
            _ => SynthConfig::default(),
        }
    }
}

fn gen_sample(
    template: &[f32],
    style_shift: Option<&[f32]>,
    cfg: &SynthConfig,
    rng: &mut Rng,
    out: &mut Vec<f32>,
) {
    out.clear();
    for (i, &t) in template.iter().enumerate() {
        let mut v = t * cfg.signal + (rng.gaussian() as f32) * cfg.noise;
        if let Some(style) = style_shift {
            v += style[i];
        }
        out.push(v);
    }
}

/// Generate the federated corpus for `kind` with `n_clients` clients and
/// roughly `samples_per_client` training samples each.
pub fn generate(
    kind: DatasetKind,
    partition: Partition,
    n_clients: usize,
    samples_per_client: usize,
    seed: u64,
) -> FederatedData {
    generate_with(
        kind,
        partition,
        n_clients,
        samples_per_client,
        seed,
        &SynthConfig::for_kind(kind),
    )
}

/// [`generate`] with explicit generation knobs instead of the per-kind
/// defaults.
pub fn generate_with(
    kind: DatasetKind,
    partition: Partition,
    n_clients: usize,
    samples_per_client: usize,
    seed: u64,
    cfg: &SynthConfig,
) -> FederatedData {
    let (feature_len, _) = feature_shape(kind);
    let num_classes = kind.num_classes();
    let templates: Vec<Vec<f32>> =
        (0..num_classes).map(|c| class_template(kind, c, feature_len)).collect();
    let mut rng = Rng::new(seed ^ 0xDA7A_0001);

    let mut train = Dataset::new(feature_len, num_classes);
    let mut buf = Vec::with_capacity(feature_len);

    // FEMNIST: one writer per client with its own style and 300–400
    // samples; other datasets: a flat corpus partitioned afterwards.
    let (shards, n_train) = if kind == DatasetKind::SynthFemnist {
        let styles: Vec<Vec<f32>> = (0..n_clients)
            .map(|w| {
                let mut r = Rng::new(seed ^ 0x57E1_E000 ^ w as u64);
                (0..feature_len).map(|_| (r.gaussian() as f32) * cfg.style).collect()
            })
            .collect();
        let mut shards = Vec::new();
        for writer in 0..n_clients {
            let n = 300 + rng.below(101); // 300–400 per writer (§V-A1)
            let n = n.min(samples_per_client.max(1) * 2);
            for _ in 0..n {
                let label = rng.below(num_classes);
                gen_sample(
                    &templates[label],
                    Some(&styles[writer]),
                    cfg,
                    &mut rng,
                    &mut buf,
                );
                train.push(&buf, label as u16);
                shards.push(writer);
            }
        }
        let n_train = shards.len();
        (Some(shards), n_train)
    } else {
        let n_train = n_clients * samples_per_client;
        for _ in 0..n_train {
            let label = rng.below(num_classes);
            gen_sample(&templates[label], None, cfg, &mut rng, &mut buf);
            train.push(&buf, label as u16);
        }
        (None, n_train)
    };

    let mut test = Dataset::new(feature_len, num_classes);
    for _ in 0..cfg.test_samples {
        let label = rng.below(num_classes);
        gen_sample(&templates[label], None, cfg, &mut rng, &mut buf);
        test.push(&buf, label as u16);
    }

    let client_indices = match (partition, &shards) {
        (Partition::Natural, Some(shards)) => partition_natural(shards, n_clients),
        (Partition::Natural, None) | (Partition::Iid, _) => {
            partition_iid(n_train, n_clients, &mut rng)
        }
        (Partition::Dirichlet(beta), _) => {
            partition_dirichlet(train.labels(), num_classes, n_clients, beta, &mut rng)
        }
    };

    FederatedData { train, test, client_indices }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_models() {
        // Must agree with python/compile/model.py MODEL_SPECS input shapes.
        assert_eq!(feature_shape(DatasetKind::Tiny).0, 32);
        assert_eq!(feature_shape(DatasetKind::SynthCifar10).0, 768);
        assert_eq!(feature_shape(DatasetKind::SynthFemnist).0, 784);
    }

    #[test]
    fn generate_iid_sizes() {
        let fd = generate(DatasetKind::Tiny, Partition::Iid, 4, 50, 1);
        assert_eq!(fd.train.len(), 200);
        assert_eq!(fd.n_clients(), 4);
        assert!(fd.test.len() > 0);
        let covered: usize = fd.client_indices.iter().map(|c| c.len()).sum();
        assert_eq!(covered, 200);
        assert!(fd.noniid_degree() < 0.25, "iid degree {}", fd.noniid_degree());
    }

    #[test]
    fn dirichlet_more_skewed_than_iid() {
        let iid = generate(DatasetKind::SynthCifar10, Partition::Iid, 10, 100, 2);
        let skew =
            generate(DatasetKind::SynthCifar10, Partition::Dirichlet(0.3), 10, 100, 2);
        assert!(skew.noniid_degree() > iid.noniid_degree() + 0.1);
    }

    #[test]
    fn femnist_natural_writers() {
        let fd = generate(DatasetKind::SynthFemnist, Partition::Natural, 5, 350, 3);
        // 300–400 per writer.
        for c in &fd.client_indices {
            assert!((300..=400).contains(&c.len()), "writer size {}", c.len());
        }
        // Writer styles make the feature distributions client-specific even
        // though labels are uniform: natural non-IID is in features.
        assert_eq!(fd.train.len(), fd.client_indices.iter().map(Vec::len).sum());
    }

    #[test]
    fn deterministic_in_seed() {
        let a = generate(DatasetKind::Tiny, Partition::Iid, 3, 20, 7);
        let b = generate(DatasetKind::Tiny, Partition::Iid, 3, 20, 7);
        assert_eq!(a.train.labels(), b.train.labels());
        assert_eq!(a.train.features_of(5), b.train.features_of(5));
        let c = generate(DatasetKind::Tiny, Partition::Iid, 3, 20, 8);
        assert_ne!(a.train.labels(), c.train.labels());
    }

    #[test]
    fn classes_are_separable() {
        // Nearest-template classification on clean test data should be
        // nearly perfect — the corpus carries real signal.
        let fd = generate(DatasetKind::Tiny, Partition::Iid, 2, 50, 4);
        let (flen, _) = feature_shape(DatasetKind::Tiny);
        let templates: Vec<Vec<f32>> =
            (0..10).map(|c| class_template(DatasetKind::Tiny, c, flen)).collect();
        let mut correct = 0;
        for i in 0..fd.test.len() {
            let x = fd.test.features_of(i);
            let pred = (0..10)
                .max_by(|&a, &b| {
                    let da: f32 = x.iter().zip(&templates[a]).map(|(u, v)| u * v).sum();
                    let db: f32 = x.iter().zip(&templates[b]).map(|(u, v)| u * v).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if pred as u16 == fd.test.label_of(i) {
                correct += 1;
            }
        }
        let acc = correct as f64 / fd.test.len() as f64;
        assert!(acc > 0.8, "template accuracy {acc}");
    }
}
