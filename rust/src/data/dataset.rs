//! In-memory dataset container shared by the native and PJRT backends.
//!
//! Samples are stored as flat f32 feature rows (images are row-major
//! H·W·C), matching exactly what the AOT model artifacts take as input.

/// A labelled dataset of flat feature rows.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// feature_len floats per sample, concatenated.
    features: Vec<f32>,
    labels: Vec<u16>,
    feature_len: usize,
    num_classes: usize,
}

impl Dataset {
    /// Empty dataset with fixed row width and label space.
    pub fn new(feature_len: usize, num_classes: usize) -> Self {
        Dataset { features: Vec::new(), labels: Vec::new(), feature_len, num_classes }
    }

    /// Append one labelled sample.
    pub fn push(&mut self, features: &[f32], label: u16) {
        debug_assert_eq!(features.len(), self.feature_len);
        debug_assert!((label as usize) < self.num_classes);
        self.features.extend_from_slice(features);
        self.labels.push(label);
    }

    /// Number of samples.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// True when no samples were pushed.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Floats per sample row.
    pub fn feature_len(&self) -> usize {
        self.feature_len
    }

    /// Label-space size.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Feature row of sample `idx`.
    pub fn features_of(&self, idx: usize) -> &[f32] {
        let lo = idx * self.feature_len;
        &self.features[lo..lo + self.feature_len]
    }

    /// Label of sample `idx`.
    pub fn label_of(&self, idx: usize) -> u16 {
        self.labels[idx]
    }

    /// All labels in sample order.
    pub fn labels(&self) -> &[u16] {
        &self.labels
    }

    /// Gather a batch into caller-provided buffers (no allocation on the
    /// training hot path).
    pub fn fill_batch(&self, indices: &[usize], feat_out: &mut [f32], label_out: &mut [i32]) {
        debug_assert_eq!(feat_out.len(), indices.len() * self.feature_len);
        debug_assert_eq!(label_out.len(), indices.len());
        for (row, &idx) in indices.iter().enumerate() {
            let src = self.features_of(idx);
            feat_out[row * self.feature_len..(row + 1) * self.feature_len]
                .copy_from_slice(src);
            label_out[row] = self.labels[idx] as i32;
        }
    }

    /// Per-class sample counts (used by partition tests / non-IID metrics).
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut hist = vec![0usize; self.num_classes];
        for &l in &self.labels {
            hist[l as usize] += 1;
        }
        hist
    }
}

/// Train/test split of a generated corpus plus the per-client partition.
#[derive(Debug, Clone)]
pub struct FederatedData {
    /// Training corpus (partitioned by `client_indices`).
    pub train: Dataset,
    /// Held-out evaluation corpus.
    pub test: Dataset,
    /// Per-client indices into `train`.
    pub client_indices: Vec<Vec<usize>>,
}

impl FederatedData {
    /// Clients in the partition.
    pub fn n_clients(&self) -> usize {
        self.client_indices.len()
    }

    /// Label distribution divergence: mean total-variation distance between
    /// each client's label histogram and the global one. 0 ⇒ perfectly IID.
    pub fn noniid_degree(&self) -> f64 {
        let c = self.train.num_classes();
        let mut global = vec![0f64; c];
        for &l in self.train.labels() {
            global[l as usize] += 1.0;
        }
        let total: f64 = global.iter().sum();
        global.iter_mut().for_each(|x| *x /= total);
        let mut tv_sum = 0.0;
        for indices in &self.client_indices {
            if indices.is_empty() {
                continue;
            }
            let mut local = vec![0f64; c];
            for &i in indices {
                local[self.train.label_of(i) as usize] += 1.0;
            }
            let n: f64 = local.iter().sum();
            local.iter_mut().for_each(|x| *x /= n);
            tv_sum += global
                .iter()
                .zip(&local)
                .map(|(g, l)| (g - l).abs())
                .sum::<f64>()
                / 2.0;
        }
        tv_sum / self.client_indices.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_dataset() -> Dataset {
        let mut ds = Dataset::new(3, 2);
        ds.push(&[1.0, 2.0, 3.0], 0);
        ds.push(&[4.0, 5.0, 6.0], 1);
        ds.push(&[7.0, 8.0, 9.0], 1);
        ds
    }

    #[test]
    fn push_and_access() {
        let ds = tiny_dataset();
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.features_of(1), &[4.0, 5.0, 6.0]);
        assert_eq!(ds.label_of(2), 1);
        assert_eq!(ds.class_histogram(), vec![1, 2]);
    }

    #[test]
    fn fill_batch_gathers() {
        let ds = tiny_dataset();
        let mut feats = vec![0f32; 6];
        let mut labels = vec![0i32; 2];
        ds.fill_batch(&[2, 0], &mut feats, &mut labels);
        assert_eq!(feats, vec![7.0, 8.0, 9.0, 1.0, 2.0, 3.0]);
        assert_eq!(labels, vec![1, 0]);
    }

    #[test]
    fn noniid_degree_extremes() {
        // Two clients, two classes: identical split ⇒ 0; disjoint ⇒ high.
        let mut train = Dataset::new(1, 2);
        for i in 0..100 {
            train.push(&[i as f32], (i % 2) as u16);
        }
        let iid = FederatedData {
            train: train.clone(),
            test: Dataset::new(1, 2),
            client_indices: vec![
                (0..50).collect::<Vec<_>>(),
                (50..100).collect::<Vec<_>>(),
            ],
        };
        assert!(iid.noniid_degree() < 0.05, "{}", iid.noniid_degree());
        let disjoint = FederatedData {
            train,
            test: Dataset::new(1, 2),
            client_indices: vec![
                (0..100).step_by(2).collect::<Vec<_>>(),   // all class 0
                (1..100).step_by(2).collect::<Vec<_>>(),   // all class 1
            ],
        };
        assert!(disjoint.noniid_degree() > 0.45, "{}", disjoint.noniid_degree());
    }
}
