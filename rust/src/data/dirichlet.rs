//! Client data partitioners (§V-A1).
//!
//! * IID: shuffle the corpus and split uniformly — "the label distribution
//!   is the same for different clients".
//! * Dirichlet(β): draw one label distribution per client from a symmetric
//!   Dirichlet and assign samples accordingly — "the default parameter of
//!   the Dirichlet distribution denoted by β is set to 0.5 [34]".
//! * Natural: group by an externally supplied shard id (FEMNIST writers).

use crate::util::Rng;

/// Uniform IID split of `n_samples` across `n_clients`.
pub fn partition_iid(n_samples: usize, n_clients: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut indices: Vec<usize> = (0..n_samples).collect();
    rng.shuffle(&mut indices);
    let mut out = vec![Vec::new(); n_clients];
    for (i, idx) in indices.into_iter().enumerate() {
        out[i % n_clients].push(idx);
    }
    out
}

/// Dirichlet(β) label-skew partition: for each class, split its samples
/// across clients proportionally to per-client Dirichlet draws.
pub fn partition_dirichlet(
    labels: &[u16],
    num_classes: usize,
    n_clients: usize,
    beta: f64,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    // Samples per class, shuffled for random assignment within a class.
    let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); num_classes];
    for (i, &l) in labels.iter().enumerate() {
        per_class[l as usize].push(i);
    }
    let mut out = vec![Vec::new(); n_clients];
    for class_samples in per_class.iter_mut() {
        rng.shuffle(class_samples);
        // Per-client share of this class.
        let shares = rng.dirichlet(beta, n_clients);
        // Largest-remainder allocation of |class| samples to clients.
        let n = class_samples.len();
        let mut counts: Vec<usize> = shares.iter().map(|s| (s * n as f64) as usize).collect();
        let mut assigned: usize = counts.iter().sum();
        // Distribute the remainder to the largest fractional shares.
        let mut order: Vec<usize> = (0..n_clients).collect();
        order.sort_by(|&a, &b| {
            let fa = shares[a] * n as f64 - counts[a] as f64;
            let fb = shares[b] * n as f64 - counts[b] as f64;
            fb.partial_cmp(&fa).unwrap()
        });
        let mut oi = 0;
        while assigned < n {
            counts[order[oi % n_clients]] += 1;
            assigned += 1;
            oi += 1;
        }
        let mut cursor = 0;
        for (client, &c) in counts.iter().enumerate() {
            out[client].extend_from_slice(&class_samples[cursor..cursor + c]);
            cursor += c;
        }
    }
    out
}

/// Natural partition: samples carry a shard id (e.g. FEMNIST writer);
/// client i gets every sample whose shard maps to it.
pub fn partition_natural(shards: &[usize], n_clients: usize) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); n_clients];
    for (i, &s) in shards.iter().enumerate() {
        out[s % n_clients].push(i);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn iid_covers_everything_once() {
        let mut rng = Rng::new(1);
        let parts = partition_iid(103, 10, &mut rng);
        let mut all: Vec<usize> = parts.iter().flatten().cloned().collect();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        // Balanced within ±1.
        for p in &parts {
            assert!(p.len() == 10 || p.len() == 11);
        }
    }

    #[test]
    fn dirichlet_partition_is_exact_cover() {
        prop::check("dirichlet_cover", 16, |rng| {
            let n = 500;
            let classes = 10;
            let labels: Vec<u16> = (0..n).map(|_| rng.below(classes) as u16).collect();
            let parts = partition_dirichlet(&labels, classes, 7, 0.5, rng);
            let mut all: Vec<usize> = parts.iter().flatten().cloned().collect();
            all.sort_unstable();
            crate::prop_assert!(
                all == (0..n).collect::<Vec<_>>(),
                "not an exact cover: {} items",
                all.len()
            );
            Ok(())
        });
    }

    #[test]
    fn smaller_beta_more_skew() {
        let mut rng = Rng::new(2);
        let n = 4000;
        let classes = 10;
        let labels: Vec<u16> = (0..n).map(|i| (i % classes) as u16).collect();
        let skew = |beta: f64, rng: &mut Rng| {
            let parts = partition_dirichlet(&labels, classes, 10, beta, rng);
            // Mean of per-client max class share.
            parts
                .iter()
                .map(|p| {
                    let mut hist = vec![0f64; classes];
                    for &i in p {
                        hist[labels[i] as usize] += 1.0;
                    }
                    let total: f64 = hist.iter().sum();
                    hist.iter().cloned().fold(0.0, f64::max) / total.max(1.0)
                })
                .sum::<f64>()
                / 10.0
        };
        let strong = skew(0.1, &mut rng);
        let weak = skew(5.0, &mut rng);
        assert!(strong > weak + 0.1, "strong {strong} weak {weak}");
    }

    #[test]
    fn natural_partition_groups_by_shard() {
        let shards = vec![0usize, 1, 2, 0, 1, 2, 5];
        let parts = partition_natural(&shards, 3);
        assert_eq!(parts[0], vec![0, 3]);
        assert_eq!(parts[1], vec![1, 4]);
        assert_eq!(parts[2], vec![2, 5, 6]); // shard 5 wraps to client 2
    }
}
