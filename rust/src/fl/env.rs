//! Shared round environment: global model, switch, network timing helpers
//! and traffic accounting. Algorithms (FediAC + baselines) drive their
//! protocol through this; the timing model follows §V-A2 exactly:
//!
//! * each client's upload is a Poisson packet stream at its trace rate;
//! * the PS serves the merged stream through an M/G/1 queue (one
//!   aggregation op per packet, Gaussian service);
//! * downloads run at 5× the mean client upload rate;
//! * local training charges the per-dataset constant (0.1/2/3 s).

use crate::configx::ExperimentConfig;
use crate::fl::backend::ModelBackend;
use crate::metrics::TrafficMeter;
use crate::net::{client_rates, PoissonProcess};
use crate::sim::SimTime;
use crate::switch::ProgrammableSwitch;
use crate::util::Rng;

/// The mutable world one experiment run lives in.
pub struct FlEnv {
    /// The experiment's full configuration.
    pub cfg: ExperimentConfig,
    /// Model-execution backend (native MLP or PJRT artifacts).
    pub backend: Box<dyn ModelBackend>,
    /// The simulated programmable switch (primary PS in multi-PS mode).
    pub switch: ProgrammableSwitch,
    /// Mean upload rate per client (packets/s) from the cellular traces.
    pub rates: Vec<f64>,
    /// Global model (identical on every client after each round).
    pub params: Vec<f32>,
    /// Environment RNG (arrival/service/jitter draws).
    pub rng: Rng,
    /// Simulated wall-clock (end of the last completed round).
    pub now: SimTime,
    /// Cumulative traffic across the run.
    pub traffic_total: TrafficMeter,
}

/// Timing outcome of one upload phase.
#[derive(Debug, Clone, Copy)]
pub struct PhaseTiming {
    /// Absolute sim time at which the switch finished the last packet.
    pub end: SimTime,
    /// Packets the phase put on the wire (first copies only).
    pub packets: u64,
    /// Loss-triggered retransmissions (extra wire copies; the scoreboard
    /// drops the occasional spurious duplicate).
    pub retransmissions: u64,
}

impl FlEnv {
    /// Build the environment: trace-derived client rates, the configured
    /// switch profile (net_scale applied) and a seeded RNG.
    pub fn new(cfg: ExperimentConfig, backend: Box<dyn ModelBackend>) -> Self {
        // net_scale emulates a net_scale×-larger model on the wire: each
        // "packet" here stands for net_scale real packets, so per-packet
        // transmission slows down and per-packet aggregation cost grows
        // by the same factor (DESIGN.md §2 note 4).
        let rates: Vec<f64> = client_rates(cfg.num_clients, cfg.seed)
            .into_iter()
            .map(|r| r / cfg.net_scale)
            .collect();
        let mut ps = cfg.ps.clone();
        ps.agg_mean_s *= cfg.net_scale;
        ps.agg_jitter_s *= cfg.net_scale;
        let switch = ProgrammableSwitch::new(ps, cfg.seed);
        let rng = Rng::new(cfg.seed ^ 0xE17);
        FlEnv {
            cfg,
            backend,
            switch,
            rates,
            params: Vec::new(),
            rng,
            now: 0.0,
            traffic_total: TrafficMeter::default(),
        }
    }

    /// Initialise the global model from the backend.
    pub fn init_model(&mut self) {
        self.params = self.backend.init_params();
    }

    /// Model dimension d.
    pub fn d(&self) -> usize {
        self.backend.d()
    }

    /// Mean client upload rate (pkts/s) — the base of the download rate.
    pub fn mean_rate(&self) -> f64 {
        self.rates.iter().sum::<f64>() / self.rates.len() as f64
    }

    /// Download rate in packets/s (5× mean upload per the paper).
    pub fn download_rate(&self) -> f64 {
        self.cfg.download_mult * self.mean_rate()
    }

    /// Per-client local-training completion times for a round starting at
    /// `start`: the dataset constant ± 5% jitter.
    pub fn local_train_ready(&mut self, start: SimTime) -> Vec<SimTime> {
        let base = self.cfg.dataset.local_train_time_s();
        (0..self.cfg.num_clients)
            .map(|_| start + base * (0.95 + 0.1 * self.rng.f64()))
            .collect()
    }

    /// Simulate an upload phase: client i emits `pkts[i]` packets as a
    /// Poisson stream at its trace rate starting at `ready[i]`; the merged
    /// stream is served FIFO by the switch (one aggregation op each).
    ///
    /// `waves` > 1 models register-memory pressure: the block space is
    /// processed in `waves` synchronised passes — clients only start wave
    /// w+1's packets after the switch drained wave w *and* multicast the
    /// completed partial aggregates (the slot-credit round trip that frees
    /// the registers, SwitchML-style). This is §III-B's "excessive number
    /// of aggregations" effect: exceeding PS memory serialises the round.
    pub fn upload_phase(&mut self, ready: &[SimTime], pkts: &[usize], waves: usize) -> PhaseTiming {
        self.upload_phase_sharded(ready, pkts, waves, self.cfg.num_switches)
    }

    /// Multi-PS variant (§VI future work): the index space is sharded
    /// round-robin across `n_switches` collaborative switches. Each client
    /// still emits ONE Poisson packet stream (its uplink serialises), but
    /// service parallelises: shard s's packets drain through switch s's
    /// own queue, and the phase ends when the slowest shard finishes.
    /// Aggregation ops are charged once per packet regardless of shard
    /// (the system-wide count); the primary switch carries the stats.
    pub fn upload_phase_sharded(
        &mut self,
        ready: &[SimTime],
        pkts: &[usize],
        waves: usize,
        n_switches: usize,
    ) -> PhaseTiming {
        debug_assert_eq!(ready.len(), pkts.len());
        let n_switches = n_switches.max(1);
        if n_switches > 1 {
            return self.upload_phase_multi(ready, pkts, waves, n_switches);
        }
        let waves = waves.max(1);
        let n = ready.len();
        let loss = self.cfg.loss_rate;
        let rto = self.cfg.retx_timeout_s;
        let mut wave_ready: Vec<SimTime> = ready.to_vec();
        let mut total_packets = 0u64;
        let mut retransmissions = 0u64;
        let mut end: SimTime = ready.iter().cloned().fold(0.0, f64::max);
        if waves > 1 {
            self.switch.note_waves(waves as u64 - 1);
        }
        for w in 0..waves {
            // Client i's packet share for this wave.
            let mut arrivals: Vec<(SimTime, usize)> = Vec::new();
            for i in 0..n {
                let per_wave = pkts[i].div_ceil(waves);
                let sent_before = (w * per_wave).min(pkts[i]);
                let this_wave = per_wave.min(pkts[i] - sent_before);
                if this_wave == 0 {
                    continue;
                }
                let mut proc = PoissonProcess::new(self.rates[i], wave_ready[i]);
                for _ in 0..this_wave {
                    let mut t = proc.next(&mut self.rng);
                    // Uplink loss: geometric retransmission with RTO
                    // back-off (SwitchML end-host retransmission).
                    while loss > 0.0 && self.rng.f64() < loss {
                        retransmissions += 1;
                        t += rto;
                    }
                    arrivals.push((t, i));
                }
            }
            if arrivals.is_empty() {
                continue;
            }
            arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            total_packets += arrivals.len() as u64;
            let wave_pkts = arrivals.len();
            let mut wave_end: SimTime = 0.0;
            for &(arrival, _client) in &arrivals {
                wave_end = self.switch.service_packet(arrival);
            }
            end = end.max(wave_end);
            if w + 1 < waves {
                // Slot-credit barrier: the partial aggregates of this
                // wave's blocks are multicast back before the registers
                // are reused; clients resume only after receiving credit.
                // Latency only — byte accounting happens once in the
                // algorithm's download phase.
                let credit = wave_pkts as f64 / self.download_rate();
                let restart = wave_end + credit;
                wave_ready.iter_mut().for_each(|t| *t = restart.max(*t));
                end = end.max(restart);
            }
        }
        PhaseTiming { end, packets: total_packets, retransmissions }
    }

    /// Parallel-shard service: arrivals are generated exactly as in the
    /// single-switch path, assigned round-robin to `n_switches` FIFO
    /// queues with the same Gaussian service model, and the end time is
    /// the max over shards. Waves divide each shard's window identically.
    fn upload_phase_multi(
        &mut self,
        ready: &[SimTime],
        pkts: &[usize],
        waves: usize,
        n_switches: usize,
    ) -> PhaseTiming {
        use crate::net::Mg1Queue;
        let waves = waves.max(1);
        let n = ready.len();
        let loss = self.cfg.loss_rate;
        let rto = self.cfg.retx_timeout_s;
        let profile = self.switch.profile().clone();
        let mut queues: Vec<Mg1Queue> = (0..n_switches).map(|_| Mg1Queue::new()).collect();
        let mut wave_ready: Vec<SimTime> = ready.to_vec();
        let mut total_packets = 0u64;
        let mut retransmissions = 0u64;
        let mut end: SimTime = ready.iter().cloned().fold(0.0, f64::max);
        if waves > 1 {
            self.switch.note_waves(waves as u64 - 1);
        }
        for w in 0..waves {
            let mut arrivals: Vec<(SimTime, usize)> = Vec::new();
            for i in 0..n {
                let per_wave = pkts[i].div_ceil(waves);
                let sent_before = (w * per_wave).min(pkts[i]);
                let this_wave = per_wave.min(pkts[i] - sent_before);
                if this_wave == 0 {
                    continue;
                }
                let mut proc = PoissonProcess::new(self.rates[i], wave_ready[i]);
                for seq in 0..this_wave {
                    let mut t = proc.next(&mut self.rng);
                    while loss > 0.0 && self.rng.f64() < loss {
                        retransmissions += 1;
                        t += rto;
                    }
                    arrivals.push((t, seq % n_switches));
                }
            }
            if arrivals.is_empty() {
                continue;
            }
            arrivals.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            total_packets += arrivals.len() as u64;
            let wave_pkts = arrivals.len();
            let mut wave_end: SimTime = 0.0;
            for &(arrival, shard) in &arrivals {
                // Same service model as ProgrammableSwitch::service_packet,
                // drawn from the env RNG; system-wide op count charged on
                // the primary switch.
                let service = self
                    .rng
                    .gaussian_pos(profile.agg_mean_s, profile.agg_jitter_s);
                let depart = queues[shard].serve(arrival, service);
                wave_end = wave_end.max(depart);
                self.switch.note_shadow_op();
            }
            end = end.max(wave_end);
            if w + 1 < waves {
                let credit = wave_pkts as f64 / self.download_rate();
                let restart = wave_end + credit;
                wave_ready.iter_mut().for_each(|t| *t = restart.max(*t));
                end = end.max(restart);
            }
        }
        PhaseTiming { end, packets: total_packets, retransmissions }
    }

    /// Charge the wire cost of retransmitted copies (full-size frames).
    pub fn charge_retransmissions(
        &mut self,
        timing: &PhaseTiming,
        traffic: &mut TrafficMeter,
    ) {
        traffic.up_bytes += timing.retransmissions * self.cfg.packet_mtu as u64;
    }

    /// Broadcast `payload_bytes` to all clients at the download rate.
    /// Returns the completion time. Traffic is charged per receiving
    /// client (the paper's tables count download traffic for the system).
    pub fn broadcast(
        &mut self,
        start: SimTime,
        payload_bytes: usize,
        traffic: &mut TrafficMeter,
        vote_phase: bool,
    ) -> SimTime {
        let payload = self.cfg.packet_payload();
        let packets = payload_bytes.div_ceil(payload).max(1);
        let wire = payload_bytes + packets * self.cfg.packet_header;
        let bytes_all = wire as u64 * self.cfg.num_clients as u64;
        traffic.down_bytes += bytes_all;
        if vote_phase {
            traffic.vote_down_bytes += bytes_all;
        }
        start + packets as f64 / self.download_rate()
    }

    /// Charge upload traffic for `packets` MTU frames carrying
    /// `payload_bytes` in total (per single client).
    pub fn charge_upload(
        &mut self,
        payload_bytes: usize,
        packets: usize,
        traffic: &mut TrafficMeter,
        vote_phase: bool,
    ) {
        let wire = (payload_bytes + packets * self.cfg.packet_header) as u64;
        traffic.up_bytes += wire;
        if vote_phase {
            traffic.vote_up_bytes += wire;
        }
    }

    /// Packets needed to carry `total_bits` of payload.
    pub fn packets_for_bits(&self, total_bits: usize) -> usize {
        if total_bits == 0 {
            return 0;
        }
        total_bits.div_ceil(8).div_ceil(self.cfg.packet_payload())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configx::{DatasetKind, ExperimentConfig, Partition};
    use crate::data::synth;
    use crate::fl::native::NativeBackend;

    fn env() -> FlEnv {
        let cfg = ExperimentConfig {
            num_clients: 4,
            ..ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid)
        };
        let fd = synth::generate(cfg.dataset, cfg.partition, cfg.num_clients, 40, cfg.seed);
        let backend = Box::new(NativeBackend::new(fd, 16, cfg.local_iters, 8, cfg.seed));
        FlEnv::new(cfg, backend)
    }

    #[test]
    fn rates_match_population() {
        let e = env();
        assert_eq!(e.rates.len(), 4);
        assert!(e.download_rate() > e.mean_rate() * 4.9);
    }

    #[test]
    fn upload_phase_duration_scales_with_packets() {
        let mut e = env();
        let ready = vec![0.0; 4];
        let t_small = e.upload_phase(&ready, &[10; 4], 1);
        let mut e2 = env();
        let t_large = e2.upload_phase(&ready, &[100; 4], 1);
        assert_eq!(t_small.packets, 40);
        assert_eq!(t_large.packets, 400);
        assert!(t_large.end > t_small.end);
    }

    #[test]
    fn waves_serialize_uploads() {
        let mut a = env();
        let mut b = env();
        let ready = vec![0.0; 4];
        let one = a.upload_phase(&ready, &[50; 4], 1);
        let four = b.upload_phase(&ready, &[50; 4], 4);
        assert_eq!(one.packets, four.packets);
        assert!(four.end > one.end, "waves {:.4} vs {:.4}", four.end, one.end);
    }

    #[test]
    fn broadcast_charges_all_clients() {
        let mut e = env();
        let mut t = TrafficMeter::default();
        let end = e.broadcast(1.0, 10_000, &mut t, false);
        assert!(end > 1.0);
        let packets = 10_000usize.div_ceil(e.cfg.packet_payload());
        let wire = 10_000 + packets * e.cfg.packet_header;
        assert_eq!(t.down_bytes, wire as u64 * 4);
    }

    #[test]
    fn packets_for_bits_consistent() {
        let e = env();
        assert_eq!(e.packets_for_bits(0), 0);
        assert_eq!(e.packets_for_bits(8), 1);
        let cap_bits = e.cfg.packet_payload() * 8;
        assert_eq!(e.packets_for_bits(cap_bits), 1);
        assert_eq!(e.packets_for_bits(cap_bits + 1), 2);
    }

    #[test]
    fn multi_ps_parallelises_service_bound_phase() {
        // Service-bound regime: slow switch, fast arrivals. Four shards
        // should finish markedly sooner than one.
        let slow = |n_switches: usize| {
            let mut e = env();
            e.cfg.num_switches = n_switches;
            e.switch = crate::switch::ProgrammableSwitch::new(
                crate::configx::PsProfile {
                    name: "slow".into(),
                    agg_mean_s: 4e-3,
                    agg_jitter_s: 1e-5,
                    memory_bytes: 1 << 20,
                },
                e.cfg.seed,
            );
            let ready = vec![0.0; 4];
            e.upload_phase(&ready, &[200; 4], 1).end
        };
        let t1 = slow(1);
        let t4 = slow(4);
        assert!(
            t4 < 0.5 * t1,
            "4 switches should at least halve a service-bound phase: {t4:.3} vs {t1:.3}"
        );
    }

    #[test]
    fn multi_ps_packet_count_unchanged() {
        let mut e = env();
        e.cfg.num_switches = 3;
        let ready = vec![0.0; 4];
        let t = e.upload_phase(&ready, &[50; 4], 1);
        assert_eq!(t.packets, 200);
        assert_eq!(e.switch.stats().agg_ops, 200, "system-wide ops must be charged");
    }

    #[test]
    fn packet_loss_delays_and_retransmits() {
        let mut clean = env();
        let ready = vec![0.0; 4];
        let t_clean = clean.upload_phase(&ready, &[100; 4], 1);
        assert_eq!(t_clean.retransmissions, 0);

        let mut lossy = env();
        lossy.cfg.loss_rate = 0.2;
        let t_lossy = lossy.upload_phase(&ready, &[100; 4], 1);
        assert!(t_lossy.retransmissions > 0, "no retransmissions at 20% loss");
        assert!(
            t_lossy.end > t_clean.end,
            "loss should delay: {:.4} !> {:.4}",
            t_lossy.end,
            t_clean.end
        );
        // Retransmission traffic charged as full frames.
        let mut traffic = TrafficMeter::default();
        lossy.charge_retransmissions(&t_lossy, &mut traffic);
        assert_eq!(
            traffic.up_bytes,
            t_lossy.retransmissions * lossy.cfg.packet_mtu as u64
        );
    }

    #[test]
    fn ready_times_jittered_around_constant() {
        let mut e = env();
        let ready = e.local_train_ready(10.0);
        let base = e.cfg.dataset.local_train_time_s();
        for &r in &ready {
            assert!(r >= 10.0 + base * 0.95 && r <= 10.0 + base * 1.05);
        }
    }
}
