//! Pure-rust model backend: one-hidden-layer MLP with manual backprop.
//!
//! Exists so the entire coordination stack (voting, GIA, switch, queueing,
//! traffic) can be exercised deterministically and fast without the AOT
//! artifacts — CI, property tests and large parameter sweeps use this.
//! The PJRT backend replaces it for the full paper stack. The compression
//! members delegate to `crate::compress`, which mirrors the Pallas kernel
//! math exactly.
//!
//! Layout of the flat vector: [W1 (in×h) | b1 (h) | W2 (h×C) | b2 (C)],
//! row-major, matching the convention of `python/compile/model.py`.

use crate::compress;
use crate::data::FederatedData;
use crate::fl::backend::{LocalTrainOutput, ModelBackend};
use crate::util::Rng;

/// MLP dimensions + data + sampling state.
pub struct NativeBackend {
    data: FederatedData,
    input: usize,
    hidden: usize,
    classes: usize,
    local_iters: usize,
    batch: usize,
    seed: u64,
    // Reused buffers (no allocation in the train loop).
    feat_buf: Vec<f32>,
    label_buf: Vec<i32>,
    h_buf: Vec<f32>,
    logits_buf: Vec<f32>,
    dh_buf: Vec<f32>,
}

impl NativeBackend {
    /// Build a backend over `data` with a `hidden`-unit MLP, `local_iters`
    /// SGD steps per round at batch size `batch`, seeded by `seed`.
    pub fn new(
        data: FederatedData,
        hidden: usize,
        local_iters: usize,
        batch: usize,
        seed: u64,
    ) -> Self {
        let input = data.train.feature_len();
        let classes = data.train.num_classes();
        NativeBackend {
            data,
            input,
            hidden,
            classes,
            local_iters,
            batch,
            seed,
            feat_buf: Vec::new(),
            label_buf: Vec::new(),
            h_buf: Vec::new(),
            logits_buf: Vec::new(),
            dh_buf: Vec::new(),
        }
    }

    /// The federated dataset this backend trains on.
    pub fn data(&self) -> &FederatedData {
        &self.data
    }

    fn dims(&self) -> (usize, usize, usize, usize) {
        let w1 = self.input * self.hidden;
        let b1 = self.hidden;
        let w2 = self.hidden * self.classes;
        let b2 = self.classes;
        (w1, b1, w2, b2)
    }

    /// One SGD step on a batch; returns the mean loss. Gradients are
    /// accumulated straight into `params` scaled by −lr/B (fused update).
    fn sgd_step(&mut self, params: &mut [f32], indices: &[usize], lr: f32) -> f32 {
        let (w1n, b1n, w2n, _) = self.dims();
        let b = indices.len();
        let (inp, hid, cls) = (self.input, self.hidden, self.classes);

        self.feat_buf.resize(b * inp, 0.0);
        self.label_buf.resize(b, 0);
        self.data.train.fill_batch(indices, &mut self.feat_buf, &mut self.label_buf);

        self.h_buf.resize(b * hid, 0.0);
        self.logits_buf.resize(b * cls, 0.0);
        self.dh_buf.resize(b * hid, 0.0);

        let scale = lr / b as f32;
        let mut loss_sum = 0.0f64;

        // Forward for the whole batch.
        for r in 0..b {
            let x = &self.feat_buf[r * inp..(r + 1) * inp];
            let h = &mut self.h_buf[r * hid..(r + 1) * hid];
            // h = b1 + xᵀ·W1, accumulated input-major so every W1 row access
            // is contiguous (W1 is (input × hidden) row-major: row i at i·hid).
            h.copy_from_slice(&params[w1n..w1n + hid]);
            for (i, &xi) in x.iter().enumerate() {
                if xi != 0.0 {
                    let row = &params[i * hid..(i + 1) * hid];
                    for (hj, &wj) in h.iter_mut().zip(row) {
                        *hj += xi * wj;
                    }
                }
            }
            for hj in h.iter_mut() {
                *hj = hj.max(0.0); // relu
            }
            let logits = &mut self.logits_buf[r * cls..(r + 1) * cls];
            for c in 0..cls {
                let mut acc = params[w1n + b1n + w2n + c]; // b2[c]
                for (j, &hj) in h.iter().enumerate() {
                    acc += hj * params[w1n + b1n + j * cls + c];
                }
                logits[c] = acc;
            }
            // Softmax CE, computing dlogits in place.
            let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut z = 0.0f32;
            for l in logits.iter_mut() {
                *l = (*l - max).exp();
                z += *l;
            }
            let label = self.label_buf[r] as usize;
            loss_sum += -(f64::from(logits[label]) / f64::from(z)).ln();
            for l in logits.iter_mut() {
                *l /= z; // now softmax probs
            }
            logits[label] -= 1.0; // dlogits = p − y
        }

        // Backward + fused SGD update.
        for r in 0..b {
            let x = &self.feat_buf[r * inp..(r + 1) * inp];
            let h = &self.h_buf[r * hid..(r + 1) * hid];
            let dlogits = &self.logits_buf[r * cls..(r + 1) * cls];
            let dh = &mut self.dh_buf[r * hid..(r + 1) * hid];
            // dH = dlogits · W2ᵀ, gated by relu; W2 update.
            for j in 0..hid {
                let mut acc = 0.0f32;
                let w2_row = w1n + b1n + j * cls;
                for c in 0..cls {
                    acc += dlogits[c] * params[w2_row + c];
                }
                dh[j] = if h[j] > 0.0 { acc } else { 0.0 };
            }
            for c in 0..cls {
                let d = dlogits[c];
                params[w1n + b1n + w2n + c] -= scale * d; // b2
            }
            for j in 0..hid {
                let hj = h[j];
                if hj != 0.0 {
                    let w2_row = w1n + b1n + j * cls;
                    for c in 0..cls {
                        params[w2_row + c] -= scale * dlogits[c] * hj;
                    }
                }
                if dh[j] != 0.0 {
                    params[w1n + j] -= scale * dh[j]; // b1
                }
            }
            // W1 update input-major: each touched W1 row is contiguous.
            for (i, &xi) in x.iter().enumerate() {
                if xi != 0.0 {
                    let sxi = scale * xi;
                    let row = &mut params[i * hid..(i + 1) * hid];
                    for (wj, &dhj) in row.iter_mut().zip(dh.iter()) {
                        *wj -= sxi * dhj;
                    }
                }
            }
        }
        (loss_sum / b as f64) as f32
    }

    fn forward_logits(&self, params: &[f32], x: &[f32], logits: &mut [f32]) {
        let (w1n, b1n, w2n, _) = self.dims();
        let (hid, cls) = (self.hidden, self.classes);
        let mut h = params[w1n..w1n + hid].to_vec();
        for (i, &xi) in x.iter().enumerate() {
            if xi != 0.0 {
                let row = &params[i * hid..(i + 1) * hid];
                for (hj, &wj) in h.iter_mut().zip(row) {
                    *hj += xi * wj;
                }
            }
        }
        for hj in h.iter_mut() {
            *hj = hj.max(0.0);
        }
        for c in 0..cls {
            let mut acc = params[w1n + b1n + w2n + c];
            for (j, &hj) in h.iter().enumerate() {
                acc += hj * params[w1n + b1n + j * cls + c];
            }
            logits[c] = acc;
        }
    }
}

impl ModelBackend for NativeBackend {
    fn d(&self) -> usize {
        let (w1, b1, w2, b2) = self.dims();
        w1 + b1 + w2 + b2
    }

    fn init_params(&mut self) -> Vec<f32> {
        let mut rng = Rng::new(self.seed ^ 0x1417);
        let (w1n, b1n, w2n, b2n) = self.dims();
        let mut p = vec![0.0f32; w1n + b1n + w2n + b2n];
        let s1 = (2.0 / self.input as f64).sqrt();
        let s2 = (2.0 / self.hidden as f64).sqrt();
        for v in &mut p[..w1n] {
            *v = (rng.gaussian() * s1) as f32;
        }
        for v in &mut p[w1n + b1n..w1n + b1n + w2n] {
            *v = (rng.gaussian() * s2) as f32;
        }
        p
    }

    fn local_train(
        &mut self,
        params: &[f32],
        client: usize,
        round: usize,
        lr: f32,
    ) -> LocalTrainOutput {
        let mut p = params.to_vec();
        let mut rng =
            Rng::new(self.seed ^ (client as u64) << 20 ^ (round as u64) << 1 ^ 0xB47C);
        let my = self.data.client_indices[client].clone();
        assert!(!my.is_empty(), "client {client} has no data");
        let mut loss_sum = 0.0f32;
        for _ in 0..self.local_iters {
            let batch: Vec<usize> =
                (0..self.batch).map(|_| my[rng.below(my.len())]).collect();
            loss_sum += self.sgd_step(&mut p, &batch, lr);
        }
        LocalTrainOutput { new_params: p, mean_loss: loss_sum / self.local_iters as f32 }
    }

    fn evaluate(&mut self, params: &[f32]) -> (f64, f64) {
        let n = self.data.test.len();
        let mut logits = vec![0.0f32; self.classes];
        let mut correct = 0usize;
        let mut loss_sum = 0.0f64;
        for i in 0..n {
            let x = self.data.test.features_of(i);
            self.forward_logits(params, x, &mut logits);
            let label = self.data.test.label_of(i) as usize;
            let pred = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            if pred == label {
                correct += 1;
            }
            let max = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let z: f32 = logits.iter().map(|l| (l - max).exp()).sum();
            loss_sum += -f64::from(logits[label] - max) + f64::from(z.ln());
        }
        (correct as f64 / n as f64, loss_sum / n as f64)
    }

    fn vote_scores(&mut self, updates: &[f32], seed: i64) -> Vec<f32> {
        let mut rng = Rng::new(self.seed ^ seed as u64 ^ 0x907e);
        compress::vote_scores_native(updates, &mut rng)
    }

    fn compress(
        &mut self,
        updates: &[f32],
        gia: &[f32],
        f: f32,
        seed: i64,
    ) -> (Vec<i32>, Vec<f32>) {
        let mut rng = Rng::new(self.seed ^ seed as u64 ^ 0xc049);
        compress::quantize_sparsify(updates, gia, f, &mut rng)
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configx::{DatasetKind, Partition};
    use crate::data::synth;

    fn backend() -> NativeBackend {
        let fd = synth::generate(DatasetKind::Tiny, Partition::Iid, 4, 60, 5);
        NativeBackend::new(fd, 32, 5, 16, 5)
    }

    #[test]
    fn d_matches_layout() {
        let b = backend();
        assert_eq!(b.d(), 32 * 32 + 32 + 32 * 10 + 10);
    }

    #[test]
    fn init_deterministic() {
        let mut b = backend();
        assert_eq!(b.init_params(), b.init_params());
    }

    #[test]
    fn local_training_reduces_loss() {
        let mut b = backend();
        let mut params = b.init_params();
        let mut first = None;
        let mut last = 0.0;
        for round in 0..10 {
            let out = b.local_train(&params, 0, round, 0.1);
            params = out.new_params;
            if first.is_none() {
                first = Some(out.mean_loss);
            }
            last = out.mean_loss;
        }
        assert!(last < first.unwrap(), "loss {first:?} → {last}");
    }

    #[test]
    fn accuracy_improves_with_training() {
        let mut b = backend();
        let mut params = b.init_params();
        let (acc0, _) = b.evaluate(&params);
        for round in 0..25 {
            // All clients train sequentially on the shared model (FedSGD-ish).
            for c in 0..4 {
                let out = b.local_train(&params, c, round, 0.05);
                // Average client deltas to emulate aggregation.
                for (p, np) in params.iter_mut().zip(&out.new_params) {
                    *p += (np - *p) / 4.0;
                }
            }
        }
        let (acc1, _) = b.evaluate(&params);
        assert!(acc1 > acc0 + 0.2, "acc {acc0} → {acc1}");
    }

    #[test]
    fn updates_nonzero_and_finite() {
        let mut b = backend();
        let params = b.init_params();
        let out = b.local_train(&params, 1, 0, 0.1);
        let u: Vec<f32> =
            params.iter().zip(&out.new_params).map(|(a, b)| a - b).collect();
        assert!(u.iter().any(|&x| x != 0.0));
        assert!(u.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn local_train_deterministic_per_round() {
        let mut b = backend();
        let params = b.init_params();
        let a = b.local_train(&params, 2, 7, 0.1);
        let c = b.local_train(&params, 2, 7, 0.1);
        assert_eq!(a.new_params, c.new_params);
        let d = b.local_train(&params, 2, 8, 0.1);
        assert_ne!(a.new_params, d.new_params);
    }
}
