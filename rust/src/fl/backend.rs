//! Model-execution backend abstraction.
//!
//! The FL round engine treats the model as an opaque flat f32 vector and
//! asks a backend for four operations. Two implementations exist:
//!
//! * [`crate::fl::native::NativeBackend`] — pure-rust manual-backprop MLP;
//!   artifact-free, fast, used by tests and quick sweeps.
//! * [`crate::runtime::PjrtBackend`] — the full paper stack: AOT-lowered
//!   JAX/Pallas HLO executed through the PJRT C API.
//!
//! Both must satisfy the same contract; `rust/tests/protocol_props.rs`
//! cross-checks compression semantics between them.

/// Result of one client's local training round (E SGD iterations).
#[derive(Debug, Clone)]
pub struct LocalTrainOutput {
    /// Parameters after the client's E local iterations.
    pub new_params: Vec<f32>,
    /// Mean training loss over those iterations.
    pub mean_loss: f32,
}

/// Uniform interface over native and PJRT model execution.
pub trait ModelBackend {
    /// Flat parameter dimension d.
    fn d(&self) -> usize;

    /// Deterministic initial global model w₁.
    fn init_params(&mut self) -> Vec<f32>;

    /// Run E local SGD iterations for `client` starting from `params`
    /// (Algorithm 1 line 3). `round` seeds batch sampling.
    fn local_train(
        &mut self,
        params: &[f32],
        client: usize,
        round: usize,
        lr: f32,
    ) -> LocalTrainOutput;

    /// Full-test-set evaluation → (accuracy ∈ [0,1], mean loss).
    fn evaluate(&mut self, params: &[f32]) -> (f64, f64);

    /// Gumbel vote scores for one client's updates (§IV step 1).
    fn vote_scores(&mut self, updates: &[f32], seed: i64) -> Vec<f32>;

    /// Fused quantise+sparsify+residual (§IV step 3 / Eq. 1):
    /// (updates, gia mask of 0.0/1.0, f, seed) → (q, residual).
    fn compress(
        &mut self,
        updates: &[f32],
        gia: &[f32],
        f: f32,
        seed: i64,
    ) -> (Vec<i32>, Vec<f32>);

    /// Human-readable backend name for logs.
    fn backend_name(&self) -> &'static str;
}
