//! FL core: the backend abstraction, the pure-rust native backend and the
//! shared round environment (model, switch, timing, traffic).

pub mod backend;
pub mod env;
pub mod native;

pub use backend::{LocalTrainOutput, ModelBackend};
pub use env::{FlEnv, PhaseTiming};
pub use native::NativeBackend;
