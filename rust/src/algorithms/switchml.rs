//! SwitchML baseline [5]: dense b-bit quantised in-network aggregation.
//!
//! Every round, every client quantises all d updates into b-bit integers
//! (the paper tunes b and finds 12 best, §V-A3) and streams them to the
//! PS, which accumulates aligned i32 lanes slot-by-slot and multicasts
//! the aggregate. No sparsification, no residual (the quantiser is
//! unbiased); communication is d·b up + d·32 down per client per round.

use anyhow::Result;

use crate::algorithms::{common, Algorithm, RoundReport};
use crate::compress;
use crate::configx::{AlgorithmKind, ExperimentConfig};
use crate::fl::FlEnv;
use crate::metrics::TrafficMeter;
use crate::switch::{waves_needed, RegisterFile, UpdateAggregator};

/// SwitchML baseline: dense quantised in-network aggregation (§II).
pub struct SwitchMl {
    bits: usize,
}

impl SwitchMl {
    /// Configure SwitchML from the tuned baselines.
    pub fn new(cfg: &ExperimentConfig) -> Self {
        SwitchMl { bits: cfg.baselines.switchml_bits }
    }
}

impl Algorithm for SwitchMl {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::SwitchMl
    }

    fn run_round(&mut self, env: &mut FlEnv, round: usize) -> Result<RoundReport> {
        let lr = env.cfg.lr.at(round) as f32;
        let d = env.d();
        let n = env.cfg.num_clients;
        let payload = env.cfg.packet_payload();
        let agg_ops_before = env.switch.stats().agg_ops;
        env.switch.reset_queue();
        let mut traffic = TrafficMeter::default();

        let local = common::local_training(env, round, lr, None);
        let m = common::global_max_abs(&local.updates);
        let f = compress::scale_factor(self.bits, n, m);

        let epb = (payload * 8 / self.bits).max(1);
        let n_blocks = d.div_ceil(epb);
        let mem = env.switch.profile().memory_bytes;
        let window = (mem / (epb * 4)).max(1);
        let waves = waves_needed(n_blocks, window);
        env.switch.note_memory_demand((d * 4).min(mem), d * 4);

        let mut file = RegisterFile::new(d * 4);
        let mut agg = UpdateAggregator::new(&mut file, d, n, epb).unwrap();
        let ones = vec![1.0f32; d];
        let bits_up = d * self.bits;
        let pkts: Vec<usize> = vec![env.packets_for_bits(bits_up); n];
        for i in 0..n {
            // The unbiased quantiser is the same L1 kernel FediAC uses,
            // with an all-ones mask (SwitchML keeps every dimension).
            let seed = 0x50ED_0000 | (round as i64) << 8 | i as i64;
            let (q, _residual) = env.backend.compress(&local.updates[i], &ones, f, seed);
            for block in 0..n_blocks {
                let lo = block * epb;
                let hi = ((block + 1) * epb).min(d);
                agg.ingest(i, block, &q[lo..hi]);
            }
            env.charge_upload(bits_up.div_ceil(8), pkts[i], &mut traffic, false);
        }
        debug_assert!(agg.all_complete());

        let t_up = env.upload_phase(&local.ready, &pkts, waves);
        env.charge_retransmissions(&t_up, &mut traffic);
        let t_done = env.broadcast(t_up.end, d * 4, &mut traffic, false);

        let overflow = agg.overflow_lanes();
        if overflow > 0 {
            env.switch.note_overflow(overflow);
        }
        let delta = compress::dequantize_aggregate(agg.aggregate(), n, f);
        agg.release(&mut file);
        common::apply_dense_delta(&mut env.params, &delta);

        env.traffic_total.add(&traffic);
        Ok(RoundReport {
            round,
            duration_s: t_done,
            train_loss: local.mean_loss,
            traffic,
            agg_ops: env.switch.stats().agg_ops - agg_ops_before,
            uploaded_elems: d as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configx::{DatasetKind, Partition};
    use crate::data::synth;
    use crate::fl::NativeBackend;

    fn make_env(n: usize) -> FlEnv {
        let cfg = ExperimentConfig {
            num_clients: n,
            ..ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid)
        };
        let fd = synth::generate(cfg.dataset, cfg.partition, n, 40, cfg.seed);
        let backend = Box::new(NativeBackend::new(fd, 16, cfg.local_iters, 8, cfg.seed));
        let mut env = FlEnv::new(cfg, backend);
        env.init_model();
        env
    }

    #[test]
    fn round_runs_and_learns() {
        let mut env = make_env(4);
        let mut alg = SwitchMl::new(&env.cfg);
        let mut first = None;
        let mut last = 0.0;
        for round in 0..8 {
            let r = alg.run_round(&mut env, round).unwrap();
            assert!(r.agg_ops > 0);
            assert_eq!(r.uploaded_elems as usize, env.d());
            if round == 0 {
                first = Some(r.train_loss);
            }
            last = r.train_loss;
        }
        assert!(last < first.unwrap());
    }

    #[test]
    fn traffic_is_dense_b_bits() {
        let mut env = make_env(3);
        let mut alg = SwitchMl::new(&env.cfg);
        let r = alg.run_round(&mut env, 0).unwrap();
        let d = env.d();
        let bits = env.cfg.baselines.switchml_bits;
        let payload = env.cfg.packet_payload();
        let pkts = (d * bits).div_ceil(8).div_ceil(payload);
        let expect_up = 3 * ((d * bits).div_ceil(8) + pkts * env.cfg.packet_header);
        assert_eq!(r.traffic.up_bytes, expect_up as u64);
        assert_eq!(r.traffic.vote_up_bytes, 0, "switchml has no vote phase");
    }

    #[test]
    fn more_bits_more_traffic() {
        let mut e1 = make_env(3);
        e1.cfg.baselines.switchml_bits = 8;
        let r8 = SwitchMl::new(&e1.cfg).run_round(&mut e1, 0).unwrap();
        let mut e2 = make_env(3);
        e2.cfg.baselines.switchml_bits = 14;
        let r14 = SwitchMl::new(&e2.cfg).run_round(&mut e2, 0).unwrap();
        assert!(r14.traffic.up_bytes > r8.traffic.up_bytes);
    }
}
