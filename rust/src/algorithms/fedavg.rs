//! FedAvg over a remote parameter server [8] — the uncompressed reference.
//!
//! Full-precision f32 updates travel to a conventional server (no PS on
//! the path), are averaged, and the dense global delta is broadcast. Used
//! as the convergence upper bound and the traffic/latency anchor the
//! in-network algorithms are compared against.

use anyhow::Result;

use crate::algorithms::{common, Algorithm, RoundReport};
use crate::configx::{AlgorithmKind, ExperimentConfig};
use crate::fl::FlEnv;
use crate::metrics::TrafficMeter;

/// Plain parameter-server FedAvg (uncompressed reference point).
pub struct FedAvg;

impl FedAvg {
    /// FedAvg has no knobs.
    pub fn new(_cfg: &ExperimentConfig) -> Self {
        FedAvg
    }
}

impl Algorithm for FedAvg {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::FedAvg
    }

    fn run_round(&mut self, env: &mut FlEnv, round: usize) -> Result<RoundReport> {
        let lr = env.cfg.lr.at(round) as f32;
        let d = env.d();
        let n = env.cfg.num_clients;
        let mut traffic = TrafficMeter::default();

        let local = common::local_training(env, round, lr, None);

        let bits_up = d * 32;
        let pkts: Vec<usize> = vec![env.packets_for_bits(bits_up); n];
        for _ in 0..n {
            env.charge_upload(bits_up / 8, pkts[0], &mut traffic, false);
        }
        let upload_end = common::server_path(env, &local.ready, &pkts);
        let t_done = env.broadcast(upload_end, d * 4, &mut traffic, false);

        let mut delta = vec![0.0f32; d];
        for u in &local.updates {
            for (acc, &v) in delta.iter_mut().zip(u) {
                *acc += v;
            }
        }
        delta.iter_mut().for_each(|v| *v /= n as f32);
        common::apply_dense_delta(&mut env.params, &delta);

        env.traffic_total.add(&traffic);
        Ok(RoundReport {
            round,
            duration_s: t_done,
            train_loss: local.mean_loss,
            traffic,
            agg_ops: 0,
            uploaded_elems: d as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configx::{DatasetKind, Partition};
    use crate::data::synth;
    use crate::fl::NativeBackend;

    fn make_env(n: usize) -> FlEnv {
        let cfg = ExperimentConfig {
            num_clients: n,
            ..ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid)
        };
        let fd = synth::generate(cfg.dataset, cfg.partition, n, 40, cfg.seed);
        let backend = Box::new(NativeBackend::new(fd, 16, cfg.local_iters, 8, cfg.seed));
        let mut env = FlEnv::new(cfg, backend);
        env.init_model();
        env
    }

    #[test]
    fn converges_fast_per_round() {
        let mut env = make_env(4);
        let mut alg = FedAvg::new(&env.cfg);
        let mut first = None;
        let mut last = 0.0;
        for round in 0..8 {
            let r = alg.run_round(&mut env, round).unwrap();
            assert_eq!(r.agg_ops, 0, "fedavg must not touch the switch");
            if round == 0 {
                first = Some(r.train_loss);
            }
            last = r.train_loss;
        }
        assert!(last < first.unwrap());
    }

    #[test]
    fn traffic_is_full_precision() {
        let mut env = make_env(2);
        let mut alg = FedAvg::new(&env.cfg);
        let r = alg.run_round(&mut env, 0).unwrap();
        let d = env.d();
        let payload = env.cfg.packet_payload();
        let pkts = (d * 4).div_ceil(payload);
        let expect = 2 * (d * 4 + pkts * env.cfg.packet_header);
        assert_eq!(r.traffic.up_bytes, expect as u64);
    }

    #[test]
    fn slower_than_in_network_on_same_payload() {
        // The premise of the paper: a server round takes longer than a
        // switch round for the same dense payload (server per-packet time
        // + RTT dominate).
        use crate::algorithms::switchml::SwitchMl;
        let mut env_s = make_env(4);
        let t_sml = SwitchMl::new(&env_s.cfg)
            .run_round(&mut env_s, 0)
            .unwrap()
            .duration_s;
        let mut env_f = make_env(4);
        let t_avg = FedAvg::new(&env_f.cfg).run_round(&mut env_f, 0).unwrap().duration_s;
        assert!(
            t_avg > t_sml,
            "fedavg {t_avg:.4}s should exceed switchml {t_sml:.4}s"
        );
    }
}
