//! Shared protocol building blocks: local-training fan-out, remote
//! parameter-server path timing, and small helpers every algorithm uses.

use crate::fl::FlEnv;
use crate::net::{Mg1Queue, PoissonProcess};
use crate::sim::SimTime;

/// All clients' local updates for one round, plus bookkeeping.
pub struct LocalRound {
    /// U_t^i per client (with residual folded in by the caller if any).
    pub updates: Vec<Vec<f32>>,
    /// Mean local training loss across clients.
    pub mean_loss: f64,
    /// Per-client local-training completion time (relative to round start).
    pub ready: Vec<SimTime>,
}

/// Run local training for every client from the current global model.
/// `residuals`, when provided, are added to the raw update (Algorithm 1
/// line 4: U = w_{t,0} − w_{t,E} + e_{t−1}).
pub fn local_training(
    env: &mut FlEnv,
    round: usize,
    lr: f32,
    residuals: Option<&[Vec<f32>]>,
) -> LocalRound {
    let n = env.cfg.num_clients;
    let params = env.params.clone();
    let mut updates = Vec::with_capacity(n);
    let mut loss_sum = 0.0f64;
    for i in 0..n {
        let out = env.backend.local_train(&params, i, round, lr);
        let mut u: Vec<f32> =
            params.iter().zip(&out.new_params).map(|(w0, we)| w0 - we).collect();
        if let Some(res) = residuals {
            for (uv, &rv) in u.iter_mut().zip(&res[i]) {
                *uv += rv;
            }
        }
        updates.push(u);
        loss_sum += out.mean_loss as f64;
    }
    let ready = env.local_train_ready(0.0);
    LocalRound { updates, mean_loss: loss_sum / n as f64, ready }
}

/// Global max-|U| across clients — the m in f = (2^{b−1} − N)/(N·m).
/// On the wire this is one 4-byte scalar per client folded into the first
/// upload packet (the PS takes the max, an operation Tofino supports).
pub fn global_max_abs(updates: &[Vec<f32>]) -> f32 {
    updates
        .iter()
        .map(|u| crate::compress::max_abs(u))
        .fold(f32::MIN_POSITIVE, f32::max)
}

/// Timing of a remote parameter-server exchange (libra cold path, FedAvg):
/// per-client Poisson packet streams, one RTT each way, M/G/1 service at
/// the server with the configured per-packet time.
pub fn server_path(
    env: &mut FlEnv,
    ready: &[SimTime],
    pkts: &[usize],
) -> SimTime {
    let rtt = env.cfg.baselines.server_rtt_s;
    let service = env.cfg.baselines.server_packet_time_s * env.cfg.net_scale;
    let mut queue = Mg1Queue::new();
    let mut arrivals: Vec<SimTime> = Vec::new();
    for i in 0..ready.len() {
        if pkts[i] == 0 {
            continue;
        }
        let mut proc = PoissonProcess::new(env.rates[i], ready[i]);
        for _ in 0..pkts[i] {
            arrivals.push(proc.next(&mut env.rng) + rtt / 2.0);
        }
    }
    if arrivals.is_empty() {
        return ready.iter().cloned().fold(0.0, f64::max);
    }
    arrivals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut end: SimTime = 0.0;
    for &a in &arrivals {
        let jitter = 1.0 + 0.1 * (env.rng.f64() - 0.5);
        end = queue.serve(a, service * jitter);
    }
    end + rtt / 2.0
}

/// Apply the aggregated float delta to the global model:
/// w_{t+1} = w_t − delta (delta already scaled by 1/(N·f)).
pub fn apply_dense_delta(params: &mut [f32], delta: &[f32]) {
    for (p, &d) in params.iter_mut().zip(delta) {
        *p -= d;
    }
}

/// Scatter-apply a sparse aggregate at `indices`.
pub fn apply_sparse_delta(params: &mut [f32], indices: &[usize], delta: &[f32]) {
    debug_assert_eq!(indices.len(), delta.len());
    for (&i, &d) in indices.iter().zip(delta) {
        params[i] -= d;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configx::{DatasetKind, ExperimentConfig, Partition};
    use crate::data::synth;
    use crate::fl::NativeBackend;

    fn env() -> FlEnv {
        let cfg = ExperimentConfig {
            num_clients: 3,
            ..ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid)
        };
        let fd = synth::generate(cfg.dataset, cfg.partition, 3, 30, cfg.seed);
        let backend = Box::new(NativeBackend::new(fd, 16, cfg.local_iters, 8, cfg.seed));
        let mut e = FlEnv::new(cfg, backend);
        e.init_model();
        e
    }

    #[test]
    fn local_training_produces_updates() {
        let mut e = env();
        let lr = LocalRound { ..local_training(&mut e, 0, 0.1, None) };
        assert_eq!(lr.updates.len(), 3);
        assert!(lr.updates.iter().all(|u| u.len() == e.d()));
        assert!(lr.mean_loss.is_finite());
        assert!(lr.updates[0].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn residuals_fold_into_updates() {
        let mut e = env();
        let d = e.d();
        let res = vec![vec![1.0f32; d]; 3];
        let with = local_training(&mut e, 0, 0.1, Some(&res));
        let mut e2 = env();
        let without = local_training(&mut e2, 0, 0.1, None);
        for (a, b) in with.updates[0].iter().zip(&without.updates[0]) {
            assert!((a - b - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn server_path_slower_with_more_packets() {
        let mut e = env();
        let ready = vec![0.0; 3];
        let t1 = server_path(&mut e, &ready, &[5, 5, 5]);
        let mut e2 = env();
        let t2 = server_path(&mut e2, &ready, &[500, 500, 500]);
        assert!(t2 > t1);
        // At least one RTT even when empty.
        let mut e3 = env();
        let t0 = server_path(&mut e3, &ready, &[0, 0, 0]);
        assert!(t0 >= 0.0);
    }

    #[test]
    fn delta_application() {
        let mut p = vec![1.0f32, 2.0, 3.0];
        apply_dense_delta(&mut p, &[0.5, 0.0, -1.0]);
        assert_eq!(p, vec![0.5, 2.0, 4.0]);
        apply_sparse_delta(&mut p, &[2], &[1.0]);
        assert_eq!(p, vec![0.5, 2.0, 3.0]);
    }

    #[test]
    fn global_max_abs_over_clients() {
        let updates = vec![vec![0.5f32, -0.1], vec![-0.9, 0.2]];
        assert!((global_max_abs(&updates) - 0.9).abs() < 1e-7);
    }
}
