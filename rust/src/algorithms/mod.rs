//! In-network aggregation algorithms: FediAC (the paper's contribution)
//! and the §V-A3 baselines (SwitchML, OmniReduce, libra) plus plain
//! server-side FedAvg. Every algorithm implements [`Algorithm`] and drives
//! its protocol through the shared [`crate::fl::FlEnv`].

pub mod common;
pub mod fedavg;
pub mod fediac;
pub mod libra;
pub mod omnireduce;
pub mod switchml;

use crate::configx::{AlgorithmKind, ExperimentConfig};
use crate::fl::FlEnv;
use crate::metrics::TrafficMeter;

/// Outcome of one global iteration.
#[derive(Debug, Clone)]
pub struct RoundReport {
    /// Global iteration index.
    pub round: usize,
    /// Simulated duration of the round (s).
    pub duration_s: f64,
    /// Mean local training loss across clients.
    pub train_loss: f64,
    /// Bytes the round moved.
    pub traffic: TrafficMeter,
    /// Switch aggregation ops consumed this round.
    pub agg_ops: u64,
    /// Mean dimensions uploaded per client this round.
    pub uploaded_elems: f64,
}

/// A federated aggregation protocol.
pub trait Algorithm {
    /// Which algorithm this is (for labels and dispatch).
    fn kind(&self) -> AlgorithmKind;

    /// Execute global iteration `round`, mutating `env.params` in place
    /// and returning timing/traffic accounting.
    fn run_round(&mut self, env: &mut FlEnv, round: usize) -> anyhow::Result<RoundReport>;
}

/// Instantiate the algorithm named in the config.
pub fn make_algorithm(cfg: &ExperimentConfig, d: usize) -> Box<dyn Algorithm> {
    match cfg.algorithm {
        AlgorithmKind::FediAc => Box::new(fediac::FediAc::new(cfg, d)),
        AlgorithmKind::SwitchMl => Box::new(switchml::SwitchMl::new(cfg)),
        AlgorithmKind::OmniReduce => Box::new(omnireduce::OmniReduce::new(cfg, d)),
        AlgorithmKind::Libra => Box::new(libra::Libra::new(cfg, d)),
        AlgorithmKind::FedAvg => Box::new(fedavg::FedAvg::new(cfg)),
    }
}
