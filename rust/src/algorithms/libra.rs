//! libra baseline [9]: hot/cold parameter split.
//!
//! "libra divides model parameters into hot and cold types, representing
//! parameters that will be updated frequently and rarely. The switch is
//! only responsible for the aggregation of hot parameters. Cold parameters
//! are redirected to a remote server for aggregation." (§V-A3; Topk with
//! the paper-tuned k = 1%·d.)
//!
//! The hot set is global switch state installed ahead of the round; we
//! maintain it as an EMA of per-dimension selection frequency (standing in
//! for libra's offline pretraining predictor — the paper excludes that
//! pretraining overhead from its measurements, and so do we).

use anyhow::Result;

use crate::algorithms::{common, Algorithm, RoundReport};
use crate::compress::{self, topk};
use crate::configx::{AlgorithmKind, ExperimentConfig};
use crate::fl::FlEnv;
use crate::metrics::TrafficMeter;
use crate::switch::{alu, waves_needed};

/// libra baseline: hot dimensions aggregate on the switch, cold ones on
/// a remote server (§II related work).
pub struct Libra {
    residuals: Vec<Vec<f32>>,
    /// Per-dimension EMA of selection frequency (the hotness predictor).
    hotness: Vec<f32>,
    /// Dimensions currently installed as hot switch slots.
    hot_set: Vec<usize>,
    k: usize,
    hot_slots: usize,
    bits: usize,
}

impl Libra {
    /// Configure libra for model dimension `d` from the tuned baselines.
    pub fn new(cfg: &ExperimentConfig, d: usize) -> Self {
        let k = ((cfg.baselines.libra_k_frac * d as f64).round() as usize).clamp(1, d);
        // Hot slots sized to hot_frac of the expected per-round union,
        // capped by switch registers (4 B per slot).
        let hot_slots = ((cfg.baselines.libra_hot_frac * (k * cfg.num_clients) as f64)
            as usize)
            .clamp(1, cfg.ps.memory_bytes / 4)
            .min(d);
        Libra {
            residuals: vec![vec![0.0; d]; cfg.num_clients],
            hotness: vec![0.0; d],
            hot_set: Vec::new(),
            k,
            hot_slots,
            bits: 16,
        }
    }

    fn refresh_hot_set(&mut self) {
        if self.hotness.iter().all(|&h| h == 0.0) {
            self.hot_set.clear();
            return;
        }
        self.hot_set = compress::top_k_indices(&self.hotness, self.hot_slots);
    }
}

impl Algorithm for Libra {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::Libra
    }

    fn run_round(&mut self, env: &mut FlEnv, round: usize) -> Result<RoundReport> {
        let lr = env.cfg.lr.at(round) as f32;
        let d = env.d();
        let n = env.cfg.num_clients;
        let payload = env.cfg.packet_payload();
        let agg_ops_before = env.switch.stats().agg_ops;
        env.switch.reset_queue();
        let mut traffic = TrafficMeter::default();

        // Hot set installed *before* the round from past frequencies.
        self.refresh_hot_set();
        let mut is_hot = vec![false; d];
        let mut hot_slot_of = vec![usize::MAX; d];
        for (slot, &dim) in self.hot_set.iter().enumerate() {
            is_hot[dim] = true;
            hot_slot_of[dim] = slot;
        }

        let ef = env.cfg.baselines.error_feedback;
        let local = common::local_training(
            env,
            round,
            lr,
            ef.then_some(self.residuals.as_slice()),
        );
        let m = common::global_max_abs(&local.updates);
        let f = compress::scale_factor(self.bits, n, m);

        let mut hot_acc = vec![0i32; self.hot_set.len()];
        let mut cold_acc: std::collections::BTreeMap<usize, i64> =
            std::collections::BTreeMap::new();
        let mut switch_pkts: Vec<usize> = Vec::with_capacity(n);
        let mut server_pkts: Vec<usize> = Vec::with_capacity(n);
        let mut uploaded = 0.0f64;
        for i in 0..n {
            let mask = topk::topk_mask(&local.updates[i], self.k);
            let mask_f32 = mask.to_f32_mask();
            let seed = 0x11B4_0000 | (round as i64) << 8 | i as i64;
            let (q, new_residual) =
                env.backend.compress(&local.updates[i], &mask_f32, f, seed);
            if ef {
                self.residuals[i] = new_residual;
            } else {
                let _ = new_residual; // paper baselines: residual dropped
            }

            let mut hot_pairs = 0usize;
            let mut cold_pairs = 0usize;
            for dim in mask.iter_ones() {
                self.hotness[dim] = 0.9 * self.hotness[dim] + 0.1;
                if q[dim] == 0 {
                    continue;
                }
                if is_hot[dim] {
                    let slot = hot_slot_of[dim];
                    let over =
                        alu::add_i32_sat(&mut hot_acc[slot..slot + 1], &[q[dim]]);
                    if over > 0 {
                        env.switch.note_overflow(over);
                    }
                    hot_pairs += 1;
                } else {
                    *cold_acc.entry(dim).or_insert(0) += q[dim] as i64;
                    cold_pairs += 1;
                }
            }
            // Hotness decay for unselected dims happens implicitly via EMA
            // on selection; decay everything slightly once per client pass
            // would be O(d·n) — do it once per round below.
            uploaded += (hot_pairs + cold_pairs) as f64;

            // Wire: (slot/index, value) pairs, 8 B each.
            let hot_bytes = hot_pairs * 8;
            let cold_bytes = cold_pairs * 8;
            let hp = hot_bytes.div_ceil(payload).max(usize::from(hot_pairs > 0));
            let cp = cold_bytes.div_ceil(payload).max(usize::from(cold_pairs > 0));
            switch_pkts.push(hp);
            server_pkts.push(cp);
            env.charge_upload(hot_bytes + cold_bytes, hp + cp, &mut traffic, false);
        }
        // Global hotness decay (dimensions not selected cool down).
        self.hotness.iter_mut().for_each(|h| *h *= 0.95);
        uploaded /= n as f64;

        // Switch path (hot) and server path (cold) run in parallel.
        let mem = env.switch.profile().memory_bytes;
        let slots_bytes = self.hot_set.len() * 4;
        let epb = (payload / 8).max(1); // 8-byte pairs per packet
        let window = (mem / (epb * 4).max(1)).max(1);
        let hot_blocks: usize = switch_pkts.iter().sum();
        let waves = waves_needed(hot_blocks.min(self.hot_set.len().div_ceil(epb)), window);
        env.switch.note_memory_demand(slots_bytes.min(mem), slots_bytes);
        let t_switch = env.upload_phase(&local.ready, &switch_pkts, waves);
        env.charge_retransmissions(&t_switch, &mut traffic);
        let t_server = common::server_path(env, &local.ready, &server_pkts);
        let merge_end = t_switch.end.max(t_server);

        // Server merges hot aggregate + cold aggregate; broadcast union
        // as (index, value) pairs.
        let union_elems =
            hot_acc.iter().filter(|&&v| v != 0).count() + cold_acc.len();
        let t_done = env.broadcast(merge_end, union_elems * 8, &mut traffic, false);

        // Apply.
        let scale = 1.0 / (n as f32 * f);
        for (slot, &dim) in self.hot_set.iter().enumerate() {
            if hot_acc[slot] != 0 {
                env.params[dim] -= hot_acc[slot] as f32 * scale;
            }
        }
        for (&dim, &v) in &cold_acc {
            env.params[dim] -= v as f32 * scale;
        }

        env.traffic_total.add(&traffic);
        Ok(RoundReport {
            round,
            duration_s: t_done,
            train_loss: local.mean_loss,
            traffic,
            agg_ops: env.switch.stats().agg_ops - agg_ops_before,
            uploaded_elems: uploaded,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configx::{DatasetKind, Partition};
    use crate::data::synth;
    use crate::fl::NativeBackend;

    fn make_env(n: usize) -> FlEnv {
        let cfg = ExperimentConfig {
            num_clients: n,
            ..ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid)
        };
        let fd = synth::generate(cfg.dataset, cfg.partition, n, 40, cfg.seed);
        let backend = Box::new(NativeBackend::new(fd, 16, cfg.local_iters, 8, cfg.seed));
        let mut env = FlEnv::new(cfg, backend);
        env.init_model();
        env
    }

    #[test]
    fn learns_over_rounds() {
        let mut env = make_env(4);
        let mut alg = Libra::new(&env.cfg, env.d());
        let mut first = None;
        let mut last = 0.0;
        for round in 0..10 {
            let r = alg.run_round(&mut env, round).unwrap();
            if round == 0 {
                first = Some(r.train_loss);
            }
            last = r.train_loss;
        }
        assert!(last < first.unwrap());
    }

    #[test]
    fn hot_set_forms_after_first_round() {
        let mut env = make_env(4);
        let mut alg = Libra::new(&env.cfg, env.d());
        assert!(alg.hot_set.is_empty());
        alg.run_round(&mut env, 0).unwrap();
        let r1 = alg.run_round(&mut env, 1).unwrap();
        assert!(!alg.hot_set.is_empty(), "hotness EMA never formed a hot set");
        // Once hot slots exist the switch sees traffic.
        assert!(r1.agg_ops > 0, "hot path unused");
    }

    #[test]
    fn round0_is_all_cold() {
        // No hot set yet ⇒ everything goes to the server, zero PS ops.
        let mut env = make_env(4);
        let mut alg = Libra::new(&env.cfg, env.d());
        let r0 = alg.run_round(&mut env, 0).unwrap();
        assert_eq!(r0.agg_ops, 0);
        assert!(r0.traffic.up_bytes > 0);
    }

    #[test]
    fn uploads_respect_topk_budget() {
        let mut env = make_env(4);
        let mut alg = Libra::new(&env.cfg, env.d());
        let r = alg.run_round(&mut env, 0).unwrap();
        // (index,value) pairs with zero-quantised values skipped: ≤ k.
        assert!(r.uploaded_elems <= alg.k as f64 + 0.5);
    }
}
