//! FediAC: two-phase voting-based consensus compression (§IV, Algorithm 1).
//!
//! Round t:
//!  1. clients run E local SGD iterations and fold in the residual error;
//!  2. **phase 1 — client voting**: each client Gumbel-votes k = 5%·d
//!     dimensions ∝ magnitude and streams a d-bit 0-1 array to the PS,
//!     which adds the arrays into u16 counters and thresholds with `a`
//!     into the GIA, multicast back;
//!  3. **phase 2 — model aggregation**: clients quantise (Eq. 1, factor
//!     f = (2^{b−1} − N)/(N·m)), sparsify by the GIA, upload b-bit
//!     integers in GIA order (indices implicitly aligned), and the PS adds
//!     aligned payloads in i32 registers; the aggregate is multicast and
//!     applied as w_{t+1} = w_t − Σq/(N·f).
//!
//! Round 1 is server-assisted (§IV-D): clients report raw updates to a
//! plain parameter server which fits the power law, derives b from
//! Corollary 1, aggregates uncompressed, then withdraws.

use anyhow::Result;

use crate::algorithms::{common, Algorithm, RoundReport};
use crate::client::protocol;
use crate::compress::{self, rle};
use crate::configx::{AlgorithmKind, ExperimentConfig};
use crate::fl::FlEnv;
use crate::metrics::TrafficMeter;
use crate::switch::{waves_needed, RegisterFile, UpdateAggregator, VoteAggregator};
use crate::theory::{fit_power_law, min_bits, PowerLaw};
use crate::util::BitVec;

/// FediAC protocol state.
pub struct FediAc {
    /// Residual error e_t^i per client.
    residuals: Vec<Vec<f32>>,
    /// Votes per client k (resolved from k_frac at construction).
    k: usize,
    /// Quantisation bits; resolved in round 1 when the config leaves it to
    /// Corollary 1.
    bits_b: Option<usize>,
    /// Power law fitted in round 1 (kept for diagnostics / theory checks).
    pub fitted_law: Option<PowerLaw>,
    threshold_a: usize,
    rle_phase1: bool,
}

impl FediAc {
    /// Configure FediAC for model dimension `d` from the experiment knobs.
    pub fn new(cfg: &ExperimentConfig, d: usize) -> Self {
        FediAc {
            residuals: vec![vec![0.0; d]; cfg.num_clients],
            k: ((cfg.fediac.k_frac * d as f64).round() as usize).clamp(1, d),
            bits_b: cfg.fediac.bits_b,
            fitted_law: None,
            threshold_a: cfg.fediac.threshold_a,
            rle_phase1: cfg.fediac.rle_phase1,
        }
    }

    /// The quantisation bit-width in force (set by round 1's bootstrap
    /// when the config leaves it to Corollary 1).
    pub fn bits(&self) -> Option<usize> {
        self.bits_b
    }

    /// §IV-D server-assisted first iteration: raw updates to a parameter
    /// server, power-law fit, b from Corollary 1, uncompressed aggregate.
    fn bootstrap_round(&mut self, env: &mut FlEnv, round: usize) -> Result<RoundReport> {
        let lr = env.cfg.lr.at(round) as f32;
        let local = common::local_training(env, round, lr, None);
        let d = env.d();
        let n = env.cfg.num_clients;
        let mut traffic = TrafficMeter::default();

        // Fit the power law on client 0's updates (any client works — the
        // paper assumes a uniform bound across clients, Definition 1).
        let law = fit_power_law(&local.updates[0])
            .unwrap_or(PowerLaw { phi: 0.01, alpha: -0.5 });
        if self.bits_b.is_none() {
            self.bits_b = Some(min_bits(d, n, self.k, self.threshold_a, &law).max(8));
        }
        self.fitted_law = Some(law);

        // Raw f32 updates to the server, aggregated mean broadcast back.
        let bits_up = d * 32;
        let pkts: Vec<usize> = vec![env.packets_for_bits(bits_up); n];
        for _ in 0..n {
            env.charge_upload(bits_up / 8, pkts[0], &mut traffic, false);
        }
        let upload_end = common::server_path(env, &local.ready, &pkts);
        let down_end = env.broadcast(upload_end, d * 4, &mut traffic, false);

        // w₂ = w₁ − mean(U).
        let mut delta = vec![0.0f32; d];
        for u in &local.updates {
            for (acc, &v) in delta.iter_mut().zip(u) {
                *acc += v;
            }
        }
        delta.iter_mut().for_each(|v| *v /= n as f32);
        common::apply_dense_delta(&mut env.params, &delta);

        Ok(RoundReport {
            round,
            duration_s: down_end,
            train_loss: local.mean_loss,
            traffic,
            agg_ops: 0, // server round: no PS aggregation
            uploaded_elems: d as f64,
        })
    }
}

impl Algorithm for FediAc {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::FediAc
    }

    fn run_round(&mut self, env: &mut FlEnv, round: usize) -> Result<RoundReport> {
        if round == 0 {
            return self.bootstrap_round(env, round);
        }
        let bits_b = self.bits_b.expect("bootstrap sets b");
        let lr = env.cfg.lr.at(round) as f32;
        let d = env.d();
        let n = env.cfg.num_clients;
        let payload = env.cfg.packet_payload();
        let agg_ops_before = env.switch.stats().agg_ops;
        env.switch.reset_queue();
        let mut traffic = TrafficMeter::default();

        // --- local training + residual fold-in (Algorithm 1 lines 3–4).
        let local = common::local_training(env, round, lr, Some(&self.residuals));

        // --- phase 1: voting (lines 5–7).
        let votes: Vec<BitVec> = (0..n)
            .map(|i| {
                // Canonical per-(round, client) seed — the networked client
                // (`client::driver`) derives the identical stream.
                let seed = protocol::vote_seed(round, i);
                let scores = env.backend.vote_scores(&local.updates[i], seed);
                compress::vote_bitmap_from_scores(&scores, self.k)
            })
            .collect();

        // Wire size of one client's phase-1 payload (RLE optional, §IV-D).
        let vote_bytes: Vec<usize> = votes
            .iter()
            .map(|v| {
                if self.rle_phase1 {
                    rle::encoded_bytes(v).min(v.payload_bytes())
                } else {
                    v.payload_bytes()
                }
            })
            .collect();
        let vote_pkts: Vec<usize> =
            vote_bytes.iter().map(|&b| b.div_ceil(payload).max(1)).collect();
        for i in 0..n {
            env.charge_upload(vote_bytes[i], vote_pkts[i], &mut traffic, true);
        }

        // Switch-side phase-1 content: counters over all d dims.
        let epb_vote = payload * 8; // one bit per dimension
        let mem = env.switch.profile().memory_bytes;
        let window1 = (mem / (epb_vote * 2)).max(1);
        let n_blocks1 = d.div_ceil(epb_vote);
        let waves1 = waves_needed(n_blocks1, window1);
        let mut vote_file = RegisterFile::new(d * 2);
        let mut vote_agg =
            VoteAggregator::new(&mut vote_file, d, n, self.threshold_a, epb_vote)
                .expect("virtual vote registers");
        for (i, v) in votes.iter().enumerate() {
            let bytes = v.to_bytes();
            for block in 0..n_blocks1 {
                let lo = block * payload;
                let hi = ((block + 1) * payload).min(bytes.len());
                vote_agg.ingest(i, block, &bytes[lo..hi]);
            }
        }
        debug_assert!(vote_agg.all_complete());
        let gia = vote_agg.gia();
        vote_agg.release(&mut vote_file);

        let t_vote = env.upload_phase(&local.ready, &vote_pkts, waves1);
        env.charge_retransmissions(&t_vote, &mut traffic);

        // GIA multicast (d bits, or RLE when enabled).
        let gia_bytes = if self.rle_phase1 {
            rle::encoded_bytes(&gia).min(gia.payload_bytes())
        } else {
            gia.payload_bytes()
        };
        let t_gia = env.broadcast(t_vote.end, gia_bytes, &mut traffic, true);

        // --- phase 2: quantise + sparsify + aligned aggregation (8–12).
        let m = common::global_max_abs(&local.updates);
        let f = compress::scale_factor(bits_b, n, m);
        let gia_mask = gia.to_f32_mask();
        let gia_indices: Vec<usize> = gia.iter_ones().collect();
        let k_s = gia_indices.len();

        let epb_upd = (payload * 8 / bits_b).max(1);
        let n_blocks2 = k_s.div_ceil(epb_upd).max(1);
        let window2 = (mem / (epb_upd * 4)).max(1);
        let waves2 = waves_needed(if k_s == 0 { 0 } else { n_blocks2 }, window2);
        env.switch
            .note_memory_demand((d * 2).max(k_s * 4).min(mem), (d * 2).max(k_s * 4));

        let mut upd_file = RegisterFile::new((k_s * 4).max(4));
        let mut upd_agg = (k_s > 0)
            .then(|| UpdateAggregator::new(&mut upd_file, k_s, n, epb_upd).unwrap());

        let bits2 = k_s * bits_b;
        let pkts2: Vec<usize> = vec![env.packets_for_bits(bits2); n];
        let mut selected = vec![0i32; k_s];
        for i in 0..n {
            let seed = protocol::compress_seed(round, i);
            let (q, new_residual) =
                env.backend.compress(&local.updates[i], &gia_mask, f, seed);
            self.residuals[i] = new_residual;
            if let Some(agg) = upd_agg.as_mut() {
                for (slot, &gi) in gia_indices.iter().enumerate() {
                    selected[slot] = q[gi];
                }
                for block in 0..n_blocks2 {
                    let lo = block * epb_upd;
                    let hi = ((block + 1) * epb_upd).min(k_s);
                    agg.ingest(i, block, &selected[lo..hi]);
                }
            }
            env.charge_upload(bits2.div_ceil(8), pkts2[i], &mut traffic, false);
        }

        let ready2 = vec![t_gia; n];
        let t_upload2 = env.upload_phase(&ready2, &pkts2, waves2);
        env.charge_retransmissions(&t_upload2, &mut traffic);

        // Aggregate multicast: 32-bit lanes (sums reach N·2^{b−1}).
        let t_done = env.broadcast(t_upload2.end, k_s * 4, &mut traffic, false);

        // --- apply w_{t+1} = w_t − Σq/(N·f) (line 12).
        if let Some(agg) = upd_agg.take() {
            debug_assert!(agg.all_complete());
            let overflow = agg.overflow_lanes();
            if overflow > 0 {
                env.switch.note_overflow(overflow);
            }
            let delta = compress::dequantize_aggregate(agg.aggregate(), n, f);
            common::apply_sparse_delta(&mut env.params, &gia_indices, &delta);
            agg.release(&mut upd_file);
        }

        env.traffic_total.add(&traffic);
        Ok(RoundReport {
            round,
            duration_s: t_done,
            train_loss: local.mean_loss,
            traffic,
            agg_ops: env.switch.stats().agg_ops - agg_ops_before,
            uploaded_elems: k_s as f64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configx::{DatasetKind, Partition};
    use crate::data::synth;
    use crate::fl::NativeBackend;

    fn make_env(n: usize) -> FlEnv {
        let cfg = ExperimentConfig {
            num_clients: n,
            ..ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid)
        };
        let fd = synth::generate(cfg.dataset, cfg.partition, n, 40, cfg.seed);
        let backend = Box::new(NativeBackend::new(fd, 16, cfg.local_iters, 8, cfg.seed));
        let mut env = FlEnv::new(cfg, backend);
        env.init_model();
        env
    }

    #[test]
    fn bootstrap_then_compressed_rounds() {
        let mut env = make_env(4);
        let mut alg = FediAc::new(&env.cfg, env.d());
        assert!(alg.bits().is_none());
        let r0 = alg.run_round(&mut env, 0).unwrap();
        assert!(alg.bits().is_some(), "corollary-1 b not set");
        assert_eq!(r0.agg_ops, 0, "bootstrap must not touch the PS");
        let r1 = alg.run_round(&mut env, 1).unwrap();
        assert!(r1.agg_ops > 0, "phase 1+2 must aggregate on the PS");
        assert!(r1.uploaded_elems < env.d() as f64, "no compression happened");
        assert!(r1.traffic.vote_up_bytes > 0);
        assert!(r1.duration_s > 0.0);
    }

    #[test]
    fn loss_decreases_over_rounds() {
        let mut env = make_env(4);
        let mut alg = FediAc::new(&env.cfg, env.d());
        let mut first = None;
        let mut last = 0.0;
        for round in 0..8 {
            let r = alg.run_round(&mut env, round).unwrap();
            if round == 1 {
                first = Some(r.train_loss);
            }
            last = r.train_loss;
        }
        assert!(last < first.unwrap(), "no convergence: {first:?} → {last}");
    }

    #[test]
    fn phase1_traffic_is_one_bit_per_dim() {
        let mut env = make_env(4);
        let mut alg = FediAc::new(&env.cfg, env.d());
        alg.run_round(&mut env, 0).unwrap();
        let r = alg.run_round(&mut env, 1).unwrap();
        let d = env.d();
        let n = env.cfg.num_clients;
        // Upload share of phase 1: n · (ceil(d/8) + header) bytes.
        let payload = env.cfg.packet_payload();
        let pkts = d.div_ceil(8).div_ceil(payload);
        let expect = n * (d.div_ceil(8) + pkts * env.cfg.packet_header);
        assert_eq!(r.traffic.vote_up_bytes, expect as u64);
    }

    #[test]
    fn residuals_carry_masked_updates() {
        let mut env = make_env(3);
        let mut alg = FediAc::new(&env.cfg, env.d());
        alg.run_round(&mut env, 0).unwrap();
        alg.run_round(&mut env, 1).unwrap();
        // After a compressed round, at least one client has non-zero
        // residual (unvoted dimensions keep their full update).
        let any = alg.residuals.iter().any(|r| r.iter().any(|&x| x != 0.0));
        assert!(any, "residual feedback inactive");
    }

    #[test]
    fn higher_threshold_uploads_fewer_elems() {
        let run_with_a = |a: usize| {
            let mut env = make_env(6);
            env.cfg.fediac.threshold_a = a;
            let mut alg = FediAc::new(&env.cfg, env.d());
            alg.run_round(&mut env, 0).unwrap();
            alg.run_round(&mut env, 1).unwrap().uploaded_elems
        };
        let loose = run_with_a(1);
        let strict = run_with_a(5);
        assert!(strict < loose, "a=5 {strict} !< a=1 {loose}");
    }

    #[test]
    fn rle_reduces_phase1_bytes_for_sparse_votes() {
        let mut env = make_env(4);
        env.cfg.fediac.rle_phase1 = true;
        env.cfg.fediac.k_frac = 0.01; // very sparse votes
        let mut alg = FediAc::new(&env.cfg, env.d());
        alg.run_round(&mut env, 0).unwrap();
        let r_rle = alg.run_round(&mut env, 1).unwrap();

        let mut env2 = make_env(4);
        env2.cfg.fediac.k_frac = 0.01;
        let mut alg2 = FediAc::new(&env2.cfg, env2.d());
        alg2.run_round(&mut env2, 0).unwrap();
        let r_raw = alg2.run_round(&mut env2, 1).unwrap();
        assert!(
            r_rle.traffic.vote_up_bytes < r_raw.traffic.vote_up_bytes,
            "rle {} !< raw {}",
            r_rle.traffic.vote_up_bytes,
            r_raw.traffic.vote_up_bytes
        );
    }
}
