//! OmniReduce baseline [28]: non-zero-block sparse collective.
//!
//! Updates are Topk-sparsified (paper-tuned k = 5%·d, §V-A3), the d-space
//! is split into fixed blocks, and a client uploads *whole blocks* that
//! contain at least one non-zero element — "only uploads the packets with
//! non-zero elements to the PS for aggregation". Because a single
//! non-zero element drags its entire block onto the wire, the effective
//! compression rate is limited; the paper observes this makes OmniReduce
//! the weakest baseline.
//!
//! The switch aggregates blocks as they arrive (expected contributors are
//! known from each worker's next-nonzero-block pointer, so no all-N
//! scoreboard is required); missing contributions are implicit zeros.

use anyhow::Result;

use crate::algorithms::{common, Algorithm, RoundReport};
use crate::compress::{self, topk};
use crate::configx::{AlgorithmKind, ExperimentConfig};
use crate::fl::FlEnv;
use crate::metrics::TrafficMeter;
use crate::switch::{alu, waves_needed};

/// OmniReduce baseline: non-zero-block sparse aggregation (§II).
pub struct OmniReduce {
    residuals: Vec<Vec<f32>>,
    k: usize,
    block_elems: usize,
    bits: usize,
}

impl OmniReduce {
    /// Configure OmniReduce for model dimension `d`.
    pub fn new(cfg: &ExperimentConfig, d: usize) -> Self {
        OmniReduce {
            residuals: vec![vec![0.0; d]; cfg.num_clients],
            k: ((cfg.baselines.omni_k_frac * d as f64).round() as usize).clamp(1, d),
            block_elems: cfg.baselines.omni_block_elems,
            // Block payloads are 32-bit integer lanes (dense within the
            // block; the switch adds full blocks).
            bits: 32,
        }
    }
}

impl Algorithm for OmniReduce {
    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::OmniReduce
    }

    fn run_round(&mut self, env: &mut FlEnv, round: usize) -> Result<RoundReport> {
        let lr = env.cfg.lr.at(round) as f32;
        let d = env.d();
        let n = env.cfg.num_clients;
        let payload = env.cfg.packet_payload();
        let agg_ops_before = env.switch.stats().agg_ops;
        env.switch.reset_queue();
        let mut traffic = TrafficMeter::default();

        let ef = env.cfg.baselines.error_feedback;
        let local = common::local_training(
            env,
            round,
            lr,
            ef.then_some(self.residuals.as_slice()),
        );
        let m = common::global_max_abs(&local.updates);
        // 16-bit quantisation within 32-bit lanes leaves headroom for the
        // N-client sum (OmniReduce's switch aggregates full-width ints).
        let f = compress::scale_factor(16, n, m);

        let n_blocks = d.div_ceil(self.block_elems);
        let block_bytes = self.block_elems * (self.bits / 8);
        // Block wire size: payload + 4-byte block id.
        let pkts_per_block = (block_bytes + 4).div_ceil(payload).max(1);

        // Aggregate (host mirror of the switch's per-block adds).
        let mut acc = vec![0i32; d];
        let mut union_blocks = vec![false; n_blocks];
        let mut pkts: Vec<usize> = Vec::with_capacity(n);
        let mut selected_mean = 0.0f64;
        for i in 0..n {
            let mask = topk::topk_mask(&local.updates[i], self.k);
            let mask_f32 = mask.to_f32_mask();
            let seed = 0x0914_0000 | (round as i64) << 8 | i as i64;
            let (q, new_residual) =
                env.backend.compress(&local.updates[i], &mask_f32, f, seed);
            if ef {
                self.residuals[i] = new_residual;
            } else {
                let _ = new_residual; // paper baselines: residual dropped
            }

            // Which blocks does this client touch?
            let mut my_blocks = 0usize;
            let mut sent_elems = 0usize;
            for b in 0..n_blocks {
                let lo = b * self.block_elems;
                let hi = ((b + 1) * self.block_elems).min(d);
                if q[lo..hi].iter().any(|&v| v != 0) {
                    my_blocks += 1;
                    union_blocks[b] = true;
                    sent_elems += hi - lo;
                    let over = alu::add_i32_sat(&mut acc[lo..hi], &q[lo..hi]);
                    if over > 0 {
                        env.switch.note_overflow(over);
                    }
                }
            }
            selected_mean += sent_elems as f64;
            let client_pkts = my_blocks * pkts_per_block;
            pkts.push(client_pkts);
            env.charge_upload(my_blocks * (block_bytes + 4), client_pkts, &mut traffic, false);
        }
        selected_mean /= n as f64;

        // Memory: registers for blocks in flight; waves when the union of
        // live blocks exceeds the register file.
        let mem = env.switch.profile().memory_bytes;
        let union_count = union_blocks.iter().filter(|&&b| b).count();
        let window = (mem / block_bytes.max(1)).max(1);
        let waves = waves_needed(union_count, window);
        env.switch
            .note_memory_demand((union_count * block_bytes).min(mem), union_count * block_bytes);

        let t_up = env.upload_phase(&local.ready, &pkts, waves);
        env.charge_retransmissions(&t_up, &mut traffic);

        // Broadcast the union blocks (block id + dense 32-bit lanes).
        let down_bytes = union_count * (block_bytes + 4);
        let t_done = env.broadcast(t_up.end, down_bytes, &mut traffic, false);

        let delta = compress::dequantize_aggregate(&acc, n, f);
        common::apply_dense_delta(&mut env.params, &delta);

        env.traffic_total.add(&traffic);
        Ok(RoundReport {
            round,
            duration_s: t_done,
            train_loss: local.mean_loss,
            traffic,
            agg_ops: env.switch.stats().agg_ops - agg_ops_before,
            uploaded_elems: selected_mean,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configx::{DatasetKind, Partition};
    use crate::data::synth;
    use crate::fl::NativeBackend;

    fn make_env(n: usize) -> FlEnv {
        let cfg = ExperimentConfig {
            num_clients: n,
            ..ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid)
        };
        let fd = synth::generate(cfg.dataset, cfg.partition, n, 40, cfg.seed);
        let backend = Box::new(NativeBackend::new(fd, 16, cfg.local_iters, 8, cfg.seed));
        let mut env = FlEnv::new(cfg, backend);
        env.init_model();
        env
    }

    #[test]
    fn learns_and_uploads_whole_blocks() {
        let mut env = make_env(4);
        let mut alg = OmniReduce::new(&env.cfg, env.d());
        let mut first = None;
        let mut last = 0.0;
        for round in 0..8 {
            let r = alg.run_round(&mut env, round).unwrap();
            // Block granularity: uploaded elems ≥ the Topk k.
            assert!(r.uploaded_elems >= alg.k as f64);
            if round == 0 {
                first = Some(r.train_loss);
            }
            last = r.train_loss;
        }
        assert!(last < first.unwrap());
    }

    #[test]
    fn block_amplification_vs_pure_topk() {
        // With scattered top-k, whole-block upload sends far more than k
        // elements — the design weakness the paper calls out.
        let mut env = make_env(4);
        let mut alg = OmniReduce::new(&env.cfg, env.d());
        let r = alg.run_round(&mut env, 0).unwrap();
        assert!(
            r.uploaded_elems > 1.5 * alg.k as f64,
            "uploaded {} vs k {}",
            r.uploaded_elems,
            alg.k
        );
    }

    #[test]
    fn smaller_blocks_less_amplification() {
        let mut e1 = make_env(4);
        e1.cfg.baselines.omni_block_elems = 512;
        let mut a1 = OmniReduce::new(&e1.cfg, e1.d());
        let big = a1.run_round(&mut e1, 0).unwrap().uploaded_elems;
        let mut e2 = make_env(4);
        e2.cfg.baselines.omni_block_elems = 32;
        let mut a2 = OmniReduce::new(&e2.cfg, e2.d());
        let small = a2.run_round(&mut e2, 0).unwrap().uploaded_elems;
        assert!(small < big, "blocks 32 {small} !< 512 {big}");
    }
}
