//! `fediac soak`: seeded randomized preset×chaos×backend episodes.
//!
//! Each episode samples a deployment preset (`configx::preset`), an I/O
//! backend and a chaos coin from a single 64-bit episode seed, stands
//! the deployment up on loopback, drives the preset's client mix
//! through real wire rounds, and asserts the invariants the rest of the
//! suite proves one at a time:
//!
//! * **bit-exactness** — every client's GIA and aggregate equal the
//!   pure `algorithms::fediac`-style reference recomputation;
//! * **budget hygiene** — the shared [`HostBudget`] returns to zero for
//!   every job once the daemons shut down;
//! * **no wedged rounds** — clean episodes complete with zero
//!   `idle_releases` (no round sat past its idle-reclaim deadline);
//! * **pool steady state** — clean driver episodes add zero
//!   `pool_misses` after the warm-up round;
//! * and the episode's flight-recorder ring is dumped to
//!   `SOAK_FAIL_ep<N>.trace.jsonl` on any failure.
//!
//! Presets that declare a churn plane (`[churn]` + `mix.quorum`) also
//! schedule **churn episodes**: the fleet runs on the swarm multiplexer
//! under the `net::churn` lifecycle injector in two legs. Leg A (clean
//! wire, permanent kills only) proves quorum rounds stay **bit-exact
//! for the surviving quorum** against a quorum-aware reference over the
//! guaranteed voter/updater sets, close at the phase deadline instead
//! of stalling (zero `idle_releases`), and drain the [`HostBudget`] to
//! zero even though the dead clients never say goodbye. Leg B replays
//! the preset's full fault plane — chaos, kills, stale rejoins, a flash
//! crowd — and asserts liveness: every eventually-active client
//! finishes all rounds and the lifecycle ledger matches the plan.
//!
//! Every episode appends one JSON line to the `SOAK.json` ledger whose
//! `replay` field is a complete `fediac soak --episode-seed …` command:
//! the whole episode — preset pick, backend, chaos coin, client mix,
//! chaos lanes — derives from the seed alone, so a failure reproduces
//! from its ledger line. Episode scheduling stratifies seeds so a
//! 4-episode smoke covers all three backends {threaded, reactor,
//! fleet} plus {clean, chaos} × {1, N shards}.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Context, Result};

use crate::client::swarm::{self, SwarmJobPlan, SwarmOptions, UpdateSource};
use crate::client::{protocol, ClientOptions, FediacClient, ShardedFediacClient};
use crate::compress::{self, deduce_gia};
use crate::configx::{load_preset, DeployPreset, BUILTIN_PRESETS};
use crate::net::{ChaosConfig, ChaosDirection, ChurnConfig, ChurnPlan};
use crate::server::{
    serve, serve_sharded, HostBudget, IoBackend, ServeOptions, ServerHandle, StatsSnapshot,
};
use crate::telemetry::{FlightRecorder, DEFAULT_EVENTS};
use crate::util::{BitVec, Rng};

/// What `fediac soak` runs.
#[derive(Debug, Clone)]
pub struct SoakOptions {
    /// Episodes to run (0 = until the duration budget runs out).
    pub episodes: usize,
    /// Wall-clock budget in seconds; no new episode starts past it
    /// (0 = no time budget).
    pub duration_s: f64,
    /// Root seed for episode scheduling.
    pub seed: u64,
    /// Replay exactly one episode from its ledger seed instead of
    /// scheduling from the root seed.
    pub episode_seed: Option<u64>,
    /// Preset names (or TOML paths) to sample episodes from.
    pub presets: Vec<String>,
    /// Ledger path, one JSON line per episode.
    pub out: String,
}

impl Default for SoakOptions {
    fn default() -> Self {
        SoakOptions {
            episodes: 8,
            duration_s: 300.0,
            seed: 7,
            episode_seed: None,
            presets: BUILTIN_PRESETS.iter().map(|s| s.to_string()).collect(),
            out: "SOAK.json".to_string(),
        }
    }
}

/// A fully sampled episode: everything below derives from `seed` (plus
/// the preset list), so a ledger line's seed replays the episode.
#[derive(Debug, Clone)]
pub struct EpisodePlan {
    /// The episode seed every draw below came from.
    pub seed: u64,
    /// The `--presets` argument that was picked (name or path).
    pub preset_arg: String,
    /// The loaded preset.
    pub preset: DeployPreset,
    /// Daemon I/O backend for this episode (a soak axis — it overrides
    /// the preset's `deploy.io`).
    pub backend: IoBackend,
    /// Whether the preset's chaos knobs are applied this episode.
    pub chaos: bool,
    /// Host the fleet on the swarm multiplexer instead of one thread
    /// per client (preset `mix.swarm`, single-shard deployments only).
    pub swarm: bool,
    /// Run the client-churn fault plane this episode (presets with a
    /// `[churn]` section, single-shard deployments only). Churn
    /// episodes host the fleet on the swarm multiplexer regardless of
    /// `mix.swarm` and stamp the preset's `mix.quorum` on every job.
    pub churn: bool,
    /// Shard daemons (from the preset).
    pub shards: u8,
    /// Concurrent jobs (driver mode).
    pub jobs: usize,
    /// Clients per job.
    pub clients: u16,
    /// Model dimension (preset `mix.d`, possibly halved by the seed).
    pub d: usize,
    /// Rounds per client.
    pub rounds: usize,
    /// Payload budget in bytes.
    pub payload: usize,
    /// Consensus threshold a (clamped to the client count).
    pub threshold_a: u16,
    /// Votes per client k.
    pub k: usize,
}

impl EpisodePlan {
    /// `driver` (one thread per client), `swarm` (one thread total) or
    /// `churn` (swarm-hosted, quorum rounds under the lifecycle
    /// injector).
    pub fn mode(&self) -> &'static str {
        if self.churn {
            "churn"
        } else if self.swarm {
            "swarm"
        } else {
            "driver"
        }
    }

    /// The complete replay command for this episode.
    pub fn replay_command(&self) -> String {
        format!(
            "fediac soak --episodes 1 --episode-seed {} --presets {}",
            self.seed, self.preset_arg
        )
    }
}

/// Counters an episode leaves behind for its ledger line.
#[derive(Debug, Clone, Copy, Default)]
pub struct EpisodeCounters {
    /// Server stats merged across shards at episode end.
    pub server: StatsSnapshot,
    /// Client-side retransmissions summed over the fleet.
    pub client_retx: u64,
    /// Client-rounds completed (clients × rounds).
    pub client_rounds: u64,
    /// `pool_misses` right after the warm-up round (driver mode; clean
    /// episodes assert the final count equals this).
    pub warm_pool_misses: u64,
}

/// One ledger entry (one line of SOAK.json).
#[derive(Debug, Clone)]
pub struct EpisodeRecord {
    /// Episode index within the soak run.
    pub episode: usize,
    /// The sampled plan.
    pub plan: EpisodePlan,
    /// Episode wall time in seconds.
    pub wall_s: f64,
    /// End-of-episode counters (zeroed when the episode failed early).
    pub counters: EpisodeCounters,
    /// Whether every invariant held.
    pub ok: bool,
    /// The failing invariant, when `ok` is false.
    pub failure: Option<String>,
}

/// What a completed soak run did.
#[derive(Debug, Clone, Copy)]
pub struct SoakReport {
    /// Episodes that ran and passed.
    pub episodes: usize,
    /// Wall time of the whole run in seconds.
    pub wall_s: f64,
}

fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Sample the episode fully determined by `seed`. Every draw goes
/// through [`Rng::fork`], whose parent advance is independent of what
/// the child stream is used for — so replaying with `--presets` narrowed
/// to the one picked preset reproduces the same backend, chaos coin and
/// mix draws.
pub fn sample_episode(seed: u64, presets: &[String]) -> Result<EpisodePlan> {
    ensure!(!presets.is_empty(), "soak needs at least one preset");
    let mut root = Rng::new(seed);
    let pick = root.fork(1).below(presets.len());
    let preset_arg = presets[pick].clone();
    let preset = load_preset(&preset_arg).map_err(|e| anyhow!("preset '{preset_arg}': {e}"))?;
    let backend = match root.fork(2).below(3) {
        0 => IoBackend::Threaded,
        1 => IoBackend::Reactor,
        _ => IoBackend::Fleet,
    };
    // 3-in-4 chaos when the preset has knobs to apply; a clean preset
    // always runs clean.
    let chaos = !preset.is_clean() && root.fork(3).below(4) > 0;
    let mut mix_rng = root.fork(4);
    // Halve d on a coin flip for workload variety, but never below the
    // point where a shard would own zero vote blocks.
    let mut d = preset.mix.d;
    if mix_rng.below(2) == 1 {
        let half = (d / 2).max(512);
        if half.div_ceil(8 * preset.mix.payload) >= preset.shards as usize {
            d = half;
        }
    }
    let k = protocol::votes_per_client(d, preset.mix.k_frac).max(1);
    // Presets with a churn plane split their episodes 50/50 between the
    // legacy all-N driver path (quorum=0, bit-identical wire) and the
    // quorum + churn fault plane — both halves stay covered.
    let churn = !preset.churn.is_quiet()
        && preset.shards == 1
        && root.fork(5).below(2) == 1;
    let plan = EpisodePlan {
        seed,
        preset_arg,
        backend,
        chaos,
        swarm: preset.mix.swarm && preset.shards == 1,
        churn,
        shards: preset.shards,
        jobs: preset.mix.jobs,
        clients: preset.mix.clients_per_job,
        d,
        rounds: preset.mix.rounds,
        payload: preset.mix.payload,
        threshold_a: preset.mix.threshold_a.min(preset.mix.clients_per_job),
        k,
        preset,
    };
    Ok(plan)
}

/// Episode seed for slot `idx` of a soak run: a deterministic salt
/// search over `mix64` candidates until the sampled episode lands in
/// the stratum slot `idx` targets — preset `idx % presets`, backend
/// rotating through {threaded, reactor, fleet}, chaos on a
/// `[clean, chaos, chaos, clean]` cycle. Four episodes over the builtin
/// presets therefore cover every backend plus {clean, chaos} ×
/// {1, N shards}, while each returned seed alone still replays its
/// episode.
pub fn schedule_seed(root: u64, idx: usize, presets: &[String]) -> Result<u64> {
    ensure!(!presets.is_empty(), "soak needs at least one preset");
    let target_preset = &presets[idx % presets.len()];
    let want_backend =
        [IoBackend::Threaded, IoBackend::Reactor, IoBackend::Fleet][idx % 3];
    let want_chaos = matches!(idx % 4, 1 | 2);
    // Presets with a churn plane alternate churn and legacy episodes
    // across schedule slots, so a smoke that reaches such a preset once
    // deterministically runs its fault plane.
    let want_churn = idx % 2 == 0;
    let base = root ^ (idx as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    for salt in 0..4096u64 {
        let seed = mix64(base ^ salt.wrapping_mul(0xD1B5_4A32_D192_ED03));
        let plan = sample_episode(seed, presets)?;
        let chaos_ok = if plan.preset.is_clean() {
            !plan.chaos
        } else {
            plan.chaos == want_chaos
        };
        let churn_ok = if plan.preset.churn.is_quiet() || plan.shards > 1 {
            !plan.churn
        } else {
            plan.churn == want_churn
        };
        if plan.preset_arg == *target_preset
            && plan.backend == want_backend
            && chaos_ok
            && churn_ok
        {
            return Ok(seed);
        }
    }
    // ~(1 - 1/32)^4096 ≈ 1e-56; unreachable in practice, but a soak
    // must degrade to "less stratified", never die on scheduling.
    Ok(mix64(base))
}

/// One reference round recomputed from first principles (the pure
/// oracle `tests/wire_backend.rs` proves the wire path against):
/// votes → GIA deduction → shared scale → stochastic quantisation →
/// lane sums at the GIA indices. Returns the per-client residuals so
/// driver-mode oracles can fold them into the next round's updates.
#[allow(clippy::type_complexity)]
fn reference_round(
    updates: &[Vec<f32>],
    job_seed: u64,
    round: usize,
    k: usize,
    a: usize,
    bits_b: usize,
) -> (Vec<usize>, Vec<i32>, Vec<Vec<f32>>) {
    let votes: Vec<BitVec> = updates
        .iter()
        .enumerate()
        .map(|(c, u)| protocol::client_vote(u, k, job_seed, round, c))
        .collect();
    let gia = deduce_gia(&votes, a);
    let indices: Vec<usize> = gia.iter_ones().collect();
    let m = updates.iter().map(|u| compress::max_abs(u)).fold(f32::MIN_POSITIVE, f32::max);
    let f = compress::scale_factor(bits_b, updates.len(), m);
    let mask = gia.to_f32_mask();
    let mut lanes = vec![0i32; indices.len()];
    let mut residuals = Vec::with_capacity(updates.len());
    for (c, u) in updates.iter().enumerate() {
        let (q, residual) = protocol::client_quantize(u, &mask, f, job_seed, round, c);
        for (slot, &g) in indices.iter().enumerate() {
            lanes[slot] += q[g];
        }
        residuals.push(residual);
    }
    (indices, lanes, residuals)
}

/// The synthetic update stream every episode drives — byte-identical to
/// `fediac bench-wire` / `fediac client`: round r of client c draws
/// Gaussians from `Rng::new(job_seed ^ (c << 32) ^ r)` scaled by 0.01.
fn synthetic_update(job_seed: u64, cid: usize, round: usize, d: usize) -> Vec<f32> {
    let mut rng = Rng::new(job_seed ^ ((cid as u64) << 32) ^ round as u64);
    (0..d).map(|_| (rng.gaussian() * 0.01) as f32).collect()
}

fn job_id(job_idx: usize) -> u32 {
    1000 + job_idx as u32
}

fn job_seed(plan_seed: u64, job_idx: usize) -> u64 {
    plan_seed ^ ((job_idx as u64) << 16)
}

fn merged_stats(handles: &[ServerHandle]) -> StatsSnapshot {
    let mut merged = StatsSnapshot::default();
    for h in handles {
        merged.merge(&h.stats());
    }
    merged
}

/// Either client transport, as in `fediac client`.
enum EpisodeClient {
    Single(FediacClient),
    Sharded(ShardedFediacClient),
}

/// Drive one client through rounds `lo..=hi`, folding `residual` in as
/// Algorithm 1 requires. Returns the per-round (GIA indices, aggregate)
/// pairs, the final residual (for the next pass) and the client's
/// retransmission count.
#[allow(clippy::type_complexity)]
fn drive_client(
    plan: &EpisodePlan,
    addrs: &[String],
    job_idx: usize,
    cid: u16,
    lo: usize,
    hi: usize,
    mut residual: Vec<f32>,
) -> Result<(Vec<(Vec<usize>, Vec<i32>)>, Vec<f32>, u64)> {
    let preset = &plan.preset;
    let seed = job_seed(plan.seed, job_idx);
    let mut copts =
        ClientOptions::new(addrs[0].clone(), job_id(job_idx), cid, plan.d, plan.clients);
    copts.threshold_a = plan.threshold_a;
    copts.k = plan.k;
    copts.bits_b = preset.mix.bits_b;
    copts.payload_budget = plan.payload;
    copts.backend_seed = seed;
    copts.timeout = Duration::from_millis(preset.mix.timeout_ms);
    copts.max_retries = preset.mix.max_retries;
    if plan.chaos && !preset.up.is_clean() {
        // Uplink chaos lives client-side (an in-process proxy lane);
        // downlink chaos lives in the daemon, so leave it clean here.
        copts.chaos = Some(ChaosConfig {
            seed: plan.seed ^ ((job_idx as u64) << 8) ^ (cid as u64) ^ 0x50AC,
            uplink: preset.up.direction(),
            downlink: ChaosDirection::default(),
        });
    }
    let mut client = if addrs.len() > 1 {
        EpisodeClient::Sharded(ShardedFediacClient::connect(addrs, copts)?)
    } else {
        EpisodeClient::Single(FediacClient::connect(copts)?)
    };
    let mut got = Vec::with_capacity(hi + 1 - lo);
    for round in lo..=hi {
        let mut update = synthetic_update(seed, cid as usize, round, plan.d);
        for (u, r) in update.iter_mut().zip(&residual) {
            *u += *r;
        }
        let out = match &mut client {
            EpisodeClient::Single(c) => c.run_round(round, &update)?,
            EpisodeClient::Sharded(c) => c.run_round(round, &update)?,
        };
        residual = out.residual;
        got.push((out.gia_indices, out.aggregate));
    }
    let retx = match &client {
        EpisodeClient::Single(c) => c.stats.retransmissions,
        EpisodeClient::Sharded(c) => c.stats().retransmissions,
    };
    Ok((got, residual, retx))
}

/// Run rounds `lo..=hi` for the whole fleet, one thread per client
/// (fresh connections each pass — pass 2 exercises inline re-join).
#[allow(clippy::type_complexity)]
fn run_pass(
    plan: &EpisodePlan,
    addrs: &[String],
    lo: usize,
    hi: usize,
    residuals: &mut [Vec<Vec<f32>>],
    outcomes: &mut [Vec<Vec<(Vec<usize>, Vec<i32>)>>],
) -> Result<u64> {
    let clients = plan.clients as usize;
    let results: Vec<Vec<Result<(Vec<(Vec<usize>, Vec<i32>)>, Vec<f32>, u64)>>> =
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(plan.jobs);
            for (j, job_residuals) in residuals.iter().enumerate().take(plan.jobs) {
                let mut row = Vec::with_capacity(clients);
                for (c, residual) in job_residuals.iter().enumerate().take(clients) {
                    let residual = residual.clone();
                    row.push(s.spawn(move || {
                        drive_client(plan, addrs, j, c as u16, lo, hi, residual)
                    }));
                }
                handles.push(row);
            }
            handles
                .into_iter()
                .map(|row| {
                    row.into_iter()
                        .map(|h| {
                            h.join().unwrap_or_else(|_| {
                                Err(anyhow!("client thread panicked"))
                            })
                        })
                        .collect()
                })
                .collect()
        });
    let mut retx = 0u64;
    for (j, row) in results.into_iter().enumerate() {
        for (c, res) in row.into_iter().enumerate() {
            let (got, residual, r) =
                res.with_context(|| format!("job {j} client {c} rounds {lo}..={hi}"))?;
            outcomes[j][c].extend(got);
            residuals[j][c] = residual;
            retx += r;
        }
    }
    Ok(retx)
}

/// Stand the deployment up and run a driver-mode episode (one blocking
/// client per thread, as `fediac client` does), then check every
/// invariant. See the module docs for the invariant list.
fn run_driver_episode(plan: &EpisodePlan, recorder: &Arc<FlightRecorder>) -> Result<EpisodeCounters> {
    let preset = &plan.preset;
    let limits = preset.limits.limits();
    let budget = Arc::new(HostBudget::new(limits.host_bytes));
    let base = ServeOptions {
        bind: "127.0.0.1:0".to_string(),
        profile: preset.ps_profile(),
        limits,
        downlink_chaos: (plan.chaos && !preset.down.is_clean())
            .then(|| preset.down.direction()),
        chaos_seed: plan.seed,
        io_backend: plan.backend,
        // Auto-size fleet episodes to the host; single-socket backends
        // ignore this. The injected budget Arc below is shared by every
        // fleet core (and every shard), which the post-shutdown
        // zero-reservation invariant exercises.
        cores: 0,
        host_budget: Some(Arc::clone(&budget)),
        trace: Some(Arc::clone(recorder)),
    };
    let handles = if plan.shards > 1 {
        serve_sharded(&base, plan.shards)?
    } else {
        vec![serve(&base)?]
    };
    let addrs: Vec<String> = handles.iter().map(|h| h.local_addr().to_string()).collect();

    let clients = plan.clients as usize;
    let mut residuals: Vec<Vec<Vec<f32>>> =
        vec![vec![vec![0.0f32; plan.d]; clients]; plan.jobs];
    let mut outcomes: Vec<Vec<Vec<(Vec<usize>, Vec<i32>)>>> =
        vec![vec![Vec::new(); clients]; plan.jobs];

    // Pass 1 (round 1) warms the frame pools; pass 2 re-joins fresh
    // client sessions and must not allocate a single new pool frame on
    // a clean network.
    let mut client_retx = run_pass(plan, &addrs, 1, 1, &mut residuals, &mut outcomes)?;
    let warm = merged_stats(&handles);
    if plan.rounds > 1 {
        client_retx +=
            run_pass(plan, &addrs, 2, plan.rounds, &mut residuals, &mut outcomes)?;
    }
    let server = merged_stats(&handles);
    for h in handles {
        h.shutdown();
    }

    // Invariant: the shared HostBudget returns to zero per job once the
    // daemons (and so every Job) are gone.
    for j in 0..plan.jobs {
        let held = budget.reserved(job_id(j));
        ensure!(
            held == 0,
            "HostBudget leak: job {} still holds {held} bytes after shutdown",
            job_id(j)
        );
    }

    // Invariant: bit-exactness vs the pure reference recomputation,
    // with residuals evolving exactly as Algorithm 1 prescribes.
    for j in 0..plan.jobs {
        let seed = job_seed(plan.seed, j);
        let mut oracle_residuals = vec![vec![0.0f32; plan.d]; clients];
        for round in 1..=plan.rounds {
            let updates: Vec<Vec<f32>> = (0..clients)
                .map(|c| {
                    let mut u = synthetic_update(seed, c, round, plan.d);
                    for (x, r) in u.iter_mut().zip(&oracle_residuals[c]) {
                        *x += *r;
                    }
                    u
                })
                .collect();
            let (exp_idx, exp_lanes, next_residuals) = reference_round(
                &updates,
                seed,
                round,
                plan.k,
                plan.threshold_a as usize,
                preset.mix.bits_b,
            );
            oracle_residuals = next_residuals;
            for c in 0..clients {
                let (got_idx, got_lanes) = &outcomes[j][c][round - 1];
                ensure!(
                    *got_idx == exp_idx,
                    "job {j} client {c} round {round}: GIA diverged from reference \
                     ({} vs {} indices)",
                    got_idx.len(),
                    exp_idx.len()
                );
                ensure!(
                    *got_lanes == exp_lanes,
                    "job {j} client {c} round {round}: aggregate diverged from reference"
                );
            }
        }
    }

    check_server_invariants(plan, &server, Some(warm.pool_misses))?;
    Ok(EpisodeCounters {
        server,
        client_retx,
        client_rounds: (plan.jobs * clients * plan.rounds) as u64,
        warm_pool_misses: warm.pool_misses,
    })
}

/// Stand the deployment up and run a swarm-mode episode: the whole
/// fleet multiplexed on one thread with explicit per-round update
/// streams, outcomes collected for the reference comparison.
fn run_swarm_episode(plan: &EpisodePlan, recorder: &Arc<FlightRecorder>) -> Result<EpisodeCounters> {
    let preset = &plan.preset;
    let limits = preset.limits.limits();
    let budget = Arc::new(HostBudget::new(limits.host_bytes));
    let base = ServeOptions {
        bind: "127.0.0.1:0".to_string(),
        profile: preset.ps_profile(),
        limits,
        downlink_chaos: (plan.chaos && !preset.down.is_clean())
            .then(|| preset.down.direction()),
        chaos_seed: plan.seed,
        io_backend: plan.backend,
        cores: 0,
        host_budget: Some(Arc::clone(&budget)),
        trace: Some(Arc::clone(recorder)),
    };
    let handle = serve(&base)?;

    // Carve the fleet into jobs with explicit update streams, so the
    // reference recomputation sees exactly what each client uploaded.
    let per = plan.clients as usize;
    let mut job_plans = Vec::new();
    let mut remaining = preset.mix.swarm_clients;
    let mut j = 0usize;
    let mut min_n = per;
    while remaining > 0 {
        let n = remaining.min(per);
        min_n = min_n.min(n);
        let seed = job_seed(plan.seed, j);
        let updates: Vec<Vec<Vec<f32>>> = (1..=plan.rounds)
            .map(|round| {
                (0..n).map(|c| synthetic_update(seed, c, round, plan.d)).collect()
            })
            .collect();
        job_plans.push(SwarmJobPlan {
            job: job_id(j),
            n_clients: n as u16,
            backend_seed: seed,
            updates: UpdateSource::Explicit(updates),
        });
        remaining -= n;
        j += 1;
    }
    let n_jobs = job_plans.len();
    let threshold_a = plan.threshold_a.min(min_n as u16).max(1);

    let mut sopts = SwarmOptions::new(handle.local_addr().to_string(), plan.d);
    sopts.jobs = job_plans.clone();
    sopts.threshold_a = threshold_a;
    sopts.k = plan.k;
    sopts.bits_b = preset.mix.bits_b;
    sopts.payload_budget = plan.payload;
    sopts.rounds = plan.rounds;
    sopts.sockets = preset.mix.swarm_sockets;
    sopts.timeout = Duration::from_millis(preset.mix.timeout_ms);
    sopts.max_retries = preset.mix.max_retries;
    sopts.uplink_chaos =
        (plan.chaos && !preset.up.is_clean()).then(|| preset.up.direction());
    sopts.chaos_seed = plan.seed;
    sopts.collect_outcomes = true;

    let report = swarm::run(&sopts)?;
    let server = handle.stats();
    handle.shutdown();

    for jp in &job_plans {
        let held = budget.reserved(jp.job);
        ensure!(
            held == 0,
            "HostBudget leak: job {} still holds {held} bytes after shutdown",
            jp.job
        );
    }

    let outcomes = report
        .outcomes
        .as_ref()
        .ok_or_else(|| anyhow!("swarm run did not collect outcomes"))?;
    ensure!(outcomes.len() == n_jobs, "swarm outcomes lost a job");
    for (ji, jp) in job_plans.iter().enumerate() {
        let UpdateSource::Explicit(rounds_updates) = &jp.updates else {
            unreachable!("soak builds explicit streams only");
        };
        for round in 1..=plan.rounds {
            let updates = &rounds_updates[round - 1];
            let (exp_idx, exp_lanes, _) = reference_round(
                updates,
                jp.backend_seed,
                round,
                plan.k,
                threshold_a as usize,
                preset.mix.bits_b,
            );
            for c in 0..updates.len() {
                let out = &outcomes[ji][c][round - 1];
                ensure!(
                    out.gia_indices == exp_idx,
                    "swarm job {} client {c} round {round}: GIA diverged from reference",
                    jp.job
                );
                ensure!(
                    out.aggregate == exp_lanes,
                    "swarm job {} client {c} round {round}: aggregate diverged from reference",
                    jp.job
                );
            }
        }
    }

    let client_rounds = (preset.mix.swarm_clients * plan.rounds) as u64;
    ensure!(
        report.rounds_completed == client_rounds,
        "swarm completed {} client-rounds, expected {client_rounds}",
        report.rounds_completed
    );
    // The swarm drives one continuous session, so there is no warm-up
    // boundary to assert the pool against; record the final count.
    check_server_invariants_for(plan, &server, None, n_jobs)?;
    Ok(EpisodeCounters {
        server,
        client_retx: report.stats.retransmissions,
        client_rounds,
        warm_pool_misses: server.pool_misses,
    })
}

/// The quorum-aware variant of [`reference_round`]: GIA deduction and
/// the shared scale fold over the guaranteed **voter** set (votes carry
/// `local_max`, and after-vote kill victims still voted), lane sums
/// fold over the guaranteed **updater** set. `n_clients` stays the
/// job's spec N — the scale formula uses the advertised fleet size, not
/// the survivor count, on both ends of the wire.
#[allow(clippy::too_many_arguments)]
fn reference_round_quorum(
    updates: &[Vec<f32>],
    voters: &[usize],
    updaters: &[usize],
    n_clients: usize,
    job_seed: u64,
    round: usize,
    k: usize,
    a: usize,
    bits_b: usize,
) -> (Vec<usize>, Vec<i32>) {
    let votes: Vec<BitVec> = voters
        .iter()
        .map(|&c| protocol::client_vote(&updates[c], k, job_seed, round, c))
        .collect();
    let gia = deduce_gia(&votes, a);
    let indices: Vec<usize> = gia.iter_ones().collect();
    let m = voters
        .iter()
        .map(|&c| compress::max_abs(&updates[c]))
        .fold(f32::MIN_POSITIVE, f32::max);
    let f = compress::scale_factor(bits_b, n_clients, m);
    let mask = gia.to_f32_mask();
    let mut lanes = vec![0i32; indices.len()];
    for &c in updaters {
        let (q, _) = protocol::client_quantize(&updates[c], &mask, f, job_seed, round, c);
        for (slot, &g) in indices.iter().enumerate() {
            lanes[slot] += q[g];
        }
    }
    (indices, lanes)
}

/// Tightest safe quorum for a fleet-wide churn plan carved into `jobs`
/// jobs of `per` clients: the minimum, over jobs and rounds, of the
/// full-participant count — stamping a larger Q on some job would let a
/// phase wait on a client the plan kills.
fn job_quorum_floor(cplan: &ChurnPlan, jobs: usize, per: usize, rounds: usize) -> u16 {
    let mut floor = per as u16;
    for j in 0..jobs {
        for round in 1..=rounds as u32 {
            let full = (0..per)
                .filter(|&c| cplan.client((j * per + c) as u16).full_participant(round))
                .count() as u16;
            floor = floor.min(full);
        }
    }
    floor
}

/// Minimum, over jobs, of the eventually-active client count (everyone
/// the plan does not kill permanently — survivors, rejoiners and the
/// flash crowd all finish their rounds eventually).
fn job_survivor_floor(cplan: &ChurnPlan, jobs: usize, per: usize) -> u16 {
    (0..jobs)
        .map(|j| {
            (0..per)
                .filter(|&c| !cplan.client((j * per + c) as u16).permanent_death())
                .count() as u16
        })
        .min()
        .unwrap_or(0)
}

/// Stand one deployment up and run one churn leg of the episode: the
/// driver-shaped fleet (`jobs × clients_per_job`) hosted on the swarm
/// multiplexer with quorum `quorum` stamped on every job and the
/// lifecycle injector seeded with `churn_seed`. Asserts the HostBudget
/// drains to zero despite dead clients never saying goodbye.
#[allow(clippy::too_many_arguments)]
fn run_churn_leg(
    plan: &EpisodePlan,
    recorder: &Arc<FlightRecorder>,
    label: &str,
    churn_cfg: ChurnConfig,
    churn_seed: u64,
    quorum: u16,
    chaos: bool,
) -> Result<(StatsSnapshot, swarm::SwarmReport, Vec<SwarmJobPlan>)> {
    let preset = &plan.preset;
    let limits = preset.limits.limits();
    let budget = Arc::new(HostBudget::new(limits.host_bytes));
    let base = ServeOptions {
        bind: "127.0.0.1:0".to_string(),
        profile: preset.ps_profile(),
        limits,
        downlink_chaos: (chaos && !preset.down.is_clean()).then(|| preset.down.direction()),
        chaos_seed: churn_seed,
        io_backend: plan.backend,
        cores: 0,
        host_budget: Some(Arc::clone(&budget)),
        trace: Some(Arc::clone(recorder)),
    };
    let handle = serve(&base)?;

    let per = plan.clients as usize;
    let job_plans: Vec<SwarmJobPlan> = (0..plan.jobs)
        .map(|j| {
            let seed = job_seed(plan.seed, j);
            let updates: Vec<Vec<Vec<f32>>> = (1..=plan.rounds)
                .map(|round| {
                    (0..per).map(|c| synthetic_update(seed, c, round, plan.d)).collect()
                })
                .collect();
            SwarmJobPlan {
                job: job_id(j),
                n_clients: per as u16,
                backend_seed: seed,
                updates: UpdateSource::Explicit(updates),
            }
        })
        .collect();

    let mut sopts = SwarmOptions::new(handle.local_addr().to_string(), plan.d);
    sopts.jobs = job_plans.clone();
    sopts.threshold_a = plan.threshold_a;
    sopts.k = plan.k;
    sopts.bits_b = preset.mix.bits_b;
    sopts.payload_budget = plan.payload;
    sopts.rounds = plan.rounds;
    sopts.sockets = preset.mix.swarm_sockets;
    sopts.timeout = Duration::from_millis(preset.mix.timeout_ms);
    sopts.max_retries = preset.mix.max_retries;
    sopts.uplink_chaos = (chaos && !preset.up.is_clean()).then(|| preset.up.direction());
    sopts.chaos_seed = churn_seed;
    sopts.collect_outcomes = true;
    sopts.quorum = quorum;
    sopts.churn = Some(churn_cfg);

    let report = swarm::run(&sopts)
        .with_context(|| format!("churn leg {label} (churn seed {churn_seed})"))?;
    let server = handle.stats();
    handle.shutdown();

    // Dead clients never send Goodbye; quorum close and job teardown
    // must reclaim their reservations all the same.
    for jp in &job_plans {
        let held = budget.reserved(jp.job);
        ensure!(
            held == 0,
            "churn leg {label}: HostBudget leak — job {} still holds {held} bytes \
             after shutdown",
            jp.job
        );
    }
    Ok((server, report, job_plans))
}

/// Stand the deployment up twice and run the churn fault plane. Leg A
/// (clean wire, permanent kills) proves quorum rounds are bit-exact for
/// the surviving quorum and close at the phase deadline instead of
/// stalling; leg B (preset chaos + rejoins + flash crowd) proves
/// liveness and that the lifecycle ledger matches the sampled plan.
fn run_churn_episode(plan: &EpisodePlan, recorder: &Arc<FlightRecorder>) -> Result<EpisodeCounters> {
    let preset = &plan.preset;
    let per = plan.clients as usize;
    let rounds = plan.rounds;
    let total = plan.jobs * per;
    ensure!(total <= u16::MAX as usize, "churn episode fleet too large");
    let total = total as u16;

    // ---- Leg A: bit-exact quorum close under permanent kills. -------
    // Rejoiners and flash crowds race the deadline-bound close on wall
    // clock, so the deterministic leg pins every kill permanent; the
    // plan's guaranteed voter/updater sets then ARE the wire's
    // contributor sets. The seed is salt-searched so at least one
    // client dies and every job keeps at least one full participant in
    // every round.
    let cfg_a = ChurnConfig {
        kill_rate: preset.churn.kill_rate.clamp(0.2, 0.8),
        rejoin_delay: Duration::ZERO,
        flash_crowd: 0,
        permanent_rate: 1.0,
    };
    let (seed_a, cplan_a, floor_a) = (0..4096u64)
        .find_map(|salt| {
            let seed =
                mix64(plan.seed ^ 0xA11C_E55E ^ salt.wrapping_mul(0xA24B_AED4_963E_E407));
            let cplan = ChurnPlan::new(&cfg_a, seed, total, rounds as u32);
            let floor = job_quorum_floor(&cplan, plan.jobs, per, rounds);
            (floor >= 1 && cplan.kills() >= 1).then_some((seed, cplan, floor))
        })
        .ok_or_else(|| anyhow!("no leg-A churn seed with kills and a live quorum"))?;
    let (server_a, report_a, jobs_a) =
        run_churn_leg(plan, recorder, "A", cfg_a, seed_a, floor_a, false)?;

    ensure!(
        report_a.churn.kills == cplan_a.kills()
            && report_a.churn.permanent_deaths == cplan_a.kills()
            && report_a.churn.rejoins == 0
            && report_a.churn.flash_joins == 0
            && report_a.churn.stranded == 0,
        "leg A lifecycle ledger diverged from the plan: {:?} (plan: {} permanent \
         kills)",
        report_a.churn,
        cplan_a.kills()
    );
    // A killed client leaves its round short of all-N completion in at
    // least one phase, so the kill rounds can only retire through the
    // quorum path — and on a clean wire they must do so at the phase
    // deadline, never by idle reclamation.
    ensure!(
        server_a.quorum_closes >= 1,
        "leg A killed {} client(s) yet no phase quorum-closed",
        cplan_a.kills()
    );
    ensure!(
        server_a.idle_releases == 0,
        "leg A tripped idle reclamation {} time(s) — a quorum round stalled past \
         its phase deadline",
        server_a.idle_releases
    );

    let outcomes_a = report_a
        .outcomes
        .as_ref()
        .ok_or_else(|| anyhow!("leg A did not collect outcomes"))?;
    ensure!(outcomes_a.len() == jobs_a.len(), "leg A outcomes lost a job");
    let mut expected_rounds_a = 0u64;
    for (ji, jp) in jobs_a.iter().enumerate() {
        let UpdateSource::Explicit(rounds_updates) = &jp.updates else {
            unreachable!("churn legs build explicit streams only");
        };
        let base_cid = ji * per;
        for c in 0..per {
            let lc = cplan_a.client((base_cid + c) as u16);
            let completed = lc.kill_at_round.map_or(rounds, |r| r as usize - 1);
            expected_rounds_a += completed as u64;
            ensure!(
                outcomes_a[ji][c].len() == completed,
                "leg A job {} client {c}: completed {} round(s), plan says {completed}",
                jp.job,
                outcomes_a[ji][c].len()
            );
        }
        for round in 1..=rounds {
            let updates = &rounds_updates[round - 1];
            let voters: Vec<usize> = (0..per)
                .filter(|&c| {
                    cplan_a.client((base_cid + c) as u16).guaranteed_voter(round as u32)
                })
                .collect();
            let updaters: Vec<usize> = (0..per)
                .filter(|&c| {
                    cplan_a.client((base_cid + c) as u16).full_participant(round as u32)
                })
                .collect();
            let (exp_idx, exp_lanes) = reference_round_quorum(
                updates,
                &voters,
                &updaters,
                per,
                jp.backend_seed,
                round,
                plan.k,
                plan.threshold_a as usize,
                preset.mix.bits_b,
            );
            for &c in &updaters {
                let out = &outcomes_a[ji][c][round - 1];
                ensure!(
                    out.gia_indices == exp_idx,
                    "leg A job {} client {c} round {round}: GIA diverged from the \
                     quorum-aware reference",
                    jp.job
                );
                ensure!(
                    out.aggregate == exp_lanes,
                    "leg A job {} client {c} round {round}: aggregate diverged from \
                     the quorum-aware reference",
                    jp.job
                );
            }
        }
    }
    ensure!(
        report_a.rounds_completed == expected_rounds_a,
        "leg A completed {} client-rounds, plan says {expected_rounds_a}",
        report_a.rounds_completed
    );

    // ---- Leg B: liveness under the preset's full fault plane. -------
    // Kills, stale rejoins, a flash crowd and (on chaos episodes) both
    // chaos directions at once. Aggregates here legitimately include
    // catch-up contributors the close raced with, so the leg asserts
    // liveness and lifecycle accounting, not bit-exactness.
    let cfg_b = preset.churn.config();
    let (seed_b, cplan_b, floor_b) = (0..4096u64)
        .find_map(|salt| {
            let seed =
                mix64(plan.seed ^ 0xB1A5_7C20 ^ salt.wrapping_mul(0xA24B_AED4_963E_E407));
            let cplan = ChurnPlan::new(&cfg_b, seed, total, rounds as u32);
            let floor = job_survivor_floor(&cplan, plan.jobs, per);
            (floor >= 1).then_some((seed, cplan, floor))
        })
        .ok_or_else(|| anyhow!("no leg-B churn seed keeps a client alive per job"))?;
    let quorum_b = preset.mix.quorum.clamp(1, floor_b);
    let (server_b, report_b, _) =
        run_churn_leg(plan, recorder, "B", cfg_b, seed_b, quorum_b, plan.chaos)?;

    ensure!(
        report_b.churn.kills == cplan_b.kills()
            && report_b.churn.permanent_deaths == cplan_b.permanent_deaths()
            && report_b.churn.flash_joins == cplan_b.flash_crowd()
            && report_b.churn.rejoins == cplan_b.kills() - cplan_b.permanent_deaths(),
        "leg B lifecycle ledger diverged from the plan: {:?} (plan: {} kills, {} \
         permanent, {} flash)",
        report_b.churn,
        cplan_b.kills(),
        cplan_b.permanent_deaths(),
        cplan_b.flash_crowd()
    );
    ensure!(
        report_b.churn.stranded == 0,
        "leg B stranded {} client(s) on loopback",
        report_b.churn.stranded
    );
    // Every eventually-active client finishes all its rounds (rejoiners
    // redo the round they died in); permanent deaths finish exactly the
    // rounds before their kill.
    let expected_rounds_b: u64 = (0..total)
        .map(|cid| {
            let lc = cplan_b.client(cid);
            if lc.permanent_death() {
                lc.kill_at_round.map_or(rounds as u64, |r| r as u64 - 1)
            } else {
                rounds as u64
            }
        })
        .sum();
    ensure!(
        report_b.rounds_completed == expected_rounds_b,
        "leg B completed {} client-rounds, the plan owes {expected_rounds_b}",
        report_b.rounds_completed
    );

    let mut server = server_a;
    server.merge(&server_b);
    Ok(EpisodeCounters {
        server,
        client_retx: report_a.stats.retransmissions + report_b.stats.retransmissions,
        client_rounds: report_a.rounds_completed + report_b.rounds_completed,
        warm_pool_misses: server.pool_misses,
    })
}

fn check_server_invariants(
    plan: &EpisodePlan,
    server: &StatsSnapshot,
    warm_pool_misses: Option<u64>,
) -> Result<()> {
    check_server_invariants_for(plan, server, warm_pool_misses, plan.jobs)
}

/// Round-count, idle-reclaim and pool-steady-state invariants shared by
/// both episode modes. `warm_pool_misses` is `Some` when the episode
/// had a warm-up boundary to compare against.
fn check_server_invariants_for(
    plan: &EpisodePlan,
    server: &StatsSnapshot,
    warm_pool_misses: Option<u64>,
    n_jobs: usize,
) -> Result<()> {
    let expected_rounds = (plan.shards as u64) * (n_jobs as u64) * (plan.rounds as u64);
    if plan.chaos {
        ensure!(
            server.rounds_completed >= expected_rounds,
            "server completed {} rounds, expected at least {expected_rounds}",
            server.rounds_completed
        );
    } else {
        ensure!(
            server.rounds_completed == expected_rounds,
            "server completed {} rounds, expected exactly {expected_rounds}",
            server.rounds_completed
        );
        // A clean episode that trips idle reclamation had a wedged
        // round sitting past its deadline.
        ensure!(
            server.idle_releases == 0,
            "clean episode tripped idle reclamation {} time(s) — wedged round",
            server.idle_releases
        );
        if let Some(warm) = warm_pool_misses {
            ensure!(
                server.pool_misses == warm,
                "steady-state pool misses grew after warm-up: {warm} -> {}",
                server.pool_misses
            );
        }
    }
    Ok(())
}

/// Run one episode, dumping the flight recorder to `trace_path` when
/// any invariant fails.
fn run_episode(plan: &EpisodePlan, trace_path: &str) -> Result<EpisodeCounters> {
    let recorder = Arc::new(FlightRecorder::new(DEFAULT_EVENTS));
    let result = if plan.churn {
        run_churn_episode(plan, &recorder)
    } else if plan.swarm {
        run_swarm_episode(plan, &recorder)
    } else {
        run_driver_episode(plan, &recorder)
    };
    if result.is_err() {
        if let Err(e) = recorder.dump_to(trace_path) {
            crate::warn!("soak: trace dump to {trace_path} failed: {e}");
        } else {
            crate::warn!("soak: flight recorder dumped to {trace_path}");
        }
    }
    result
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render one SOAK.json ledger line (newline-terminated JSON object).
pub fn ledger_line(rec: &EpisodeRecord) -> String {
    let p = &rec.plan;
    let s = &rec.counters.server;
    let failure = match &rec.failure {
        Some(f) => format!("\"{}\"", json_escape(f)),
        None => "null".to_string(),
    };
    format!(
        "{{\"episode\": {}, \"seed\": {}, \"preset\": \"{}\", \"backend\": \"{}\", \
         \"shards\": {}, \"chaos\": {}, \"mode\": \"{}\", \"jobs\": {}, \
         \"clients_per_job\": {}, \"d\": {}, \"rounds\": {}, \"payload\": {}, \
         \"wall_s\": {:.3}, \"client_rounds\": {}, \"rounds_completed\": {}, \
         \"retransmissions\": {}, \"frames_pooled\": {}, \"pool_misses\": {}, \
         \"warm_pool_misses\": {}, \"idle_releases\": {}, \"spilled\": {}, \
         \"quorum_closes\": {}, \"late_after_close\": {}, \
         \"decode_errors\": {}, \"ok\": {}, \"failure\": {failure}, \
         \"replay\": \"{}\"}}\n",
        rec.episode,
        p.seed,
        json_escape(&p.preset_arg),
        p.backend.name(),
        p.shards,
        p.chaos,
        p.mode(),
        p.jobs,
        p.clients,
        p.d,
        p.rounds,
        p.payload,
        rec.wall_s,
        rec.counters.client_rounds,
        s.rounds_completed,
        rec.counters.client_retx,
        s.frames_pooled,
        s.pool_misses,
        rec.counters.warm_pool_misses,
        s.idle_releases,
        s.spilled,
        s.quorum_closes,
        s.late_after_close,
        s.decode_errors,
        rec.ok,
        json_escape(&p.replay_command()),
    )
}

/// Run a soak: schedule episodes from the root seed (or replay one
/// `--episode-seed`), append a ledger line per episode to `opts.out`,
/// and fail fast — the first broken invariant dumps its flight-recorder
/// trace, writes its ledger line and aborts the run with the replay
/// command in the error.
pub fn run(opts: &SoakOptions) -> Result<SoakReport> {
    use std::io::Write as _;
    ensure!(!opts.presets.is_empty(), "soak needs at least one preset");
    let started = Instant::now();
    if let Some(parent) = std::path::Path::new(&opts.out).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut ledger = std::fs::File::create(&opts.out)
        .with_context(|| format!("creating soak ledger {}", opts.out))?;
    let mut passed = 0usize;
    let mut idx = 0usize;
    loop {
        let seed = match opts.episode_seed {
            Some(s) => {
                if idx >= 1 {
                    break;
                }
                s
            }
            None => {
                if opts.episodes > 0 && idx >= opts.episodes {
                    break;
                }
                if opts.duration_s > 0.0
                    && started.elapsed().as_secs_f64() >= opts.duration_s
                {
                    crate::info!(
                        "soak: duration budget ({} s) reached after {idx} episode(s)",
                        opts.duration_s
                    );
                    break;
                }
                schedule_seed(opts.seed, idx, &opts.presets)?
            }
        };
        let plan = sample_episode(seed, &opts.presets)?;
        crate::info!(
            "soak episode {idx}: preset={} backend={} shards={} chaos={} mode={} \
             jobs={} clients={} d={} rounds={} (seed {seed})",
            plan.preset_arg,
            plan.backend.name(),
            plan.shards,
            plan.chaos,
            plan.mode(),
            plan.jobs,
            plan.clients,
            plan.d,
            plan.rounds
        );
        let trace_path = format!("SOAK_FAIL_ep{idx}.trace.jsonl");
        let t0 = Instant::now();
        let result = run_episode(&plan, &trace_path);
        let wall_s = t0.elapsed().as_secs_f64();
        let (counters, ok, failure) = match &result {
            Ok(c) => (*c, true, None),
            Err(e) => (EpisodeCounters::default(), false, Some(e.to_string())),
        };
        let record =
            EpisodeRecord { episode: idx, plan, wall_s, counters, ok, failure };
        ledger.write_all(ledger_line(&record).as_bytes())?;
        ledger.flush()?;
        if let Err(e) = result {
            bail!(
                "soak episode {idx} failed: {e}\n  replay: {}\n  trace: {trace_path}\n  \
                 ledger: {}",
                record.plan.replay_command(),
                opts.out
            );
        }
        crate::info!(
            "soak episode {idx} ok in {wall_s:.2} s: {} client-rounds, {} retx, \
             {} pool misses",
            record.counters.client_rounds,
            record.counters.client_retx,
            record.counters.server.pool_misses
        );
        passed += 1;
        idx += 1;
    }
    Ok(SoakReport { episodes: passed, wall_s: started.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn builtin_args() -> Vec<String> {
        BUILTIN_PRESETS.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn sampling_is_deterministic_and_replays_with_narrowed_presets() {
        let presets = builtin_args();
        for seed in [1u64, 7, 0xDEAD_BEEF, u64::MAX] {
            let a = sample_episode(seed, &presets).unwrap();
            let b = sample_episode(seed, &presets).unwrap();
            assert_eq!(a.preset_arg, b.preset_arg);
            assert_eq!(a.backend, b.backend);
            assert_eq!((a.chaos, a.d, a.rounds, a.k), (b.chaos, b.d, b.rounds, b.k));
            // The replay property: narrowing --presets to the picked one
            // must reproduce every other draw (fork-based sampling).
            let replay = sample_episode(seed, &[a.preset_arg.clone()]).unwrap();
            assert_eq!(a.preset_arg, replay.preset_arg);
            assert_eq!(a.backend, replay.backend);
            assert_eq!(a.chaos, replay.chaos);
            assert_eq!(a.d, replay.d);
        }
    }

    #[test]
    fn plans_respect_wire_constraints() {
        let presets = builtin_args();
        for case in 0..64u64 {
            let plan = sample_episode(mix64(0xA5A5 ^ case), &presets).unwrap();
            assert!(plan.threshold_a >= 1);
            assert!(plan.threshold_a <= plan.clients);
            assert!(plan.k >= 1 && plan.k <= plan.d);
            // Every shard must own at least one vote block.
            let blocks = plan.d.div_ceil(8 * plan.payload);
            assert!(
                blocks >= plan.shards as usize,
                "{}: {} blocks < {} shards",
                plan.preset_arg,
                blocks,
                plan.shards
            );
            if plan.swarm {
                assert_eq!(plan.shards, 1, "swarm episodes are single-shard");
            }
            if plan.churn {
                assert_eq!(plan.shards, 1, "churn episodes are single-shard");
                assert!(
                    !plan.preset.churn.is_quiet(),
                    "{}: churn episode without a churn plane",
                    plan.preset_arg
                );
                assert!(
                    plan.preset.mix.quorum >= 1,
                    "{}: churn episode with all-N rounds cannot close",
                    plan.preset_arg
                );
            }
        }
    }

    #[test]
    fn four_scheduled_episodes_cover_the_matrix() {
        let presets = builtin_args();
        let plans: Vec<EpisodePlan> = (0..4)
            .map(|i| {
                let seed = schedule_seed(7, i, &presets).unwrap();
                sample_episode(seed, &presets).unwrap()
            })
            .collect();
        assert!(plans.iter().any(|p| p.backend == IoBackend::Threaded));
        assert!(plans.iter().any(|p| p.backend == IoBackend::Reactor));
        assert!(
            plans.iter().any(|p| p.backend == IoBackend::Fleet),
            "no fleet episode scheduled"
        );
        assert!(plans.iter().any(|p| p.chaos), "no chaos episode scheduled");
        assert!(plans.iter().any(|p| !p.chaos), "no clean episode scheduled");
        assert!(plans.iter().any(|p| p.shards == 1));
        assert!(plans.iter().any(|p| p.shards >= 2));
        // The adversarial preset sits in an even schedule slot, so the
        // default smoke deterministically runs its churn fault plane.
        assert!(plans.iter().any(|p| p.churn), "no churn episode scheduled");
        assert!(plans.iter().any(|p| !p.churn), "no churn-free episode scheduled");
        // And the schedule is itself deterministic.
        let again = schedule_seed(7, 2, &presets).unwrap();
        assert_eq!(again, schedule_seed(7, 2, &presets).unwrap());
    }

    #[test]
    fn ledger_lines_parse_and_carry_the_replay_seed() {
        let presets = builtin_args();
        let plan = sample_episode(schedule_seed(3, 1, &presets).unwrap(), &presets).unwrap();
        let seed = plan.seed;
        let rec = EpisodeRecord {
            episode: 1,
            plan,
            wall_s: 0.25,
            counters: EpisodeCounters::default(),
            ok: false,
            failure: Some("aggregate diverged \"badly\"".to_string()),
        };
        let line = ledger_line(&rec);
        let json = crate::util::json::parse(line.trim()).unwrap();
        assert_eq!(json.get("episode").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(json.get("seed").and_then(|v| v.as_f64()), Some(seed as f64));
        assert_eq!(
            json.get("ok").map(|v| *v == crate::util::json::Json::Bool(false)),
            Some(true)
        );
        let replay = json.get("replay").and_then(|v| v.as_str()).unwrap();
        assert!(replay.contains("--episode-seed"), "{replay}");
        assert!(replay.contains(&seed.to_string()), "{replay}");
        let failure = json.get("failure").and_then(|v| v.as_str()).unwrap();
        assert!(failure.contains("diverged"), "{failure}");
    }

    #[test]
    fn quorum_reference_over_everyone_matches_the_all_n_reference() {
        // With voters == updaters == everyone, the quorum-aware oracle
        // must reduce to the legacy one — the quorum=0 compatibility
        // claim, restated over the reference itself.
        let d = 256;
        let updates: Vec<Vec<f32>> = (0..3).map(|c| synthetic_update(11, c, 2, d)).collect();
        let everyone: Vec<usize> = (0..3).collect();
        let (idx_all, lanes_all, _) = reference_round(&updates, 11, 2, 12, 2, 12);
        let (idx_q, lanes_q) =
            reference_round_quorum(&updates, &everyone, &everyone, 3, 11, 2, 12, 2, 12);
        assert_eq!(idx_all, idx_q);
        assert_eq!(lanes_all, lanes_q);
    }

    #[test]
    fn churn_floors_bound_the_quorum_and_quiet_plans_are_full_strength() {
        let quiet = ChurnPlan::quiet(6);
        assert_eq!(job_quorum_floor(&quiet, 2, 3, 4), 3);
        assert_eq!(job_survivor_floor(&quiet, 2, 3), 3);
        let cfg = ChurnConfig {
            kill_rate: 1.0,
            rejoin_delay: Duration::ZERO,
            flash_crowd: 0,
            permanent_rate: 1.0,
        };
        let lethal = ChurnPlan::new(&cfg, 5, 6, 4);
        // kill_rate 1.0 kills every client in round 1, so no round has a
        // full participant and no quorum is safe.
        assert_eq!(job_quorum_floor(&lethal, 2, 3, 4), 0);
        assert_eq!(job_survivor_floor(&lethal, 2, 3), 0);
    }

    #[test]
    fn reference_round_matches_the_wire_backend_oracle_shape() {
        // Smoke the oracle itself: indices sorted and in range, lanes
        // aligned with indices, residual shape preserved.
        let d = 256;
        let updates: Vec<Vec<f32>> = (0..3).map(|c| synthetic_update(9, c, 1, d)).collect();
        let (idx, lanes, residuals) = reference_round(&updates, 9, 1, 12, 2, 12);
        assert_eq!(idx.len(), lanes.len());
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices must be sorted");
        assert!(idx.iter().all(|&g| g < d));
        assert_eq!(residuals.len(), 3);
        assert!(residuals.iter().all(|r| r.len() == d));
    }
}
