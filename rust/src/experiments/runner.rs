//! Generic experiment runner: build the environment (backend + data +
//! switch), loop global iterations, evaluate on a cadence and record
//! everything. Every figure/table regenerator is a thin loop over this.

use anyhow::{Context, Result};

use crate::algorithms::make_algorithm;
use crate::configx::{BackendKind, ExperimentConfig};
use crate::data::synth;
use crate::fl::{FlEnv, NativeBackend};
use crate::metrics::{RoundRecord, RunRecorder};
use crate::runtime::{artifacts_available, PjrtBackend, DEFAULT_ARTIFACT_DIR};

/// Runner knobs not part of the scientific config.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Evaluate the global model every this many rounds (1 = every round).
    pub eval_every: usize,
    /// Print a progress line per evaluation.
    pub verbose: bool,
    /// Artifact directory for the PJRT backend.
    pub artifact_dir: String,
    /// Hidden width of the native MLP backend.
    pub native_hidden: usize,
    /// Native backend batch size.
    pub native_batch: usize,
}

impl Default for RunOptions {
    fn default() -> Self {
        RunOptions {
            eval_every: 1,
            verbose: false,
            artifact_dir: DEFAULT_ARTIFACT_DIR.to_string(),
            native_hidden: 64,
            native_batch: 16,
        }
    }
}

/// Construct the environment for `cfg` (data generation + backend).
pub fn build_env(cfg: &ExperimentConfig, opts: &RunOptions) -> Result<FlEnv> {
    cfg.validate().map_err(|e| anyhow::anyhow!("{e}"))?;
    let data = synth::generate(
        cfg.dataset,
        cfg.partition,
        cfg.num_clients,
        cfg.samples_per_client,
        cfg.seed,
    );
    let backend: Box<dyn crate::fl::ModelBackend> = match cfg.backend {
        BackendKind::Native => Box::new(NativeBackend::new(
            data,
            opts.native_hidden,
            cfg.local_iters,
            opts.native_batch,
            cfg.seed,
        )),
        BackendKind::Pjrt => {
            anyhow::ensure!(
                artifacts_available(&opts.artifact_dir),
                "no AOT bundle in '{}' — run `make artifacts` first",
                opts.artifact_dir
            );
            Box::new(
                PjrtBackend::load(&opts.artifact_dir, cfg.model_name(), data, cfg.seed)
                    .context("loading PJRT backend")?,
            )
        }
    };
    let mut env = FlEnv::new(cfg.clone(), backend);
    env.init_model();
    Ok(env)
}

/// Run one configuration to completion and return the per-round record.
pub fn run(cfg: &ExperimentConfig, opts: &RunOptions) -> Result<RunRecorder> {
    let mut env = build_env(cfg, opts)?;
    let mut alg = make_algorithm(cfg, env.d());
    let mut recorder = RunRecorder::new(cfg.label());
    for round in 0..cfg.rounds.max(1) {
        if let Some(limit) = cfg.sim_time_limit_s {
            if env.now >= limit {
                break;
            }
        }
        let report = alg.run_round(&mut env, round)?;
        env.now += report.duration_s;
        let evaluate = round % opts.eval_every == 0 || round + 1 == cfg.rounds;
        let (acc, loss) = if evaluate {
            let (a, l) = env.backend.evaluate(&env.params);
            (Some(a), Some(l))
        } else {
            (None, None)
        };
        if opts.verbose {
            if let Some(a) = acc {
                eprintln!(
                    "[{}] round {:>4}  t={:>9.2}s  loss={:.4}  acc={:.4}  traffic={:.2} MB",
                    cfg.label(),
                    round,
                    env.now,
                    report.train_loss,
                    a,
                    (recorder.total_traffic().total() + report.traffic.total()) as f64 / 1e6,
                );
            }
        }
        recorder.push(RoundRecord {
            round,
            sim_time_s: env.now,
            train_loss: report.train_loss,
            test_accuracy: acc,
            test_loss: loss,
            traffic: report.traffic,
            agg_ops: report.agg_ops,
            uploaded_elems: report.uploaded_elems,
        });
    }
    Ok(recorder)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configx::{AlgorithmKind, DatasetKind, Partition};

    fn quick_cfg(alg: AlgorithmKind) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset(DatasetKind::Tiny, Partition::Iid);
        cfg.algorithm = alg;
        cfg.rounds = 4;
        cfg.num_clients = 4;
        cfg.samples_per_client = 30;
        cfg
    }

    #[test]
    fn runner_records_every_round() {
        let rec = run(&quick_cfg(AlgorithmKind::FediAc), &RunOptions::default()).unwrap();
        assert_eq!(rec.records.len(), 4);
        assert!(rec.records.iter().all(|r| r.test_accuracy.is_some()));
        // Sim time strictly increases.
        for w in rec.records.windows(2) {
            assert!(w[1].sim_time_s > w[0].sim_time_s);
        }
    }

    #[test]
    fn all_algorithms_run_end_to_end() {
        for alg in AlgorithmKind::ALL {
            let rec = run(&quick_cfg(alg), &RunOptions::default())
                .unwrap_or_else(|e| panic!("{alg:?}: {e}"));
            assert_eq!(rec.records.len(), 4, "{alg:?}");
            assert!(rec.total_traffic().total() > 0, "{alg:?}");
        }
    }

    #[test]
    fn time_limit_stops_early() {
        let mut cfg = quick_cfg(AlgorithmKind::SwitchMl);
        cfg.rounds = 100;
        cfg.sim_time_limit_s = Some(0.5);
        let rec = run(&cfg, &RunOptions::default()).unwrap();
        assert!(rec.records.len() < 100);
    }

    #[test]
    fn eval_cadence_respected() {
        let mut cfg = quick_cfg(AlgorithmKind::FedAvg);
        cfg.rounds = 6;
        let opts = RunOptions { eval_every: 3, ..Default::default() };
        let rec = run(&cfg, &opts).unwrap();
        let evals = rec.records.iter().filter(|r| r.test_accuracy.is_some()).count();
        assert_eq!(evals, 3); // rounds 0, 3, and final
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg(AlgorithmKind::FediAc);
        let a = run(&cfg, &RunOptions::default()).unwrap();
        let b = run(&cfg, &RunOptions::default()).unwrap();
        for (ra, rb) in a.records.iter().zip(&b.records) {
            assert_eq!(ra.test_accuracy, rb.test_accuracy);
            assert_eq!(ra.traffic, rb.traffic);
            assert!((ra.sim_time_s - rb.sim_time_s).abs() < 1e-12);
        }
    }
}
