//! E5 / Fig. 4: sensitivity to the voting threshold a across system
//! scales N ∈ {20..50}, threshold a ∈ {5%, 10%, 15%, 20%}·N, IID and
//! non-IID, low-performance PS, fixed training time.
//!
//! Paper's shape: a plateau of near-best accuracy for a ∈ [5%N, 15%N]
//! (IID) / [10%N, 20%N] (non-IID); accuracy degrades as N grows at fixed
//! time because rounds take longer.

use anyhow::Result;

use crate::configx::{AlgorithmKind, DatasetKind, ExperimentConfig, Partition};
use crate::experiments::{runner, RunOptions, Scale};

/// Voting thresholds a as fractions of N (the paper's Fig. 4 grid).
pub const A_FRACTIONS: [f64; 4] = [0.05, 0.10, 0.15, 0.20];

/// Grid entry: (N, a, accuracy).
pub fn run_sweep(
    partition: Partition,
    clients: &[usize],
    scale: &Scale,
    opts: &RunOptions,
) -> Result<Vec<(usize, usize, f64)>> {
    let mut out = Vec::new();
    for &n in clients {
        for &frac in &A_FRACTIONS {
            let a = ((frac * n as f64).round() as usize).clamp(1, n);
            let mut cfg = ExperimentConfig::preset(DatasetKind::SynthCifar10, partition);
            scale.apply(&mut cfg);
            cfg.algorithm = AlgorithmKind::FediAc;
            cfg.num_clients = n;
            cfg.fediac.threshold_a = a;
            cfg.ps = crate::configx::PsProfile::low();
            // Paper: fixed 500 s training-time budget (fig. 4 setup).
            cfg.sim_time_limit_s = scale.sim_time_limit_s.or(Some(500.0));
            let rec = runner::run(&cfg, opts)?;
            let acc = rec
                .records
                .iter()
                .rev()
                .find_map(|r| r.test_accuracy)
                .unwrap_or(0.0);
            out.push((n, a, acc));
        }
    }
    Ok(out)
}

/// Render the sweep grid as a TSV block.
pub fn render(results: &[(usize, usize, f64)], label: &str) -> String {
    let mut out = format!(
        "# fig4 ({label}): FediAC final accuracy vs voting threshold a\n\
         clients_n\tthreshold_a\ta_pct_of_n\taccuracy\n"
    );
    for (n, a, acc) in results {
        out.push_str(&format!(
            "{n}\t{a}\t{:.0}%\t{acc:.4}\n",
            100.0 * *a as f64 / *n as f64
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_produces_grid() {
        let scale = Scale { rounds: 3, num_clients: 6, ..Scale::quick() };
        let res =
            run_sweep(Partition::Iid, &[6], &scale, &RunOptions::default()).unwrap();
        assert_eq!(res.len(), A_FRACTIONS.len());
        // a values rise with the fraction.
        let a_vals: Vec<usize> = res.iter().map(|&(_, a, _)| a).collect();
        assert!(a_vals.windows(2).all(|w| w[0] <= w[1]));
        assert!(!render(&res, "iid").is_empty());
    }
}
