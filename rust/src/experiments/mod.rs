//! Paper experiment regenerators (DESIGN.md §3 index):
//!
//! * E1 `fig2`  — accuracy vs wall-clock per dataset/partition/PS
//! * E2/E3 `tables` — traffic to target accuracy (Tables I & II)
//! * E4 `fig3` — accuracy vs Dirichlet β (FediAC vs libra)
//! * E5 `fig4` — accuracy vs voting threshold a across system scales N
//!
//! Each prints the paper's rows/series on stdout and writes CSVs under
//! `results/`.

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod runner;
pub mod tables;

pub use runner::{build_env, run, RunOptions};

use crate::configx::{BackendKind, DatasetKind, ExperimentConfig};

/// Workload scale shared by the regenerators. The paper's absolute scale
/// (ResNet-18, 500 s budgets) is out of reach on this testbed; `quick`
/// keeps every qualitative comparison while fitting in CI, `standard` is
/// the EXPERIMENTS.md reference scale, and every knob is CLI-overridable.
#[derive(Debug, Clone)]
pub struct Scale {
    /// Global rounds per run.
    pub rounds: usize,
    /// Clients N.
    pub num_clients: usize,
    /// Synthetic samples generated per client.
    pub samples_per_client: usize,
    /// Simulated wall-clock budget, if any.
    pub sim_time_limit_s: Option<f64>,
    /// Model-execution backend.
    pub backend: BackendKind,
    /// Evaluate accuracy every this many rounds.
    pub eval_every: usize,
    /// Wire-dimension scaling (see ExperimentConfig::net_scale).
    /// 0.0 = auto: paper_d(dataset) / testbed_d (see `auto_net_scale`).
    pub net_scale: f64,
    /// Root seed for the whole run.
    pub seed: u64,
}

/// Per-dataset auto wire scale: the paper's model dimension over this
/// testbed's (ResNet-18 ≈ 11M for CIFAR*, the 800k CNN for FEMNIST,
/// §V-A1) so each dataset keeps its own communication/computation ratio.
pub fn auto_net_scale(dataset: DatasetKind) -> f64 {
    match dataset {
        DatasetKind::Tiny => 1.0,
        DatasetKind::SynthFemnist => 15.0,  // 0.8M / ~54k
        DatasetKind::SynthCifar10 | DatasetKind::SynthCifar100 => 200.0, // 11M / ~55k
    }
}

impl Scale {
    /// CI-sized: native backend, few rounds.
    pub fn quick() -> Self {
        Scale {
            rounds: 12,
            num_clients: 8,
            samples_per_client: 60,
            sim_time_limit_s: None,
            backend: BackendKind::Native,
            eval_every: 2,
            net_scale: 1.0,
            seed: 7,
        }
    }

    /// EXPERIMENTS.md reference scale (native backend for sweeps).
    /// net_scale = 200 emulates the paper's ResNet-18 wire footprint
    /// (d ≈ 11M) at this testbed's d ≈ 50k (DESIGN.md §2 note 4).
    pub fn standard() -> Self {
        Scale {
            rounds: 60,
            num_clients: 20,
            samples_per_client: 200,
            sim_time_limit_s: None,
            backend: BackendKind::Native,
            eval_every: 2,
            net_scale: 0.0, // auto per dataset
            seed: 7,
        }
    }

    /// Apply onto a preset config.
    pub fn apply(&self, cfg: &mut ExperimentConfig) {
        cfg.rounds = self.rounds;
        cfg.num_clients = self.num_clients;
        cfg.samples_per_client = self.samples_per_client;
        cfg.sim_time_limit_s = self.sim_time_limit_s;
        cfg.backend = self.backend;
        cfg.net_scale = if self.net_scale == 0.0 {
            auto_net_scale(cfg.dataset)
        } else {
            self.net_scale
        };
        cfg.seed = self.seed;
        // Keep the paper's a-threshold proportionate when N ≠ 20:
        // a = 3/20·N (IID) or 4/20·N (non-IID), ≥ 1.
        let frac = cfg.fediac.threshold_a as f64 / 20.0;
        cfg.fediac.threshold_a =
            ((frac * self.num_clients as f64).round() as usize).clamp(1, self.num_clients);
    }
}
