//! E1 / Fig. 2: model accuracy vs simulated wall-clock for every
//! algorithm, dataset, partition and PS profile.
//!
//! The paper's headline comparison: FediAC converges fastest in wall-clock
//! on both high- and low-performance switches; OmniReduce is worst.

use anyhow::Result;

use crate::configx::{
    AlgorithmKind, DatasetKind, ExperimentConfig, Partition, PsProfile,
};
use crate::experiments::{runner, RunOptions, Scale};
use crate::metrics::RunRecorder;

/// One panel of Fig. 2.
pub struct Fig2Panel {
    /// Panel dataset.
    pub dataset: DatasetKind,
    /// Panel partition scheme.
    pub partition: Partition,
    /// Switch profile of this panel.
    pub ps: PsProfile,
    /// One recorded run per algorithm.
    pub runs: Vec<(AlgorithmKind, RunRecorder)>,
}

/// Algorithms compared in Fig. 2 (FedAvg is in the repo as an extra
/// reference but not part of the paper's figure).
pub const FIG2_ALGOS: [AlgorithmKind; 4] = [
    AlgorithmKind::FediAc,
    AlgorithmKind::SwitchMl,
    AlgorithmKind::OmniReduce,
    AlgorithmKind::Libra,
];

/// Per-dataset simulated-time budget (the paper plots accuracy against
/// wall-clock over a fixed span; every algorithm runs as many rounds as
/// fit — that is where FediAC's shorter rounds pay off).
pub fn time_budget_s(dataset: DatasetKind) -> f64 {
    match dataset {
        DatasetKind::Tiny => 20.0,
        DatasetKind::SynthFemnist => 150.0,
        DatasetKind::SynthCifar10 => 800.0,
        DatasetKind::SynthCifar100 => 1200.0,
    }
}

/// Run one panel.
pub fn run_panel(
    dataset: DatasetKind,
    partition: Partition,
    ps: PsProfile,
    scale: &Scale,
    opts: &RunOptions,
) -> Result<Fig2Panel> {
    let mut runs = Vec::new();
    for alg in FIG2_ALGOS {
        let mut cfg = ExperimentConfig::preset(dataset, partition);
        scale.apply(&mut cfg);
        cfg.algorithm = alg;
        cfg.ps = ps.clone();
        cfg.sim_time_limit_s =
            Some(scale.sim_time_limit_s.unwrap_or_else(|| time_budget_s(dataset)));
        runs.push((alg, runner::run(&cfg, opts)?));
    }
    Ok(Fig2Panel { dataset, partition, ps, runs })
}

/// Render a panel as a TSV series block (round-wise, one line per eval).
pub fn render_panel(panel: &Fig2Panel) -> String {
    let mut out = format!(
        "# fig2 panel: dataset={} partition={} ps={}\n\
         algorithm\tround\tsim_time_s\taccuracy\tcum_traffic_mb\n",
        panel.dataset.name(),
        panel.partition.name(),
        panel.ps.name
    );
    for (alg, rec) in &panel.runs {
        for (i, r) in rec.records.iter().enumerate() {
            if let Some(acc) = r.test_accuracy {
                out.push_str(&format!(
                    "{}\t{}\t{:.3}\t{:.4}\t{:.3}\n",
                    alg.name(),
                    r.round,
                    r.sim_time_s,
                    acc,
                    rec.cumulative_traffic(i).total_mb(),
                ));
            }
        }
    }
    out
}

/// Panel summary: final accuracy per algorithm (the figure's right edge).
pub fn final_accuracies(panel: &Fig2Panel) -> Vec<(AlgorithmKind, f64)> {
    panel
        .runs
        .iter()
        .map(|(alg, rec)| {
            let last = rec
                .records
                .iter()
                .rev()
                .find_map(|r| r.test_accuracy)
                .unwrap_or(0.0);
            (*alg, last)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_panel_has_all_series() {
        let scale = Scale { rounds: 3, num_clients: 4, ..Scale::quick() };
        let panel = run_panel(
            DatasetKind::Tiny,
            Partition::Iid,
            PsProfile::high(),
            &scale,
            &RunOptions::default(),
        )
        .unwrap();
        assert_eq!(panel.runs.len(), 4);
        let tsv = render_panel(&panel);
        for alg in FIG2_ALGOS {
            assert!(tsv.contains(alg.name()), "missing {alg:?}");
        }
        let finals = final_accuracies(&panel);
        assert_eq!(finals.len(), 4);
        assert!(finals.iter().all(|&(_, a)| (0.0..=1.0).contains(&a)));
    }
}
