//! E2/E3 — Tables I & II: total communication traffic (upload + download)
//! to reach target test accuracy, FediAC vs the best baseline, for the
//! high- and low-performance PS.
//!
//! Absolute targets are calibrated to the synthetic corpora (DESIGN.md
//! §2 substitution 3); the *shape* asserted against the paper: FediAC
//! reaches target with substantially less traffic (paper: 41–70% less).

use anyhow::Result;

use crate::configx::{
    AlgorithmKind, DatasetKind, ExperimentConfig, Partition, PsProfile,
};
use crate::experiments::{runner, RunOptions, Scale};

/// One table row.
#[derive(Debug, Clone)]
pub struct TableRow {
    /// Scenario label (dataset + partition).
    pub scenario: String,
    /// Accuracy bar the scenario runs until.
    pub target_accuracy: f64,
    /// (algorithm, traffic MB, sim time s) for those that reached target.
    pub reached: Vec<(AlgorithmKind, f64, f64)>,
    /// FediAC traffic vs the best baseline that reached target.
    pub reduction_pct: Option<f64>,
}

/// The scenarios of Tables I/II with synthetic-corpus target accuracies.
pub fn scenarios() -> Vec<(DatasetKind, Partition, f64)> {
    vec![
        (DatasetKind::SynthCifar10, Partition::Iid, 0.55),
        (DatasetKind::SynthCifar10, Partition::Dirichlet(0.5), 0.50),
        (DatasetKind::SynthFemnist, Partition::Natural, 0.45),
        (DatasetKind::SynthCifar100, Partition::Iid, 0.30),
        (DatasetKind::SynthCifar100, Partition::Dirichlet(0.5), 0.25),
    ]
}

/// Algorithms entered into the table race.
pub const TABLE_ALGOS: [AlgorithmKind; 4] = [
    AlgorithmKind::FediAc,
    AlgorithmKind::SwitchMl,
    AlgorithmKind::OmniReduce,
    AlgorithmKind::Libra,
];

/// Run one scenario on one PS profile.
pub fn run_row(
    dataset: DatasetKind,
    partition: Partition,
    target: f64,
    ps: PsProfile,
    scale: &Scale,
    opts: &RunOptions,
) -> Result<TableRow> {
    let mut reached = Vec::new();
    let mut fediac_mb = None;
    let mut best_baseline_mb: Option<f64> = None;
    for alg in TABLE_ALGOS {
        let mut cfg = ExperimentConfig::preset(dataset, partition);
        scale.apply(&mut cfg);
        cfg.algorithm = alg;
        cfg.ps = ps.clone();
        let rec = runner::run(&cfg, opts)?;
        if let Some((_round, time, traffic)) = rec.time_to_accuracy(target) {
            let mb = traffic.total_mb();
            reached.push((alg, mb, time));
            if alg == AlgorithmKind::FediAc {
                fediac_mb = Some(mb);
            } else {
                best_baseline_mb =
                    Some(best_baseline_mb.map_or(mb, |b: f64| b.min(mb)));
            }
        }
    }
    let reduction_pct = match (fediac_mb, best_baseline_mb) {
        (Some(f), Some(b)) if b > 0.0 => Some((1.0 - f / b) * 100.0),
        _ => None,
    };
    Ok(TableRow {
        scenario: format!("{}_{}", dataset.name(), partition.name()),
        target_accuracy: target,
        reached,
        reduction_pct,
    })
}

/// Render rows in the paper's table format.
pub fn render(rows: &[TableRow], ps_name: &str) -> String {
    let mut out = format!(
        "# Table (PS = {ps_name}): traffic to target accuracy\n\
         scenario\ttarget\talgorithm\ttraffic_mb\tsim_time_s\treduction_vs_best_baseline\n"
    );
    for row in rows {
        if row.reached.is_empty() {
            out.push_str(&format!(
                "{}\t{:.2}\t(none reached target)\t-\t-\t-\n",
                row.scenario, row.target_accuracy
            ));
            continue;
        }
        for (alg, mb, time) in &row.reached {
            let red = if *alg == AlgorithmKind::FediAc {
                row.reduction_pct
                    .map(|p| format!("{p:.2}%"))
                    .unwrap_or_else(|| "-".into())
            } else {
                "-".into()
            };
            out.push_str(&format!(
                "{}\t{:.2}\t{}\t{:.1}\t{:.1}\t{}\n",
                row.scenario,
                row.target_accuracy,
                alg.name(),
                mb,
                time,
                red
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_reports_reduction_when_fediac_wins() {
        // Tiny-scale race: all algorithms on the easy synthetic task.
        let scale = Scale { rounds: 10, num_clients: 4, ..Scale::quick() };
        let row = run_row(
            DatasetKind::Tiny,
            Partition::Iid,
            0.5,
            PsProfile::high(),
            &scale,
            &RunOptions::default(),
        )
        .unwrap();
        // At this scale everyone usually reaches 0.5; the render must not
        // panic regardless of who did.
        let txt = render(&[row], "high");
        assert!(txt.contains("scenario"));
    }
}
