//! E4 / Fig. 3: robustness to the non-IID degree.
//!
//! Dirichlet β swept over {0.3, 0.5, 1, 5} on synthetic CIFAR-10 with a
//! fixed training-time budget; FediAC vs libra (the second-best baseline
//! in the CIFAR-10 non-IID scenario), on both PS profiles. The paper's
//! shape: accuracy rises as β grows (weaker skew), and FediAC stays above
//! libra everywhere.

use anyhow::Result;

use crate::configx::{
    AlgorithmKind, DatasetKind, ExperimentConfig, Partition, PsProfile,
};
use crate::experiments::{runner, RunOptions, Scale};

/// Dirichlet β grid of the paper's Fig. 3.
pub const BETAS: [f64; 4] = [0.3, 0.5, 1.0, 5.0];
/// Algorithms compared in Fig. 3.
pub const FIG3_ALGOS: [AlgorithmKind; 2] = [AlgorithmKind::FediAc, AlgorithmKind::Libra];

/// (β, algorithm, final accuracy) grid for one PS profile.
pub fn run_sweep(
    ps: PsProfile,
    scale: &Scale,
    opts: &RunOptions,
    betas: &[f64],
) -> Result<Vec<(f64, AlgorithmKind, f64)>> {
    let mut out = Vec::new();
    for &beta in betas {
        for alg in FIG3_ALGOS {
            let mut cfg =
                ExperimentConfig::preset(DatasetKind::SynthCifar10, Partition::Dirichlet(beta));
            scale.apply(&mut cfg);
            cfg.algorithm = alg;
            cfg.ps = ps.clone();
            // Paper: "Each algorithm is set up with a training time of
            // 500 s" — fixed wall-clock budget, rounds only as a cap.
            cfg.sim_time_limit_s = scale.sim_time_limit_s.or(Some(500.0));
            let rec = runner::run(&cfg, opts)?;
            let acc = rec
                .records
                .iter()
                .rev()
                .find_map(|r| r.test_accuracy)
                .unwrap_or(0.0);
            out.push((beta, alg, acc));
        }
    }
    Ok(out)
}

/// Render the sweep grid as a TSV block.
pub fn render(results: &[(f64, AlgorithmKind, f64)], ps_name: &str) -> String {
    let mut out = format!(
        "# fig3 (PS = {ps_name}): final accuracy vs Dirichlet beta\n\
         beta\talgorithm\taccuracy\n"
    );
    for (beta, alg, acc) in results {
        out.push_str(&format!("{beta}\t{}\t{acc:.4}\n", alg.name()));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_grid() {
        let scale = Scale { rounds: 3, num_clients: 4, ..Scale::quick() };
        let res =
            run_sweep(PsProfile::high(), &scale, &RunOptions::default(), &[0.5, 5.0])
                .unwrap();
        assert_eq!(res.len(), 4); // 2 betas × 2 algorithms
        let txt = render(&res, "high");
        assert!(txt.contains("fediac") && txt.contains("libra"));
    }
}
