//! Perf-trend gate: compare a fresh `BENCH_WIRE.json` /
//! `BENCH_CODEC.json` against a committed baseline and fail CI when
//! throughput or tail latency regresses beyond a tolerance band.
//!
//! The baseline is a plain copy of a known-good bench report (only the
//! keys compared here are read, so a hand-written floor file works
//! too). Refreshing it after an intentional perf change is one line:
//!
//! ```text
//! cp BENCH_WIRE.json bench_baseline.json   # and commit
//! ```
//!
//! Tolerances are deliberately wide (CI runners are noisy): the gate is
//! a ratchet against *catastrophic* regressions — a halved rounds/s, a
//! p99 that blows out past 4× — not a microbenchmark referee.
//! `fediac trend-gate` is the CLI entry point.

use anyhow::{anyhow, bail, Result};

use crate::util::json::Json;

/// Tolerance band for the trend comparison.
#[derive(Debug, Clone, Copy)]
pub struct GateConfig {
    /// Largest tolerated fractional throughput drop, e.g. 0.5 means a
    /// leg may lose up to half its baseline rounds/s (or Melems/s).
    pub max_throughput_drop: f64,
    /// Largest tolerated p99-latency growth factor, e.g. 4.0 means the
    /// current p99 may be at most 4× the baseline p99.
    pub max_latency_ratio: f64,
}

impl Default for GateConfig {
    fn default() -> Self {
        GateConfig { max_throughput_drop: 0.5, max_latency_ratio: 4.0 }
    }
}

/// One tolerance-band violation.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    /// Which report leg regressed (backend name, shard, kernel, swarm).
    pub leg: String,
    /// The compared metric, e.g. `rounds_per_s`.
    pub metric: String,
    /// The baseline value.
    pub baseline: f64,
    /// The freshly measured value.
    pub current: f64,
    /// The tolerance-band limit the current value violated.
    pub limit: f64,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: {} regressed: baseline {:.2}, current {:.2}, limit {:.2}",
            self.leg, self.metric, self.baseline, self.current, self.limit
        )
    }
}

fn field_f64(j: &Json, leg: &str, path: &[&str]) -> Result<f64> {
    let mut cur = j;
    for key in path {
        cur = cur
            .get(key)
            .ok_or_else(|| anyhow!("leg '{leg}': report missing '{}'", path.join(".")))?;
    }
    cur.as_f64().ok_or_else(|| anyhow!("leg '{leg}': '{}' is not a number", path.join(".")))
}

/// Compare one leg's throughput (higher is better) and p99 latency
/// (lower is better) against the baseline leg.
fn gate_leg(
    leg: &str,
    base: &Json,
    cur: &Json,
    throughput_key: &str,
    cfg: &GateConfig,
    findings: &mut Vec<Finding>,
) -> Result<()> {
    let base_rps = field_f64(base, leg, &[throughput_key])?;
    let cur_rps = field_f64(cur, leg, &[throughput_key])?;
    let floor = base_rps * (1.0 - cfg.max_throughput_drop);
    if cur_rps < floor {
        findings.push(Finding {
            leg: leg.to_string(),
            metric: throughput_key.to_string(),
            baseline: base_rps,
            current: cur_rps,
            limit: floor,
        });
    }
    let base_p99 = field_f64(base, leg, &["round_latency_us", "p99"])?;
    let cur_p99 = field_f64(cur, leg, &["round_latency_us", "p99"])?;
    // A zero baseline p99 (sub-microsecond smoke rounds) gives no
    // meaningful ratio; skip rather than divide by zero.
    if base_p99 > 0.0 {
        let ceil = base_p99 * cfg.max_latency_ratio;
        if cur_p99 > ceil {
            findings.push(Finding {
                leg: leg.to_string(),
                metric: "round_latency_us.p99".to_string(),
                baseline: base_p99,
                current: cur_p99,
                limit: ceil,
            });
        }
    }
    Ok(())
}

/// Gate a fresh BENCH_WIRE.json against its baseline: every baseline
/// backend leg (and the swarm leg, when the baseline has one) must
/// exist in the current report and stay inside the tolerance band on
/// rounds/s and p99 round latency. Returns the violations; malformed
/// or structurally mismatched reports are hard `Err`s.
pub fn gate_wire(baseline: &Json, current: &Json, cfg: &GateConfig) -> Result<Vec<Finding>> {
    let base_legs = baseline
        .get("backends")
        .and_then(|b| b.as_arr())
        .ok_or_else(|| anyhow!("baseline: missing 'backends' array"))?;
    let cur_legs = current
        .get("backends")
        .and_then(|b| b.as_arr())
        .ok_or_else(|| anyhow!("current: missing 'backends' array"))?;
    if base_legs.is_empty() {
        bail!("baseline: 'backends' is empty — refresh it from a real bench run");
    }
    let mut findings = Vec::new();
    for base in base_legs {
        let name = base
            .get("backend")
            .and_then(|n| n.as_str())
            .ok_or_else(|| anyhow!("baseline: backend leg missing 'backend' name"))?;
        let cur = cur_legs
            .iter()
            .find(|l| l.get("backend").and_then(|n| n.as_str()) == Some(name))
            .ok_or_else(|| anyhow!("current report lost the '{name}' backend leg"))?;
        gate_leg(name, base, cur, "rounds_per_s", cfg, &mut findings)?;
    }
    if let Some(base_swarm) = baseline.get("swarm") {
        let cur_swarm =
            current.get("swarm").ok_or_else(|| anyhow!("current report lost the swarm leg"))?;
        gate_leg("swarm", base_swarm, cur_swarm, "rounds_per_s", cfg, &mut findings)?;
    }
    Ok(findings)
}

/// Gate a fresh BENCH_CODEC.json against its baseline: every baseline
/// kernel must hold its `fast_melems_s` inside the throughput band, and
/// `frame_encode.steady_misses` must stay zero when the baseline's was
/// zero (the allocation-free emission guarantee).
pub fn gate_codec(baseline: &Json, current: &Json, cfg: &GateConfig) -> Result<Vec<Finding>> {
    let base_kernels = baseline
        .get("kernels")
        .and_then(|k| k.as_arr())
        .ok_or_else(|| anyhow!("baseline: missing 'kernels' array"))?;
    let cur_kernels = current
        .get("kernels")
        .and_then(|k| k.as_arr())
        .ok_or_else(|| anyhow!("current: missing 'kernels' array"))?;
    let mut findings = Vec::new();
    for base in base_kernels {
        let name = base
            .get("name")
            .and_then(|n| n.as_str())
            .ok_or_else(|| anyhow!("baseline: kernel entry missing 'name'"))?;
        let cur = cur_kernels
            .iter()
            .find(|k| k.get("name").and_then(|n| n.as_str()) == Some(name))
            .ok_or_else(|| anyhow!("current report lost the '{name}' kernel"))?;
        let base_rate = field_f64(base, name, &["fast_melems_s"])?;
        let cur_rate = field_f64(cur, name, &["fast_melems_s"])?;
        let floor = base_rate * (1.0 - cfg.max_throughput_drop);
        if cur_rate < floor {
            findings.push(Finding {
                leg: name.to_string(),
                metric: "fast_melems_s".to_string(),
                baseline: base_rate,
                current: cur_rate,
                limit: floor,
            });
        }
    }
    let base_misses = field_f64(baseline, "frame_encode", &["frame_encode", "steady_misses"])?;
    let cur_misses = field_f64(current, "frame_encode", &["frame_encode", "steady_misses"])?;
    if base_misses == 0.0 && cur_misses > 0.0 {
        findings.push(Finding {
            leg: "frame_encode".to_string(),
            metric: "steady_misses".to_string(),
            baseline: base_misses,
            current: cur_misses,
            limit: 0.0,
        });
    }
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn wire_report(threaded_rps: f64, reactor_rps: f64, threaded_p99: u64) -> Json {
        json::parse(&format!(
            r#"{{"backends": [
                 {{"backend": "threaded", "rounds_per_s": {threaded_rps},
                  "round_latency_us": {{"count": 4, "p50": 100, "p90": 200,
                                        "p99": {threaded_p99}, "max": 9000}}}},
                 {{"backend": "reactor", "rounds_per_s": {reactor_rps},
                  "round_latency_us": {{"count": 4, "p50": 100, "p90": 200,
                                        "p99": 400, "max": 9000}}}}],
                "swarm": {{"rounds_per_s": 500.0,
                           "round_latency_us": {{"count": 64, "p50": 50, "p90": 90,
                                                 "p99": 200, "max": 400}}}}}}"#,
        ))
        .unwrap()
    }

    #[test]
    fn clean_run_produces_no_findings() {
        let base = wire_report(100.0, 120.0, 300);
        let cur = wire_report(90.0, 130.0, 350);
        let findings = gate_wire(&base, &cur, &GateConfig::default()).unwrap();
        assert!(findings.is_empty(), "{findings:?}");
    }

    #[test]
    fn synthetic_throughput_regression_is_detected() {
        let base = wire_report(100.0, 120.0, 300);
        // The reactor leg loses 75% of its rounds/s — past the 50% band.
        let cur = wire_report(95.0, 30.0, 300);
        let findings = gate_wire(&base, &cur, &GateConfig::default()).unwrap();
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].leg, "reactor");
        assert_eq!(findings[0].metric, "rounds_per_s");
        assert!(findings[0].to_string().contains("regressed"));
    }

    #[test]
    fn synthetic_latency_regression_is_detected() {
        let base = wire_report(100.0, 120.0, 300);
        // Threaded p99 blows out 10×, throughput unchanged.
        let cur = wire_report(100.0, 120.0, 3000);
        let findings = gate_wire(&base, &cur, &GateConfig::default()).unwrap();
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].leg, "threaded");
        assert_eq!(findings[0].metric, "round_latency_us.p99");
    }

    #[test]
    fn swarm_leg_regression_is_detected() {
        let base = wire_report(100.0, 120.0, 300);
        let mut cur = wire_report(100.0, 120.0, 300);
        // Rebuild the current report with a collapsed swarm leg.
        if let Json::Obj(map) = &mut cur {
            map.insert(
                "swarm".to_string(),
                json::parse(
                    r#"{"rounds_per_s": 10.0,
                        "round_latency_us": {"count": 64, "p50": 50, "p90": 90,
                                             "p99": 200, "max": 400}}"#,
                )
                .unwrap(),
            );
        }
        let findings = gate_wire(&base, &cur, &GateConfig::default()).unwrap();
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].leg, "swarm");
    }

    #[test]
    fn lost_backend_leg_is_a_hard_error() {
        let base = wire_report(100.0, 120.0, 300);
        let cur = json::parse(
            r#"{"backends": [{"backend": "threaded", "rounds_per_s": 100.0,
                "round_latency_us": {"p99": 300}}]}"#,
        )
        .unwrap();
        let err = gate_wire(&base, &cur, &GateConfig::default()).unwrap_err();
        assert!(err.to_string().contains("reactor"), "{err}");
    }

    #[test]
    fn zero_baseline_p99_skips_the_ratio_check() {
        let base = wire_report(100.0, 120.0, 0);
        let cur = wire_report(100.0, 120.0, 5000);
        let findings = gate_wire(&base, &cur, &GateConfig::default()).unwrap();
        assert!(findings.is_empty(), "{findings:?}");
    }

    fn codec_report(golomb: f64, misses: u64) -> Json {
        json::parse(&format!(
            r#"{{"kernels": [
                 {{"name": "golomb_decode", "fast_melems_s": {golomb}}},
                 {{"name": "lane_add", "fast_melems_s": 900.0}}],
                "frame_encode": {{"steady_misses": {misses}}}}}"#,
        ))
        .unwrap()
    }

    #[test]
    fn codec_kernel_and_pool_regressions_are_detected() {
        let base = codec_report(400.0, 0);
        let ok = gate_codec(&base, &codec_report(380.0, 0), &GateConfig::default()).unwrap();
        assert!(ok.is_empty(), "{ok:?}");
        let slow = gate_codec(&base, &codec_report(100.0, 0), &GateConfig::default()).unwrap();
        assert_eq!(slow.len(), 1, "{slow:?}");
        assert_eq!(slow[0].leg, "golomb_decode");
        let leak = gate_codec(&base, &codec_report(400.0, 3), &GateConfig::default()).unwrap();
        assert_eq!(leak.len(), 1, "{leak:?}");
        assert_eq!(leak[0].metric, "steady_misses");
    }
}
