//! `fediac bench-wire`: drive real serve + client FediAC rounds over
//! loopback UDP and report **rounds/s** and **bytes/round** per I/O
//! backend (`--io threaded` / `reactor` / `fleet`) — the first step of
//! the ROADMAP "cross-machine benches" item. The fleet leg
//! (`--io fleet --cores N`) additionally reports per-core rounds/s and
//! round-latency percentiles from each core's private stats block. Unlike `benches/bench_round`,
//! which times the in-process simulator, this exercises the whole wire
//! stack: codec, daemon backend, retransmission timers and the client
//! driver, on real sockets.
//!
//! Byte accounting is client-side ([`ClientStats::bytes_sent`] /
//! [`ClientStats::bytes_received`]), so the number is what a deployment
//! would meter at the edge: uplink data + downlink broadcasts +
//! acks/polls + retransmissions.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::client::swarm::{self, SwarmOptions, SwarmReport};
use crate::client::{ClientOptions, ClientStats, FediacClient, ShardedFediacClient};
use crate::configx::PsProfile;
use crate::net::ChaosDirection;
use crate::server::{serve, serve_sharded, IoBackend, ServeOptions, StatsSnapshot};
use crate::telemetry::HistSummary;
use crate::util::Rng;
use crate::wire::DEFAULT_PAYLOAD_BUDGET;

/// Workload shape for one bench run (applied to every backend measured).
#[derive(Debug, Clone)]
pub struct BenchWireOptions {
    /// Concurrent jobs (tenants) on the daemon.
    pub jobs: usize,
    /// FediAC rounds each job executes.
    pub rounds: usize,
    /// Clients per job (all must finish each round).
    pub clients_per_job: u16,
    /// Model dimension d per job.
    pub d: usize,
    /// Payload bytes per data frame.
    pub payload_budget: usize,
    /// Switch profile for the daemon (register memory drives waves).
    pub profile: PsProfile,
    /// Backends to measure, in order.
    pub backends: Vec<IoBackend>,
    /// Fleet cores for the fleet legs (`--cores`; 0 = auto-size to the
    /// host). Ignored by the single-socket backends.
    pub cores: usize,
    /// Collaborating shard servers (1 = a single daemon; N > 1 drives
    /// `serve_sharded` + the sharded fan-out client and reports
    /// per-shard stats). `d` at `payload_budget` must give every shard
    /// at least one vote block.
    pub shards: u8,
    /// Seed for the synthetic update streams (shared by every client of
    /// a job, as the protocol requires).
    pub seed: u64,
    /// Also measure the swarm multiplexer (`--swarm`): the same
    /// jobs × clients_per_job workload hosted by ONE client thread over
    /// [`BenchWireOptions::swarm_sockets`] sockets against a reactor
    /// daemon (unsharded — the swarm is a single-server backend).
    pub swarm: bool,
    /// UDP sockets the swarm leg spreads its jobs over.
    pub swarm_sockets: usize,
    /// Downlink chaos at the daemon (`--down-*`): measure under seeded
    /// loss/dup/reorder/corruption instead of a clean loopback. `None`
    /// = clean (the trend-gated CI configuration).
    pub downlink_chaos: Option<ChaosDirection>,
    /// Seed for the chaos lanes (`--chaos-seed`; defaults to the
    /// workload seed so one number replays workload and faults).
    pub chaos_seed: u64,
}

impl Default for BenchWireOptions {
    fn default() -> Self {
        BenchWireOptions {
            jobs: 4,
            rounds: 3,
            clients_per_job: 2,
            d: 4096,
            payload_budget: DEFAULT_PAYLOAD_BUDGET,
            profile: PsProfile::high(),
            backends: vec![IoBackend::Threaded, IoBackend::Reactor, IoBackend::Fleet],
            cores: 0,
            shards: 1,
            seed: 7,
            swarm: false,
            swarm_sockets: swarm::MAX_SWARM_SOCKETS,
            downlink_chaos: None,
            chaos_seed: 7,
        }
    }
}

impl BenchWireOptions {
    /// Tiny CI-friendly workload (`fediac bench-wire --smoke`): seconds,
    /// not minutes, but still both backends end-to-end over sockets.
    pub fn smoke() -> Self {
        BenchWireOptions {
            jobs: 2,
            rounds: 1,
            clients_per_job: 1,
            d: 512,
            payload_budget: 256,
            ..BenchWireOptions::default()
        }
    }
}

/// One backend's measurements.
#[derive(Debug, Clone)]
pub struct BackendReport {
    /// Backend name (`"threaded"` / `"reactor"` / `"fleet"`).
    pub backend: &'static str,
    /// Event cores backing the daemon (1 except for the fleet).
    pub cores: usize,
    /// Wall-clock seconds for the whole workload.
    pub wall_s: f64,
    /// Completed rounds (jobs × rounds) per wall-clock second.
    pub rounds_per_s: f64,
    /// Client-metered bytes (sent + received) per completed round.
    pub bytes_per_round: f64,
    /// Total client-metered bytes.
    pub client_bytes: u64,
    /// Frames retransmitted across all clients (loopback should be ~0).
    pub retransmissions: u64,
    /// Client-observed end-to-end round latency (one sample per
    /// completed `run_round` call, merged across every client of every
    /// job) — the p50/p99/max the JSON report quotes per backend.
    pub round_latency: HistSummary,
    /// Deployment-wide daemon counters (summed across shards).
    pub server: StatsSnapshot,
    /// Per-shard daemon counters, index = shard id (one entry for an
    /// unsharded run). Each shard completes every client round, so its
    /// `rounds_completed / wall_s` is that shard's rounds/s.
    pub per_shard: Vec<StatsSnapshot>,
    /// Per-core daemon counters for an unsharded fleet leg, index =
    /// core id (empty for the single-socket backends and for sharded
    /// runs, where the per-shard split is the interesting axis). A
    /// core's `rounds_completed / wall_s` is that core's rounds/s; its
    /// histograms carry the core's own round-latency percentiles.
    pub per_core: Vec<StatsSnapshot>,
}

/// The swarm leg's measurements (`--swarm`): one client thread hosting
/// the whole fleet, reported alongside the thread-per-client backends.
#[derive(Debug, Clone)]
pub struct SwarmLegReport {
    /// The multiplexer's own report (fleet size, latency, counters).
    pub report: SwarmReport,
    /// Completed job-rounds (jobs × rounds) per wall-clock second — the
    /// same definition the [`BackendReport`]s use, so the columns
    /// compare directly.
    pub rounds_per_s: f64,
    /// Client-metered bytes (sent + received) per completed job-round.
    pub bytes_per_round: f64,
    /// Daemon counters behind the swarm (always the reactor backend).
    pub server: StatsSnapshot,
}

/// A full bench run: the workload shape plus one report per backend.
#[derive(Debug, Clone)]
pub struct BenchWireReport {
    /// The workload that produced these numbers.
    pub opts: BenchWireOptions,
    /// One entry per measured backend, in run order.
    pub backends: Vec<BackendReport>,
    /// The swarm-multiplexer leg, when `--swarm` was requested.
    pub swarm: Option<SwarmLegReport>,
}

/// Render a latency summary as the JSON object the report embeds:
/// `{"count": …, "p50": …, "p90": …, "p99": …, "max": …}` (microseconds).
fn hist_json(h: &HistSummary) -> String {
    format!(
        "{{\"count\": {}, \"p50\": {}, \"p90\": {}, \"p99\": {}, \"max\": {}}}",
        h.count(),
        h.quantile(0.50),
        h.quantile(0.90),
        h.quantile(0.99),
        h.max
    )
}

impl BenchWireReport {
    /// Serialise to the `BENCH_WIRE.json` schema (hand-rolled — the
    /// crate builds offline without a JSON serializer).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!(
            "  \"config\": {{\"jobs\": {}, \"rounds\": {}, \"clients_per_job\": {}, \
             \"d\": {}, \"payload_budget\": {}, \"shards\": {}, \"seed\": {}}},\n",
            self.opts.jobs,
            self.opts.rounds,
            self.opts.clients_per_job,
            self.opts.d,
            self.opts.payload_budget,
            self.opts.shards,
            self.opts.seed
        ));
        out.push_str("  \"backends\": [\n");
        for (i, b) in self.backends.iter().enumerate() {
            let per_shard: Vec<String> = b
                .per_shard
                .iter()
                .enumerate()
                .map(|(s, st)| {
                    format!(
                        "{{\"shard\": {s}, \"rounds_per_s\": {:.3}, \"packets\": {}, \
                         \"rounds_completed\": {}, \"pool_misses\": {}, \
                         \"round_latency_us\": {}}}",
                        st.rounds_completed as f64 / b.wall_s,
                        st.packets,
                        st.rounds_completed,
                        st.pool_misses,
                        hist_json(&st.hist_round_latency)
                    )
                })
                .collect();
            // Per-core split of the fleet leg: each core's own counters
            // and round-latency histogram (rounds complete on the job's
            // owner core, so the rounds_per_s split is the ownership
            // split).
            let per_core: Vec<String> = b
                .per_core
                .iter()
                .enumerate()
                .map(|(c, st)| {
                    format!(
                        "{{\"core\": {c}, \"rounds_per_s\": {:.3}, \"packets\": {}, \
                         \"rounds_completed\": {}, \"steered_frames\": {}, \
                         \"round_latency_us\": {}}}",
                        st.rounds_completed as f64 / b.wall_s,
                        st.packets,
                        st.rounds_completed,
                        st.steered_frames,
                        hist_json(&st.hist_round_latency)
                    )
                })
                .collect();
            out.push_str(&format!(
                "    {{\"backend\": \"{}\", \"cores\": {}, \"wall_s\": {:.6}, \
                 \"rounds_per_s\": {:.3}, \
                 \"bytes_per_round\": {:.1}, \"client_bytes\": {}, \"retransmissions\": {}, \
                 \"server_packets\": {}, \"rounds_completed\": {}, \"workers_spawned\": {}, \
                 \"idle_wakeups\": {}, \"frames_pooled\": {}, \"pool_misses\": {}, \
                 \"steered_frames\": {}, \"round_latency_us\": {}, \"per_shard\": [{}], \
                 \"per_core\": [{}]}}{}\n",
                b.backend,
                b.cores,
                b.wall_s,
                b.rounds_per_s,
                b.bytes_per_round,
                b.client_bytes,
                b.retransmissions,
                b.server.packets,
                b.server.rounds_completed,
                b.server.workers_spawned,
                b.server.idle_wakeups,
                b.server.frames_pooled,
                b.server.pool_misses,
                b.server.steered_frames,
                hist_json(&b.round_latency),
                per_shard.join(", "),
                per_core.join(", "),
                if i + 1 < self.backends.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]");
        if let Some(s) = &self.swarm {
            let r = &s.report;
            out.push_str(&format!(
                ",\n  \"swarm\": {{\"clients_hosted\": {}, \"jobs\": {}, \"sockets\": {}, \
                 \"wall_s\": {:.6}, \"rounds_per_s\": {:.3}, \"bytes_per_round\": {:.1}, \
                 \"client_rounds\": {}, \"retransmissions\": {}, \"pending_dropped\": {}, \
                 \"server_packets\": {}, \"workers_spawned\": {}, \"round_latency_us\": {}}}",
                r.clients_hosted,
                r.jobs,
                r.sockets_used,
                r.wall_s,
                s.rounds_per_s,
                s.bytes_per_round,
                r.rounds_completed,
                r.stats.retransmissions,
                r.stats.pending_dropped,
                s.server.packets,
                s.server.workers_spawned,
                hist_json(&r.round_latency)
            ));
        }
        out.push_str("\n}\n");
        out
    }

    /// Human-readable TSV block (the shape the other `bench_*` targets
    /// print).
    pub fn render(&self) -> String {
        let mut out = format!(
            "# bench_wire: jobs={} rounds={} clients/job={} d={} payload={} shards={}\n\
             backend\twall_s\trounds/s\tbytes/round\tretx\tserver_pkts\tworkers\tidle_wakes\
             \tpool_miss\tp50_us\tp99_us\tmax_us\n",
            self.opts.jobs,
            self.opts.rounds,
            self.opts.clients_per_job,
            self.opts.d,
            self.opts.payload_budget,
            self.opts.shards
        );
        for b in &self.backends {
            out.push_str(&format!(
                "{}\t{:.3}\t{:.1}\t{:.0}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
                b.backend,
                b.wall_s,
                b.rounds_per_s,
                b.bytes_per_round,
                b.retransmissions,
                b.server.packets,
                b.server.workers_spawned,
                b.server.idle_wakeups,
                b.server.pool_misses,
                b.round_latency.quantile(0.50),
                b.round_latency.quantile(0.99),
                b.round_latency.max
            ));
            if b.per_shard.len() > 1 {
                for (s, st) in b.per_shard.iter().enumerate() {
                    out.push_str(&format!(
                        "  shard{}\t\t{:.1}\t\t\t{}\t\t\n",
                        s,
                        st.rounds_completed as f64 / b.wall_s,
                        st.packets
                    ));
                }
            }
            if b.per_core.len() > 1 {
                for (c, st) in b.per_core.iter().enumerate() {
                    out.push_str(&format!(
                        "  core{}\t\t{:.1}\t\t\t{}\t\t\t\t\t{}\t{}\t{}\n",
                        c,
                        st.rounds_completed as f64 / b.wall_s,
                        st.packets,
                        st.hist_round_latency.quantile(0.50),
                        st.hist_round_latency.quantile(0.99),
                        st.hist_round_latency.max
                    ));
                }
            }
        }
        if let Some(s) = &self.swarm {
            let r = &s.report;
            out.push_str(&format!(
                "swarm({}c/{}s)\t{:.3}\t{:.1}\t{:.0}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\t{}\n",
                r.clients_hosted,
                r.sockets_used,
                r.wall_s,
                s.rounds_per_s,
                s.bytes_per_round,
                r.stats.retransmissions,
                s.server.packets,
                s.server.workers_spawned,
                s.server.idle_wakeups,
                s.server.pool_misses,
                r.round_latency.quantile(0.50),
                r.round_latency.quantile(0.99),
                r.round_latency.max,
            ));
        }
        out
    }
}

/// Run the workload once per requested backend and collect the reports.
pub fn run(opts: &BenchWireOptions) -> Result<BenchWireReport> {
    anyhow::ensure!(opts.jobs > 0 && opts.rounds > 0, "jobs and rounds must be > 0");
    anyhow::ensure!(opts.clients_per_job > 0, "clients_per_job must be > 0");
    anyhow::ensure!(
        (1..=crate::wire::MAX_SHARDS).contains(&opts.shards),
        "shards must be in [1, {}]",
        crate::wire::MAX_SHARDS
    );
    let mut backends = Vec::with_capacity(opts.backends.len());
    for &backend in &opts.backends {
        backends.push(run_backend(opts, backend)?);
    }
    let swarm = if opts.swarm {
        anyhow::ensure!(opts.shards == 1, "--swarm is a single-server backend (shards must be 1)");
        Some(run_swarm_leg(opts)?)
    } else {
        None
    };
    Ok(BenchWireReport { opts: opts.clone(), backends, swarm })
}

/// The `--swarm` leg: the same jobs × clients_per_job synthetic workload
/// the thread-per-client backends run, but hosted by the single-thread
/// swarm multiplexer against a reactor daemon.
fn run_swarm_leg(opts: &BenchWireOptions) -> Result<SwarmLegReport> {
    let serve_opts = ServeOptions {
        profile: opts.profile.clone(),
        io_backend: IoBackend::Reactor,
        downlink_chaos: opts.downlink_chaos,
        chaos_seed: opts.chaos_seed,
        ..ServeOptions::default()
    };
    let handle = serve(&serve_opts).context("starting swarm-leg reactor daemon")?;
    let mut sopts = SwarmOptions::new(handle.local_addr().to_string(), opts.d);
    sopts.jobs = swarm::plan_fleet(
        opts.jobs * opts.clients_per_job as usize,
        opts.clients_per_job,
        opts.seed,
    );
    sopts.rounds = opts.rounds;
    sopts.payload_budget = opts.payload_budget;
    sopts.sockets = opts.swarm_sockets;
    sopts.chaos_seed = opts.chaos_seed;
    let report = swarm::run(&sopts).context("swarm bench leg")?;
    let server = handle.stats();
    handle.shutdown();
    let total_rounds = (opts.jobs * opts.rounds) as f64;
    let client_bytes = report.stats.bytes_sent + report.stats.bytes_received;
    Ok(SwarmLegReport {
        rounds_per_s: total_rounds / report.wall_s,
        bytes_per_round: client_bytes as f64 / total_rounds,
        server,
        report,
    })
}

fn run_backend(opts: &BenchWireOptions, backend: IoBackend) -> Result<BackendReport> {
    let serve_opts = ServeOptions {
        profile: opts.profile.clone(),
        io_backend: backend,
        cores: opts.cores,
        downlink_chaos: opts.downlink_chaos,
        chaos_seed: opts.chaos_seed,
        ..ServeOptions::default()
    };
    // One daemon, or a collaborating shard set on consecutive sockets.
    let handles = if opts.shards > 1 {
        serve_sharded(&serve_opts, opts.shards)
            .with_context(|| format!("starting {} shard set", backend.name()))?
    } else {
        vec![serve(&serve_opts)
            .with_context(|| format!("starting {} daemon", backend.name()))?]
    };
    let addrs: Vec<String> = handles.iter().map(|h| h.local_addr().to_string()).collect();

    let started = Instant::now();
    let mut per_client: Vec<(ClientStats, HistSummary)> = Vec::new();
    std::thread::scope(|scope| -> Result<()> {
        let mut join_handles = Vec::new();
        let addrs = &addrs;
        for job in 0..opts.jobs {
            for cid in 0..opts.clients_per_job {
                join_handles.push(scope.spawn(move || -> Result<(ClientStats, HistSummary)> {
                    drive_client(opts, addrs, job as u32, cid)
                }));
            }
        }
        for h in join_handles {
            per_client.push(h.join().expect("bench client panicked")?);
        }
        Ok(())
    })?;
    let wall_s = started.elapsed().as_secs_f64().max(f64::EPSILON);

    let mut totals = ClientStats::default();
    let mut round_latency = HistSummary::default();
    for (s, lat) in &per_client {
        totals.add(s);
        round_latency.merge(lat);
    }
    let total_rounds = (opts.jobs * opts.rounds) as f64;
    let client_bytes = totals.bytes_sent + totals.bytes_received;
    let per_shard: Vec<StatsSnapshot> = handles.iter().map(|h| h.stats()).collect();
    let mut server = StatsSnapshot::default();
    for st in &per_shard {
        server.merge(st);
    }
    let cores = handles.iter().map(|h| h.cores()).max().unwrap_or(1);
    // The per-core split is reported for the unsharded fleet leg (in a
    // sharded run the per-shard split is the axis that matters).
    let per_core = if backend == IoBackend::Fleet && handles.len() == 1 {
        handles[0].per_core_stats()
    } else {
        Vec::new()
    };
    for h in handles {
        h.shutdown();
    }
    Ok(BackendReport {
        backend: backend.name(),
        cores,
        wall_s,
        rounds_per_s: total_rounds / wall_s,
        bytes_per_round: client_bytes as f64 / total_rounds,
        client_bytes,
        retransmissions: totals.retransmissions,
        round_latency,
        server,
        per_shard,
        per_core,
    })
}

/// One client of one job: join (one server or the whole shard set), run
/// every round on a deterministic synthetic update stream (residual
/// folded in, Algorithm 1), return the driver counters plus a per-round
/// end-to-end latency histogram (one sample per `run_round` call).
fn drive_client(
    opts: &BenchWireOptions,
    addrs: &[String],
    job: u32,
    cid: u16,
) -> Result<(ClientStats, HistSummary)> {
    // Every client of a job shares the job seed (the protocol requires
    // agreement on the vote/quantise RNG streams' derivation root).
    let job_seed = opts.seed ^ ((job as u64) << 16);
    let mut copts =
        ClientOptions::new(addrs[0].clone(), 1000 + job, cid, opts.d, opts.clients_per_job);
    copts.threshold_a = 1;
    copts.payload_budget = opts.payload_budget;
    copts.backend_seed = job_seed;
    enum AnyClient {
        Single(FediacClient),
        Sharded(ShardedFediacClient),
    }
    let mut client = if addrs.len() > 1 {
        AnyClient::Sharded(
            ShardedFediacClient::connect(addrs, copts)
                .with_context(|| format!("connecting sharded bench client {cid} of job {job}"))?,
        )
    } else {
        AnyClient::Single(
            FediacClient::connect(copts)
                .with_context(|| format!("connecting bench client {cid} of job {job}"))?,
        )
    };
    let mut residual = vec![0.0f32; opts.d];
    let mut latency = HistSummary::default();
    for round in 1..=opts.rounds {
        let mut rng = Rng::new(job_seed ^ ((cid as u64) << 32) ^ round as u64);
        let mut update: Vec<f32> =
            (0..opts.d).map(|_| (rng.gaussian() * 0.01) as f32).collect();
        for (u, r) in update.iter_mut().zip(&residual) {
            *u += *r;
        }
        let t0 = Instant::now();
        let out = match &mut client {
            AnyClient::Single(c) => c.run_round(round, &update),
            AnyClient::Sharded(c) => c.run_round(round, &update),
        }
        .with_context(|| format!("job {job} client {cid} round {round}"))?;
        latency.record_micros(t0.elapsed());
        residual = out.residual;
    }
    let stats = match &client {
        AnyClient::Single(c) => c.stats,
        AnyClient::Sharded(c) => c.stats(),
    };
    Ok((stats, latency))
}
