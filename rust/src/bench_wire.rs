//! `fediac bench-wire`: drive real serve + client FediAC rounds over
//! loopback UDP and report **rounds/s** and **bytes/round** per I/O
//! backend (`--io threaded` vs `--io reactor`) — the first step of the
//! ROADMAP "cross-machine benches" item. Unlike `benches/bench_round`,
//! which times the in-process simulator, this exercises the whole wire
//! stack: codec, daemon backend, retransmission timers and the client
//! driver, on real sockets.
//!
//! Byte accounting is client-side ([`ClientStats::bytes_sent`] /
//! [`ClientStats::bytes_received`]), so the number is what a deployment
//! would meter at the edge: uplink data + downlink broadcasts +
//! acks/polls + retransmissions.

use std::time::Instant;

use anyhow::{Context, Result};

use crate::client::{ClientOptions, ClientStats, FediacClient};
use crate::configx::PsProfile;
use crate::server::{serve, IoBackend, ServeOptions, StatsSnapshot};
use crate::util::Rng;
use crate::wire::DEFAULT_PAYLOAD_BUDGET;

/// Workload shape for one bench run (applied to every backend measured).
#[derive(Debug, Clone)]
pub struct BenchWireOptions {
    /// Concurrent jobs (tenants) on the daemon.
    pub jobs: usize,
    /// FediAC rounds each job executes.
    pub rounds: usize,
    /// Clients per job (all must finish each round).
    pub clients_per_job: u16,
    /// Model dimension d per job.
    pub d: usize,
    /// Payload bytes per data frame.
    pub payload_budget: usize,
    /// Switch profile for the daemon (register memory drives waves).
    pub profile: PsProfile,
    /// Backends to measure, in order.
    pub backends: Vec<IoBackend>,
    /// Seed for the synthetic update streams (shared by every client of
    /// a job, as the protocol requires).
    pub seed: u64,
}

impl Default for BenchWireOptions {
    fn default() -> Self {
        BenchWireOptions {
            jobs: 4,
            rounds: 3,
            clients_per_job: 2,
            d: 4096,
            payload_budget: DEFAULT_PAYLOAD_BUDGET,
            profile: PsProfile::high(),
            backends: vec![IoBackend::Threaded, IoBackend::Reactor],
            seed: 7,
        }
    }
}

impl BenchWireOptions {
    /// Tiny CI-friendly workload (`fediac bench-wire --smoke`): seconds,
    /// not minutes, but still both backends end-to-end over sockets.
    pub fn smoke() -> Self {
        BenchWireOptions {
            jobs: 2,
            rounds: 1,
            clients_per_job: 1,
            d: 512,
            payload_budget: 256,
            ..BenchWireOptions::default()
        }
    }
}

/// One backend's measurements.
#[derive(Debug, Clone)]
pub struct BackendReport {
    /// Backend name (`"threaded"` / `"reactor"`).
    pub backend: &'static str,
    /// Wall-clock seconds for the whole workload.
    pub wall_s: f64,
    /// Completed rounds (jobs × rounds) per wall-clock second.
    pub rounds_per_s: f64,
    /// Client-metered bytes (sent + received) per completed round.
    pub bytes_per_round: f64,
    /// Total client-metered bytes.
    pub client_bytes: u64,
    /// Frames retransmitted across all clients (loopback should be ~0).
    pub retransmissions: u64,
    /// The daemon's counters at the end of the workload.
    pub server: StatsSnapshot,
}

/// A full bench run: the workload shape plus one report per backend.
#[derive(Debug, Clone)]
pub struct BenchWireReport {
    /// The workload that produced these numbers.
    pub opts: BenchWireOptions,
    /// One entry per measured backend, in run order.
    pub backends: Vec<BackendReport>,
}

impl BenchWireReport {
    /// Serialise to the `BENCH_WIRE.json` schema (hand-rolled — the
    /// crate builds offline without a JSON serializer).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!(
            "  \"config\": {{\"jobs\": {}, \"rounds\": {}, \"clients_per_job\": {}, \
             \"d\": {}, \"payload_budget\": {}, \"seed\": {}}},\n",
            self.opts.jobs,
            self.opts.rounds,
            self.opts.clients_per_job,
            self.opts.d,
            self.opts.payload_budget,
            self.opts.seed
        ));
        out.push_str("  \"backends\": [\n");
        for (i, b) in self.backends.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"backend\": \"{}\", \"wall_s\": {:.6}, \"rounds_per_s\": {:.3}, \
                 \"bytes_per_round\": {:.1}, \"client_bytes\": {}, \"retransmissions\": {}, \
                 \"server_packets\": {}, \"rounds_completed\": {}, \"workers_spawned\": {}, \
                 \"idle_wakeups\": {}}}{}\n",
                b.backend,
                b.wall_s,
                b.rounds_per_s,
                b.bytes_per_round,
                b.client_bytes,
                b.retransmissions,
                b.server.packets,
                b.server.rounds_completed,
                b.server.workers_spawned,
                b.server.idle_wakeups,
                if i + 1 < self.backends.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Human-readable TSV block (the shape the other `bench_*` targets
    /// print).
    pub fn render(&self) -> String {
        let mut out = format!(
            "# bench_wire: jobs={} rounds={} clients/job={} d={} payload={}\n\
             backend\twall_s\trounds/s\tbytes/round\tretx\tserver_pkts\tworkers\tidle_wakes\n",
            self.opts.jobs,
            self.opts.rounds,
            self.opts.clients_per_job,
            self.opts.d,
            self.opts.payload_budget
        );
        for b in &self.backends {
            out.push_str(&format!(
                "{}\t{:.3}\t{:.1}\t{:.0}\t{}\t{}\t{}\t{}\n",
                b.backend,
                b.wall_s,
                b.rounds_per_s,
                b.bytes_per_round,
                b.retransmissions,
                b.server.packets,
                b.server.workers_spawned,
                b.server.idle_wakeups
            ));
        }
        out
    }
}

/// Run the workload once per requested backend and collect the reports.
pub fn run(opts: &BenchWireOptions) -> Result<BenchWireReport> {
    anyhow::ensure!(opts.jobs > 0 && opts.rounds > 0, "jobs and rounds must be > 0");
    anyhow::ensure!(opts.clients_per_job > 0, "clients_per_job must be > 0");
    let mut backends = Vec::with_capacity(opts.backends.len());
    for &backend in &opts.backends {
        backends.push(run_backend(opts, backend)?);
    }
    Ok(BenchWireReport { opts: opts.clone(), backends })
}

fn run_backend(opts: &BenchWireOptions, backend: IoBackend) -> Result<BackendReport> {
    let handle = serve(&ServeOptions {
        profile: opts.profile.clone(),
        io_backend: backend,
        ..ServeOptions::default()
    })
    .with_context(|| format!("starting {} daemon", backend.name()))?;
    let addr = handle.local_addr();

    let started = Instant::now();
    let mut per_client: Vec<ClientStats> = Vec::new();
    std::thread::scope(|scope| -> Result<()> {
        let mut handles = Vec::new();
        for job in 0..opts.jobs {
            for cid in 0..opts.clients_per_job {
                handles.push(scope.spawn(move || -> Result<ClientStats> {
                    drive_client(opts, addr, job as u32, cid)
                }));
            }
        }
        for h in handles {
            per_client.push(h.join().expect("bench client panicked")?);
        }
        Ok(())
    })?;
    let wall_s = started.elapsed().as_secs_f64().max(f64::EPSILON);

    let mut totals = ClientStats::default();
    for s in &per_client {
        totals.add(s);
    }
    let total_rounds = (opts.jobs * opts.rounds) as f64;
    let client_bytes = totals.bytes_sent + totals.bytes_received;
    let server = handle.stats();
    handle.shutdown();
    Ok(BackendReport {
        backend: backend.name(),
        wall_s,
        rounds_per_s: total_rounds / wall_s,
        bytes_per_round: client_bytes as f64 / total_rounds,
        client_bytes,
        retransmissions: totals.retransmissions,
        server,
    })
}

/// One client of one job: join, run every round on a deterministic
/// synthetic update stream (residual folded in, Algorithm 1), return the
/// driver counters.
fn drive_client(
    opts: &BenchWireOptions,
    addr: std::net::SocketAddr,
    job: u32,
    cid: u16,
) -> Result<ClientStats> {
    // Every client of a job shares the job seed (the protocol requires
    // agreement on the vote/quantise RNG streams' derivation root).
    let job_seed = opts.seed ^ ((job as u64) << 16);
    let mut copts =
        ClientOptions::new(addr.to_string(), 1000 + job, cid, opts.d, opts.clients_per_job);
    copts.threshold_a = 1;
    copts.payload_budget = opts.payload_budget;
    copts.backend_seed = job_seed;
    let mut client = FediacClient::connect(copts)
        .with_context(|| format!("connecting bench client {cid} of job {job}"))?;
    let mut residual = vec![0.0f32; opts.d];
    for round in 1..=opts.rounds {
        let mut rng = Rng::new(job_seed ^ ((cid as u64) << 32) ^ round as u64);
        let mut update: Vec<f32> =
            (0..opts.d).map(|_| (rng.gaussian() * 0.01) as f32).collect();
        for (u, r) in update.iter_mut().zip(&residual) {
            *u += *r;
        }
        let out = client
            .run_round(round, &update)
            .with_context(|| format!("job {job} client {cid} round {round}"))?;
        residual = out.residual;
    }
    Ok(client.stats)
}
