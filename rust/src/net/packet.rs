//! Packet model: 1,500-byte Ethernet frames carrying aggregation payloads.
//!
//! Uploaded model updates "are encapsulated into packets which are then
//! transmitted to the PS; the default size of each packet is 1,500 bytes"
//! (§V-A2). Alignment matters: because the GIA fixes the index order, every
//! FediAC client packs the same number of elements per packet and the PS
//! adds payloads slot-by-slot without reading indices (§IV "Model
//! Aggregation").

/// Which protocol phase a packet belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// FediAC phase 1: packed 0-1 vote arrays.
    Vote,
    /// Data phase: quantised integer model updates.
    Update,
    /// Downstream: GIA or aggregated updates multicast to clients.
    Broadcast,
}

/// Simulation-level packet descriptor. Payload *contents* live in the
/// algorithm state; the descriptor carries what the network/switch needs:
/// identity, sizing and the aggregation slot (block index).
#[derive(Debug, Clone, PartialEq)]
pub struct Packet {
    /// Originating client id.
    pub client: usize,
    /// Global FL iteration.
    pub round: usize,
    /// Protocol phase the packet belongs to.
    pub phase: Phase,
    /// Aggregation block this packet contributes to (slot alignment).
    pub block: usize,
    /// Payload bytes actually carried (≤ payload capacity).
    pub payload_bytes: usize,
    /// Number of logical elements (votes bits / int updates) in the payload.
    pub elements: usize,
}

impl Packet {
    /// Total wire size including protocol headers.
    pub fn wire_bytes(&self, header: usize) -> usize {
        self.payload_bytes + header
    }
}

/// Compute the packet layout for a vector payload.
///
/// `total_bits` of payload are split into MTU-sized frames with
/// `payload_capacity = mtu − header` bytes each. Returns (packet count,
/// last-packet payload bytes).
pub fn frames_for_bits(total_bits: usize, payload_capacity_bytes: usize) -> (usize, usize) {
    if total_bits == 0 {
        return (0, 0);
    }
    let total_bytes = total_bits.div_ceil(8);
    let n = total_bytes.div_ceil(payload_capacity_bytes);
    let last = total_bytes - (n - 1) * payload_capacity_bytes;
    (n, last)
}

/// Build the per-block packet descriptors for one client's upload of
/// `elements` logical values of `bits_per_element` bits each.
///
/// Every client uses the same layout (same element count per packet), so
/// block i from any client aligns with block i from every other client —
/// the property phase 1 buys FediAC (§III-B).
pub fn packetize(
    client: usize,
    round: usize,
    phase: Phase,
    elements: usize,
    bits_per_element: usize,
    payload_capacity_bytes: usize,
) -> Vec<Packet> {
    if elements == 0 {
        return Vec::new();
    }
    let elems_per_packet = (payload_capacity_bytes * 8) / bits_per_element;
    assert!(elems_per_packet > 0, "element larger than packet payload");
    let n = elements.div_ceil(elems_per_packet);
    (0..n)
        .map(|block| {
            let e = if block + 1 == n {
                elements - block * elems_per_packet
            } else {
                elems_per_packet
            };
            Packet {
                client,
                round,
                phase,
                block,
                payload_bytes: (e * bits_per_element).div_ceil(8),
                elements: e,
            }
        })
        .collect()
}

/// Number of elements per full packet for a given encoding.
pub fn elems_per_packet(bits_per_element: usize, payload_capacity_bytes: usize) -> usize {
    (payload_capacity_bytes * 8) / bits_per_element
}

#[cfg(test)]
mod tests {
    use super::*;

    const CAP: usize = 1500 - 62;

    #[test]
    fn frames_for_bits_boundaries() {
        assert_eq!(frames_for_bits(0, CAP), (0, 0));
        assert_eq!(frames_for_bits(8, CAP), (1, 1));
        assert_eq!(frames_for_bits(CAP * 8, CAP), (1, CAP));
        assert_eq!(frames_for_bits(CAP * 8 + 1, CAP), (2, 1));
    }

    #[test]
    fn packetize_alignment_across_clients() {
        // Two clients uploading the same element count produce identical
        // block layouts — the alignment FediAC relies on.
        let a = packetize(0, 3, Phase::Update, 10_000, 32, CAP);
        let b = packetize(1, 3, Phase::Update, 10_000, 32, CAP);
        assert_eq!(a.len(), b.len());
        for (pa, pb) in a.iter().zip(&b) {
            assert_eq!(pa.block, pb.block);
            assert_eq!(pa.elements, pb.elements);
            assert_eq!(pa.payload_bytes, pb.payload_bytes);
        }
        let total: usize = a.iter().map(|p| p.elements).sum();
        assert_eq!(total, 10_000);
    }

    #[test]
    fn packetize_vote_phase_bit_density() {
        // Phase 1 carries one bit per dimension: a 10M-d model fits in
        // ceil(10e6/8 / 1438) ≈ 870 packets (§IV-D's 1.25 MB).
        let pkts = packetize(0, 0, Phase::Vote, 10_000_000, 1, CAP);
        let bytes: usize = pkts.iter().map(|p| p.payload_bytes).sum();
        assert_eq!(bytes, 1_250_000);
        assert_eq!(pkts.len(), 1_250_000_usize.div_ceil(CAP));
    }

    #[test]
    fn last_packet_partial() {
        let pkts = packetize(0, 0, Phase::Update, 7, 32, CAP);
        assert_eq!(pkts.len(), 1);
        assert_eq!(pkts[0].elements, 7);
        assert_eq!(pkts[0].payload_bytes, 28);
    }

    #[test]
    fn wire_bytes_includes_header() {
        let p = Packet {
            client: 0,
            round: 0,
            phase: Phase::Update,
            block: 0,
            payload_bytes: 100,
            elements: 25,
        };
        assert_eq!(p.wire_bytes(62), 162);
    }

    #[test]
    fn elems_per_packet_encodings() {
        assert_eq!(elems_per_packet(32, CAP), CAP * 8 / 32); // 32-bit ints
        assert_eq!(elems_per_packet(1, CAP), CAP * 8); // vote bits
        assert_eq!(elems_per_packet(12, CAP), CAP * 8 / 12); // SwitchML b=12
    }
}
