//! Network substrate: packets, Poisson arrivals, M/G/1 queues, the
//! synthetic cellular traces that drive client upload rates (§V-A2), the
//! deterministic chaos proxy for wire-path failure injection, the
//! seeded client-churn plane behind quorum-round fault testing, and the
//! readiness/timer primitives behind the reactor I/O backend.

pub mod chaos;
pub mod churn;
pub mod mg1;
pub mod packet;
pub mod poisson;
pub mod poll;
pub mod trace;

pub use chaos::{
    chaos_proxy, ChaosConfig, ChaosDirection, ChaosHandle, ChaosLane, ChaosProxyOptions,
    ChaosSnapshot, LaneSnapshot, LaneStats,
};
pub use churn::{ChurnConfig, ChurnPlan, ClientChurn};
pub use mg1::{pollaczek_khinchine, Mg1Queue};
pub use packet::{elems_per_packet, frames_for_bits, packetize, Packet, Phase};
pub use poisson::PoissonProcess;
pub use poll::{wait_readable, TimerWheel};
pub use trace::{client_rates, CellularTrace};
