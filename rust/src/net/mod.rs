//! Network substrate: packets, Poisson arrivals, M/G/1 queues and the
//! synthetic cellular traces that drive client upload rates (§V-A2).

pub mod mg1;
pub mod packet;
pub mod poisson;
pub mod trace;

pub use mg1::{pollaczek_khinchine, Mg1Queue};
pub use packet::{elems_per_packet, frames_for_bits, packetize, Packet, Phase};
pub use poisson::PoissonProcess;
pub use trace::{client_rates, CellularTrace};
