//! Deterministic client-churn plane: a seeded lifecycle injector that
//! decides, ahead of time, which clients die mid-round, which corpses
//! rejoin with stale state, which join late as a flash crowd, and which
//! never come back at all.
//!
//! `net::chaos` attacks the *datagram* path (loss, dup, reorder,
//! corruption); this module attacks the *client* path. Quorum rounds
//! (`JobSpec::quorum`, PROTOCOL.md §11) exist precisely so a federation
//! round survives these faults — the churn plane is the adversary the
//! quorum close rule is measured against, driven by `client::swarm` and
//! exercised end-to-end by the soak harness's `churn` episode class.
//!
//! **Determinism contract.** Mirrors [`crate::net::chaos::ChaosLane`]:
//! every lifecycle decision comes from [`crate::util::Rng`] streams
//! derived from a single seed. Each client forks its own stream
//! (`seed ^ (cid << 16) ^ CHURN_SALT`) and consumes draws in a fixed
//! order — one kill draw per round until the first kill lands, then one
//! kill-point draw, then one permanence draw — so the same
//! `(seed, config, n_clients, rounds)`
//! always produces the identical [`ChurnPlan`], independent of packet
//! timing or scheduling. Flash-crowd membership is structural (the last
//! `flash_crowd` client ids), not drawn, so it cannot perturb the kill
//! streams of other clients.
//!
//! **Fault classes** (all per client, all deterministic per seed):
//!
//! * *kill mid-round* — the client goes dark in round `kill_at_round`,
//!   either at the round's start (nothing sent at all) or mid-phase,
//!   right after its vote upload (`after_vote`: votes land, the update
//!   never does); a quorum round closes without it at the phase
//!   deadline either way;
//! * *rejoin stale* — a killed client (unless permanently dead) comes
//!   back `rejoin_delay` later with its old round counter, discovers the
//!   round closed without it, and re-syncs from the broadcast instead of
//!   retransmitting (`ClientStats::quorum_resyncs`);
//! * *flash crowd* — the last `flash_crowd` clients delay their first
//!   Join by `rejoin_delay`, piling in against rounds already in flight;
//! * *permanent death* — a fraction `permanent_rate` of kills never
//!   rejoin; their host-budget reservation and scoreboard slot are
//!   reclaimed when the quorum round closes.

use std::time::Duration;

use crate::util::Rng;

/// Seed salt so a churn plan and a chaos lane built from the same base
/// seed do not share streams.
const CHURN_SALT: u64 = 0xC4C4_0B17;

/// Churn knobs. `Default` is a quiet plane (nobody dies, nobody is
/// late). Loaded from a preset's `[churn]` section (`configx`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChurnConfig {
    /// Probability a live client is killed at the start of any given
    /// round (drawn once per round until the first kill lands).
    pub kill_rate: f64,
    /// How long a killed client stays dark before rejoining, and how
    /// long flash-crowd clients delay their first Join. Zero means
    /// every kill is permanent.
    pub rejoin_delay: Duration,
    /// How many of the highest client ids join late (flash crowd).
    pub flash_crowd: u16,
    /// Fraction of kills that never rejoin regardless of
    /// `rejoin_delay` (drawn once per killed client).
    pub permanent_rate: f64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            kill_rate: 0.0,
            rejoin_delay: Duration::from_millis(80),
            flash_crowd: 0,
            permanent_rate: 0.25,
        }
    }
}

impl ChurnConfig {
    /// True when the plane will actually do anything.
    pub fn enabled(&self) -> bool {
        self.kill_rate > 0.0 || self.flash_crowd > 0
    }
}

/// One client's predetermined lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClientChurn {
    /// Round in which the client goes dark (`None` = survives the
    /// whole run). Kills land at protocol edges — the round's start or
    /// right after the vote upload — so the set of full-round
    /// contributors stays exactly predictable per seed.
    pub kill_at_round: Option<u32>,
    /// The kill lands after the phase-1 (vote) upload instead of at the
    /// round's start: the victim's votes shape the GIA but its update
    /// never reaches the aggregate (killed mid-upload).
    pub after_vote: bool,
    /// Dark time before the corpse rejoins with stale state. `None`
    /// (with a kill) means permanent death.
    pub rejoin_after: Option<Duration>,
    /// Delay before the client's first Join (zero except for the flash
    /// crowd).
    pub join_delay: Duration,
}

impl ClientChurn {
    /// A client untouched by the plane.
    pub fn quiet() -> Self {
        ClientChurn {
            kill_at_round: None,
            after_vote: false,
            rejoin_after: None,
            join_delay: Duration::ZERO,
        }
    }

    /// True when this client is killed and never comes back.
    pub fn permanent_death(&self) -> bool {
        self.kill_at_round.is_some() && self.rejoin_after.is_none()
    }

    /// True when this client contributes to round `round` from its
    /// start (it has joined on time and has not yet been killed).
    /// Rejoined clients are *not* counted — they come back stale and
    /// re-sync, so their contributions to post-rejoin rounds race the
    /// quorum close and are not part of the guaranteed set.
    pub fn full_participant(&self, round: u32) -> bool {
        self.join_delay.is_zero() && self.kill_at_round.is_none_or(|k| round < k)
    }

    /// True when this client's votes are guaranteed to shape round
    /// `round`'s GIA: every full participant, plus the victim of an
    /// after-vote kill in that round (its votes went out before it
    /// died).
    pub fn guaranteed_voter(&self, round: u32) -> bool {
        self.full_participant(round)
            || (self.join_delay.is_zero() && self.after_vote && self.kill_at_round == Some(round))
    }
}

/// The whole fleet's predetermined lifecycles plus summary counts.
/// Built once per run from `(config, seed, n_clients, rounds)`; every
/// consumer (swarm driver, soak oracle, tests) derives the same plan.
#[derive(Debug, Clone)]
pub struct ChurnPlan {
    per_client: Vec<ClientChurn>,
}

impl ChurnPlan {
    pub fn new(cfg: &ChurnConfig, seed: u64, n_clients: u16, rounds: u32) -> Self {
        let flash_from = n_clients.saturating_sub(cfg.flash_crowd);
        let per_client = (0..n_clients)
            .map(|cid| {
                let mut rng = Rng::new(seed ^ ((cid as u64) << 16) ^ CHURN_SALT);
                let mut plan = ClientChurn::quiet();
                if cid >= flash_from {
                    plan.join_delay = cfg.rejoin_delay;
                }
                // Fixed draw order: one kill draw per round until the
                // first kill, then one kill-point draw, then exactly
                // one permanence draw.
                for round in 1..=rounds {
                    if rng.f64() < cfg.kill_rate {
                        plan.kill_at_round = Some(round);
                        plan.after_vote = rng.f64() < 0.5;
                        let permanent =
                            cfg.rejoin_delay.is_zero() || rng.f64() < cfg.permanent_rate;
                        if !permanent {
                            plan.rejoin_after = Some(cfg.rejoin_delay);
                        }
                        break;
                    }
                }
                plan
            })
            .collect();
        ChurnPlan { per_client }
    }

    /// A plan that touches nobody (churn disabled).
    pub fn quiet(n_clients: u16) -> Self {
        ChurnPlan { per_client: vec![ClientChurn::quiet(); n_clients as usize] }
    }

    pub fn client(&self, cid: u16) -> &ClientChurn {
        &self.per_client[cid as usize]
    }

    pub fn n_clients(&self) -> u16 {
        self.per_client.len() as u16
    }

    /// Clients guaranteed to contribute every frame of round `round`:
    /// joined on time, not yet killed. This is the quorum-aware
    /// reference set the soak oracle aggregates phase-2 updates over.
    pub fn full_participants(&self, round: u32) -> Vec<u16> {
        (0..self.per_client.len() as u16)
            .filter(|&cid| self.per_client[cid as usize].full_participant(round))
            .collect()
    }

    /// Clients whose votes are guaranteed in round `round`'s GIA: the
    /// full participants plus that round's after-vote kill victims —
    /// the quorum-aware reference set for the phase-1 consensus.
    pub fn guaranteed_voters(&self, round: u32) -> Vec<u16> {
        (0..self.per_client.len() as u16)
            .filter(|&cid| self.per_client[cid as usize].guaranteed_voter(round))
            .collect()
    }

    /// Number of clients killed at some point during the run.
    pub fn kills(&self) -> usize {
        self.per_client.iter().filter(|c| c.kill_at_round.is_some()).count()
    }

    /// Number of killed clients that never rejoin.
    pub fn permanent_deaths(&self) -> usize {
        self.per_client.iter().filter(|c| c.permanent_death()).count()
    }

    /// Number of clients whose first Join is delayed.
    pub fn flash_crowd(&self) -> usize {
        self.per_client.iter().filter(|c| !c.join_delay.is_zero()).count()
    }

    /// Largest quorum `q` such that at least `q` clients are full
    /// participants of every round in `1..=rounds` — the tightest
    /// quorum this plan can guarantee closes on data rather than on
    /// zero-fill alone.
    pub fn guaranteed_quorum(&self, rounds: u32) -> u16 {
        (1..=rounds)
            .map(|r| self.full_participants(r).len() as u16)
            .min()
            .unwrap_or(self.n_clients())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stormy() -> ChurnConfig {
        ChurnConfig {
            kill_rate: 0.3,
            rejoin_delay: Duration::from_millis(50),
            flash_crowd: 2,
            permanent_rate: 0.25,
        }
    }

    #[test]
    fn same_seed_same_plan_different_seed_different_plan() {
        let cfg = stormy();
        let a = ChurnPlan::new(&cfg, 0xFEED, 24, 8);
        let b = ChurnPlan::new(&cfg, 0xFEED, 24, 8);
        for cid in 0..24 {
            assert_eq!(a.client(cid), b.client(cid), "client {cid} diverged across reruns");
        }
        // Across many seeds at kill_rate 0.3 some plan must differ.
        let diverged = (0..32u64).any(|s| {
            let c = ChurnPlan::new(&cfg, s, 24, 8);
            (0..24).any(|cid| c.client(cid) != a.client(cid))
        });
        assert!(diverged, "32 distinct seeds all produced the 0xFEED plan");
    }

    #[test]
    fn quiet_config_touches_nobody() {
        let plan = ChurnPlan::new(&ChurnConfig::default(), 7, 16, 10);
        for cid in 0..16 {
            assert_eq!(*plan.client(cid), ClientChurn::quiet());
        }
        assert_eq!(plan.kills(), 0);
        assert_eq!(plan.flash_crowd(), 0);
        assert_eq!(plan.guaranteed_quorum(10), 16);
    }

    #[test]
    fn flash_crowd_is_the_highest_ids_and_zero_rejoin_means_permanent() {
        let cfg = ChurnConfig {
            kill_rate: 1.0, // everyone dies in round 1
            rejoin_delay: Duration::ZERO,
            flash_crowd: 3,
            permanent_rate: 0.0,
        };
        let plan = ChurnPlan::new(&cfg, 42, 8, 4);
        for cid in 0..8 {
            let c = plan.client(cid);
            assert_eq!(c.kill_at_round, Some(1));
            assert!(c.permanent_death(), "rejoin_delay=0 must make kills permanent");
            assert_eq!(!c.join_delay.is_zero(), cid >= 5, "flash crowd is the top ids");
        }
        assert_eq!(plan.flash_crowd(), 3);
        assert_eq!(plan.guaranteed_quorum(4), 0);
    }

    #[test]
    fn full_participants_shrink_monotonically_and_bound_the_quorum() {
        let cfg = ChurnConfig { kill_rate: 0.4, ..stormy() };
        let plan = ChurnPlan::new(&cfg, 0xA5A5, 32, 6);
        let mut prev = plan.full_participants(1).len();
        for r in 2..=6 {
            let cur = plan.full_participants(r).len();
            assert!(cur <= prev, "kill-only lifecycle cannot grow the full-participant set");
            prev = cur;
        }
        let q = plan.guaranteed_quorum(6);
        for r in 1..=6 {
            assert!(plan.full_participants(r).len() >= q as usize);
        }
        // Flash-crowd clients are never full participants of any round.
        for cid in 30..32 {
            assert!(!plan.client(cid).full_participant(1));
        }
    }

    #[test]
    fn draw_order_is_stable_under_flash_crowd_changes() {
        // Flash membership is structural, so toggling it must not shift
        // any client's kill stream.
        let base = ChurnPlan::new(&ChurnConfig { flash_crowd: 0, ..stormy() }, 99, 16, 8);
        let flashy = ChurnPlan::new(&ChurnConfig { flash_crowd: 4, ..stormy() }, 99, 16, 8);
        for cid in 0..16 {
            assert_eq!(
                base.client(cid).kill_at_round,
                flashy.client(cid).kill_at_round,
                "flash-crowd membership perturbed client {cid}'s kill draw"
            );
        }
    }
}
