//! Minimal readiness + timer + batched-syscall substrate for the
//! single-thread reactor backend ([`crate::server`]'s `--io reactor`)
//! and the client driver's poll loop.
//!
//! All dependency-free (the crate builds offline without `libc`; every
//! syscall used here is declared by hand):
//!
//! * [`wait_readable`] — block until a UDP socket has a datagram to read
//!   or a timeout elapses. On Unix this is a direct `poll(2)` call on the
//!   socket's file descriptor; elsewhere it degrades to a short bounded
//!   sleep, which keeps the reactor correct (its socket is nonblocking,
//!   so a spurious wake just reads `WouldBlock`) at the cost of latency.
//! * [`TimerWheel`] — a coarse hashed timer wheel for the reactor's
//!   retransmit/idle-reclaim deadlines: O(1) insert, O(slots) sweep,
//!   firing accuracy bounded by the wheel granularity. Deadlines beyond
//!   one wheel turn stay parked in their slot and are re-examined once
//!   per turn — the classic cheap trade for a device that only needs
//!   coarse deadlines (idle reclamation, chaos-lane flushes), not
//!   high-resolution timers.
//! * [`recv_batch`] / [`send_batch`] / [`send_batch_connected`] —
//!   `recvmmsg(2)` / `sendmmsg(2)` wrappers on Linux, so the reactor
//!   drains and the emitters flush up to a whole burst of datagrams per
//!   syscall instead of one; elsewhere they degrade to single-datagram
//!   loops with identical semantics (the batch is a throughput
//!   optimisation, never a behaviour change).
//! * [`bind_reuseport`] — bind a UDP socket with `SO_REUSEPORT` set
//!   *before* the bind, so N sockets (one per fleet core) can share one
//!   port and the kernel spreads inbound flows across them; elsewhere it
//!   degrades to a plain bind (at most one socket per port — the fleet
//!   backend collapses to a single core, see [`REUSEPORT_NATIVE`]).

use std::io;
use std::net::{SocketAddr, UdpSocket};
use std::time::{Duration, Instant};

// Hand-declared poll(2): the offline build has no libc crate. The
// layout matches POSIX `struct pollfd`; `nfds_t` is C `unsigned
// long`, which is `usize` on every Unix Rust targets.
#[cfg(unix)]
#[repr(C)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}
#[cfg(unix)]
const POLLIN: i16 = 0x001;
#[cfg(unix)]
extern "C" {
    fn poll(fds: *mut PollFd, nfds: usize, timeout: i32) -> i32;
}

/// Clamp a `wait_readable` timeout to poll(2)'s millisecond int: `None`
/// blocks (-1); a nonzero sub-millisecond wait rounds up so it is a
/// real wait, not a busy spin.
#[cfg(unix)]
fn poll_timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let ms = d.as_millis().min(i32::MAX as u128) as i32;
            if ms == 0 && !d.is_zero() {
                1
            } else {
                ms
            }
        }
    }
}

/// Wait until `socket` is readable or `timeout` elapses. `None` blocks
/// indefinitely. Returns `Ok(true)` when the socket has an event pending
/// (data, or an error condition a subsequent `recv_from` will surface)
/// and `Ok(false)` on timeout. `EINTR` is retried internally.
#[cfg(unix)]
pub fn wait_readable(socket: &UdpSocket, timeout: Option<Duration>) -> io::Result<bool> {
    use std::os::unix::io::AsRawFd;

    let ms = poll_timeout_ms(timeout);
    let mut pfd = PollFd { fd: socket.as_raw_fd(), events: POLLIN, revents: 0 };
    loop {
        let rc = unsafe { poll(&mut pfd as *mut PollFd, 1, ms) };
        if rc < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                continue;
            }
            return Err(e);
        }
        // Any revents (POLLIN, POLLERR, POLLHUP) means "go recv": error
        // conditions must be drained by the caller's read, not looped on
        // here.
        return Ok(rc > 0);
    }
}

/// Wait until any of `sockets` is readable or `timeout` elapses — the
/// multi-socket sibling of [`wait_readable`], one `poll(2)` call over
/// the whole descriptor set (the client-side swarm multiplexer blocks
/// here across its handful of sockets). Indices of the sockets with an
/// event pending (data or an error condition the next recv will
/// surface) are appended to `ready` (cleared first); returns how many.
/// `EINTR` is retried internally.
#[cfg(unix)]
pub fn wait_readable_many(
    sockets: &[&UdpSocket],
    timeout: Option<Duration>,
    ready: &mut Vec<usize>,
) -> io::Result<usize> {
    use std::os::unix::io::AsRawFd;

    ready.clear();
    if sockets.is_empty() {
        return Ok(0);
    }
    let ms = poll_timeout_ms(timeout);
    let mut pfds: Vec<PollFd> = sockets
        .iter()
        .map(|s| PollFd { fd: s.as_raw_fd(), events: POLLIN, revents: 0 })
        .collect();
    loop {
        let rc = unsafe { poll(pfds.as_mut_ptr(), pfds.len(), ms) };
        if rc < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                continue;
            }
            return Err(e);
        }
        for (i, p) in pfds.iter().enumerate() {
            if p.revents != 0 {
                ready.push(i);
            }
        }
        return Ok(ready.len());
    }
}

/// Portability fallback: a bounded sleep standing in for readiness. The
/// reactor's socket is nonblocking, so waking without data is harmless
/// (`recv_from` returns `WouldBlock`); the cap keeps timer latency sane.
#[cfg(not(unix))]
pub fn wait_readable(_socket: &UdpSocket, timeout: Option<Duration>) -> io::Result<bool> {
    const CAP: Duration = Duration::from_millis(5);
    std::thread::sleep(timeout.unwrap_or(CAP).min(CAP));
    Ok(true)
}

/// Portability fallback for the multi-socket wait: a bounded sleep that
/// reports every socket ready — callers' sockets are nonblocking, so a
/// spurious wake just reads `WouldBlock` on each (see [`wait_readable`]).
#[cfg(not(unix))]
pub fn wait_readable_many(
    sockets: &[&UdpSocket],
    timeout: Option<Duration>,
    ready: &mut Vec<usize>,
) -> io::Result<usize> {
    const CAP: Duration = Duration::from_millis(5);
    ready.clear();
    std::thread::sleep(timeout.unwrap_or(CAP).min(CAP));
    ready.extend(0..sockets.len());
    Ok(ready.len())
}

/// A coarse hashed timer wheel: `n_slots` buckets of `granularity` each.
/// Entries land in the slot their deadline falls in modulo one wheel
/// turn; [`TimerWheel::pop_due`] sweeps the slots the cursor has passed
/// and fires entries whose deadline has actually arrived (entries parked
/// for a later turn stay put). Firing lateness is bounded by
/// `granularity` plus however late the owner calls `pop_due`.
#[derive(Debug)]
pub struct TimerWheel<T> {
    granularity: Duration,
    /// `slots[tick % n]` holds entries as `(absolute tick, item)`.
    slots: Vec<Vec<(u64, T)>>,
    /// Wheel epoch; ticks count `granularity` steps since here.
    base: Instant,
    /// First tick not yet swept by [`TimerWheel::pop_due`].
    next_tick: u64,
    len: usize,
}

impl<T> TimerWheel<T> {
    /// Empty wheel with its epoch at `now`. `granularity` must be
    /// nonzero and `n_slots` ≥ 2.
    pub fn new(granularity: Duration, n_slots: usize, now: Instant) -> Self {
        assert!(!granularity.is_zero(), "timer wheel granularity must be nonzero");
        assert!(n_slots >= 2, "timer wheel needs at least 2 slots");
        TimerWheel {
            granularity,
            slots: (0..n_slots).map(|_| Vec::new()).collect(),
            base: now,
            next_tick: 0,
            len: 0,
        }
    }

    fn tick_of(&self, t: Instant) -> u64 {
        (t.saturating_duration_since(self.base).as_nanos() / self.granularity.as_nanos()) as u64
    }

    /// Number of armed entries.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no entries are armed.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Arm `item` to fire at `deadline`. A deadline already in the past
    /// (or inside the current tick) fires on the next [`Self::pop_due`].
    pub fn insert(&mut self, deadline: Instant, item: T) {
        let tick = self.tick_of(deadline).max(self.next_tick);
        let slot = (tick % self.slots.len() as u64) as usize;
        self.slots[slot].push((tick, item));
        self.len += 1;
    }

    /// Sweep the wheel up to `now` and return every fired entry. Sweeps
    /// at most one full turn of slots per call regardless of how long the
    /// caller slept, which still visits every bucket once.
    pub fn pop_due(&mut self, now: Instant) -> Vec<T> {
        let mut fired = Vec::new();
        if self.len == 0 {
            self.next_tick = self.tick_of(now) + 1;
            return fired;
        }
        let now_tick = self.tick_of(now);
        if now_tick < self.next_tick {
            return fired;
        }
        let n = self.slots.len() as u64;
        let span = now_tick - self.next_tick + 1;
        if span >= n {
            // Slept a full turn (or more): every slot's window has
            // passed at least once — one linear pass over all buckets.
            for slot in &mut self.slots {
                let mut i = 0;
                while i < slot.len() {
                    if slot[i].0 <= now_tick {
                        fired.push(slot.swap_remove(i).1);
                    } else {
                        i += 1;
                    }
                }
            }
        } else {
            for tick in self.next_tick..=now_tick {
                let slot = &mut self.slots[(tick % n) as usize];
                let mut i = 0;
                while i < slot.len() {
                    if slot[i].0 <= now_tick {
                        fired.push(slot.swap_remove(i).1);
                    } else {
                        i += 1;
                    }
                }
            }
        }
        self.len -= fired.len();
        self.next_tick = now_tick + 1;
        fired
    }

    /// Earliest armed deadline (None when empty). Linear in armed
    /// entries — the reactor holds at most one entry per job, so this is
    /// cheap enough to call once per loop iteration.
    pub fn next_deadline(&self) -> Option<Instant> {
        let mut min: Option<u64> = None;
        for slot in &self.slots {
            for &(tick, _) in slot {
                min = Some(min.map_or(tick, |m: u64| m.min(tick)));
            }
        }
        min.map(|tick| {
            // End of the entry's tick window, so sleeping exactly until
            // the returned instant guarantees `pop_due` fires it.
            let nanos = self.granularity.as_nanos().saturating_mul(tick as u128 + 1);
            self.base + Duration::from_nanos(nanos.min(u64::MAX as u128) as u64)
        })
    }
}

/// True when [`recv_batch`]/[`send_batch`] are kernel-batched
/// (`recvmmsg`/`sendmmsg`); false where they degrade to single-datagram
/// fallbacks. Callers that would change *blocking* behaviour by issuing
/// an extra nonblocking drain (the client driver) consult this.
pub const MMSG_NATIVE: bool = cfg!(target_os = "linux");

/// Bytes reserved per raw C sockaddr (sockaddr_in6 needs 28; padded).
const SOCKADDR_BUF: usize = 32;

/// Reusable receive-side batch: `depth` preallocated datagram buffers
/// plus per-datagram lengths, source addresses and raw sockaddr
/// storage, filled by [`recv_batch`]. One struct lives for the life of
/// a reactor / client so the per-datagram storage is reused; the
/// per-call `iovec`/`mmsghdr` arrays are rebuilt each syscall (they
/// hold raw pointers, which would otherwise cost the batch its `Send`)
/// — a few small allocations amortised over a whole batch of
/// datagrams.
#[derive(Debug)]
pub struct RecvBatch {
    bufs: Vec<Vec<u8>>,
    lens: Vec<usize>,
    addrs: Vec<SocketAddr>,
    /// Kernel-filled raw sockaddr storage, one slot per buffer.
    names: Vec<[u8; SOCKADDR_BUF]>,
    count: usize,
}

impl RecvBatch {
    /// Batch of `depth` buffers of `buf_size` bytes each (size every
    /// buffer for the largest datagram the wire can carry —
    /// [`crate::wire::MAX_DATAGRAM`] — or shorter datagrams truncate).
    pub fn new(depth: usize, buf_size: usize) -> Self {
        assert!(depth >= 1, "batch depth must be at least 1");
        RecvBatch {
            bufs: (0..depth).map(|_| vec![0u8; buf_size]).collect(),
            lens: vec![0; depth],
            addrs: vec![SocketAddr::from(([0, 0, 0, 0], 0)); depth],
            names: vec![[0u8; SOCKADDR_BUF]; depth],
            count: 0,
        }
    }

    /// Maximum datagrams one [`recv_batch`] call can deliver.
    pub fn depth(&self) -> usize {
        self.bufs.len()
    }

    /// Datagrams delivered by the most recent [`recv_batch`] call.
    pub fn count(&self) -> usize {
        self.count
    }

    /// Datagram `i` of the most recent fill (bytes, source address).
    pub fn datagram(&self, i: usize) -> (&[u8], SocketAddr) {
        debug_assert!(i < self.count);
        (&self.bufs[i][..self.lens[i]], self.addrs[i])
    }
}

// ---------------------------------------------------------------------
// Linux: hand-declared recvmmsg/sendmmsg (no libc crate). Struct
// layouts match the glibc/musl C ABI on 64-bit Linux: `#[repr(C)]`
// inserts the same padding after the u32 `namelen` and the i32 `flags`
// that the C compiler does.
// ---------------------------------------------------------------------
#[cfg(target_os = "linux")]
mod mmsg {
    use std::io;
    use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, UdpSocket};
    use std::os::unix::io::AsRawFd;

    #[repr(C)]
    struct IoVec {
        base: *mut u8,
        len: usize,
    }

    #[repr(C)]
    struct MsgHdr {
        name: *mut u8,
        namelen: u32,
        iov: *mut IoVec,
        iovlen: usize,
        control: *mut u8,
        controllen: usize,
        flags: i32,
    }

    #[repr(C)]
    struct MMsgHdr {
        hdr: MsgHdr,
        len: u32,
    }

    extern "C" {
        fn recvmmsg(fd: i32, msgvec: *mut MMsgHdr, vlen: u32, flags: i32, timeout: *mut u8)
            -> i32;
        fn sendmmsg(fd: i32, msgvec: *mut MMsgHdr, vlen: u32, flags: i32) -> i32;
    }

    const MSG_DONTWAIT: i32 = 0x40;
    const AF_INET: u16 = 2;
    const AF_INET6: u16 = 10;
    use super::SOCKADDR_BUF;

    /// Serialise a SocketAddr into a C sockaddr buffer; returns the
    /// meaningful length (sockaddr_in: 16, sockaddr_in6: 28). Also used
    /// by the sibling `reuseport` module's hand-rolled bind(2).
    pub(super) fn write_sockaddr(addr: &SocketAddr, buf: &mut [u8; SOCKADDR_BUF]) -> u32 {
        *buf = [0; SOCKADDR_BUF];
        match addr {
            SocketAddr::V4(a) => {
                buf[0..2].copy_from_slice(&AF_INET.to_ne_bytes());
                buf[2..4].copy_from_slice(&a.port().to_be_bytes());
                buf[4..8].copy_from_slice(&a.ip().octets());
                16
            }
            SocketAddr::V6(a) => {
                buf[0..2].copy_from_slice(&AF_INET6.to_ne_bytes());
                buf[2..4].copy_from_slice(&a.port().to_be_bytes());
                buf[4..8].copy_from_slice(&a.flowinfo().to_be_bytes());
                buf[8..24].copy_from_slice(&a.ip().octets());
                buf[24..28].copy_from_slice(&a.scope_id().to_ne_bytes());
                28
            }
        }
    }

    /// Parse a kernel-filled sockaddr buffer back into a SocketAddr.
    fn read_sockaddr(buf: &[u8; SOCKADDR_BUF]) -> Option<SocketAddr> {
        let family = u16::from_ne_bytes([buf[0], buf[1]]);
        if family == AF_INET {
            let port = u16::from_be_bytes([buf[2], buf[3]]);
            let ip = Ipv4Addr::new(buf[4], buf[5], buf[6], buf[7]);
            Some(SocketAddr::new(IpAddr::V4(ip), port))
        } else if family == AF_INET6 {
            let port = u16::from_be_bytes([buf[2], buf[3]]);
            let mut octets = [0u8; 16];
            octets.copy_from_slice(&buf[8..24]);
            Some(SocketAddr::new(IpAddr::V6(Ipv6Addr::from(octets)), port))
        } else {
            None
        }
    }

    pub(super) fn recv_batch(socket: &UdpSocket, batch: &mut super::RecvBatch) -> io::Result<usize> {
        batch.count = 0;
        let depth = batch.bufs.len();
        let mut iovs: Vec<IoVec> = batch
            .bufs
            .iter_mut()
            .map(|b| IoVec { base: b.as_mut_ptr(), len: b.len() })
            .collect();
        let mut hdrs: Vec<MMsgHdr> = (0..depth)
            .map(|i| MMsgHdr {
                hdr: MsgHdr {
                    name: batch.names[i].as_mut_ptr(),
                    namelen: SOCKADDR_BUF as u32,
                    iov: &mut iovs[i],
                    iovlen: 1,
                    control: std::ptr::null_mut(),
                    controllen: 0,
                    flags: 0,
                },
                len: 0,
            })
            .collect();
        let rc = unsafe {
            recvmmsg(
                socket.as_raw_fd(),
                hdrs.as_mut_ptr(),
                depth as u32,
                MSG_DONTWAIT,
                std::ptr::null_mut(),
            )
        };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        let got = rc as usize;
        for i in 0..got {
            batch.lens[i] = hdrs[i].len as usize;
            // An unparsable family (never expected for UDP) degrades to
            // a zero address; the frame router drops what it can't peek.
            batch.addrs[i] = read_sockaddr(&batch.names[i])
                .unwrap_or_else(|| SocketAddr::from(([0, 0, 0, 0], 0)));
        }
        batch.count = got;
        Ok(got)
    }

    /// Send `msgs` with explicit destinations; returns how many of the
    /// *leading* messages the kernel confirmed sent.
    pub(super) fn send_batch(
        socket: &UdpSocket,
        msgs: &[(Vec<u8>, SocketAddr)],
    ) -> io::Result<usize> {
        let mut names = vec![[0u8; SOCKADDR_BUF]; msgs.len()];
        let mut lens = vec![0u32; msgs.len()];
        for (i, (_, addr)) in msgs.iter().enumerate() {
            lens[i] = write_sockaddr(addr, &mut names[i]);
        }
        let mut iovs: Vec<IoVec> = msgs
            .iter()
            .map(|(b, _)| IoVec { base: b.as_ptr() as *mut u8, len: b.len() })
            .collect();
        let mut hdrs: Vec<MMsgHdr> = (0..msgs.len())
            .map(|i| MMsgHdr {
                hdr: MsgHdr {
                    name: names[i].as_mut_ptr(),
                    namelen: lens[i],
                    iov: &mut iovs[i],
                    iovlen: 1,
                    control: std::ptr::null_mut(),
                    controllen: 0,
                    flags: 0,
                },
                len: 0,
            })
            .collect();
        let rc =
            unsafe { sendmmsg(socket.as_raw_fd(), hdrs.as_mut_ptr(), msgs.len() as u32, 0) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(rc as usize)
    }

    /// Send pre-encoded frames on a *connected* socket (null name — the
    /// kernel routes to the connected peer).
    pub(super) fn send_batch_connected(socket: &UdpSocket, frames: &[&[u8]]) -> io::Result<usize> {
        let mut iovs: Vec<IoVec> = frames
            .iter()
            .map(|b| IoVec { base: b.as_ptr() as *mut u8, len: b.len() })
            .collect();
        let mut hdrs: Vec<MMsgHdr> = (0..frames.len())
            .map(|i| MMsgHdr {
                hdr: MsgHdr {
                    name: std::ptr::null_mut(),
                    namelen: 0,
                    iov: &mut iovs[i],
                    iovlen: 1,
                    control: std::ptr::null_mut(),
                    controllen: 0,
                    flags: 0,
                },
                len: 0,
            })
            .collect();
        let rc =
            unsafe { sendmmsg(socket.as_raw_fd(), hdrs.as_mut_ptr(), frames.len() as u32, 0) };
        if rc < 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(rc as usize)
    }
}

/// True when [`bind_reuseport`] genuinely joins an `SO_REUSEPORT` group
/// (Linux); false where it degrades to a plain bind, in which case at
/// most ONE socket can own a port and the multi-core fleet backend
/// collapses to a single reactor. Callers sizing a fleet consult this
/// before deciding how many member sockets to create.
pub const REUSEPORT_NATIVE: bool = cfg!(target_os = "linux");

// ---------------------------------------------------------------------
// Linux: hand-declared socket(2)/setsockopt(2)/bind(2)/close(2) so a
// UDP socket can be created with SO_REUSEPORT set BEFORE the bind —
// std's UdpSocket::bind offers no pre-bind option hook. Constants match
// the Linux ABI (SOL_SOCKET=1, SO_REUSEPORT=15, SOCK_DGRAM=2).
// ---------------------------------------------------------------------
#[cfg(target_os = "linux")]
mod reuseport {
    use std::io;
    use std::net::{SocketAddr, UdpSocket};
    use std::os::unix::io::FromRawFd;

    use super::SOCKADDR_BUF;

    const AF_INET: i32 = 2;
    const AF_INET6: i32 = 10;
    const SOCK_DGRAM: i32 = 2;
    const SOL_SOCKET: i32 = 1;
    const SO_REUSEPORT: i32 = 15;

    extern "C" {
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        fn setsockopt(fd: i32, level: i32, optname: i32, optval: *const u8, optlen: u32) -> i32;
        fn bind(fd: i32, addr: *const u8, addrlen: u32) -> i32;
        fn close(fd: i32) -> i32;
    }

    pub(super) fn bind_reuseport(addr: SocketAddr) -> io::Result<UdpSocket> {
        let domain = match addr {
            SocketAddr::V4(_) => AF_INET,
            SocketAddr::V6(_) => AF_INET6,
        };
        let fd = unsafe { socket(domain, SOCK_DGRAM, 0) };
        if fd < 0 {
            return Err(io::Error::last_os_error());
        }
        // From here on the raw fd must not leak on any error path.
        let fail = |fd: i32| -> io::Error {
            let e = io::Error::last_os_error();
            unsafe { close(fd) };
            e
        };
        let one: i32 = 1;
        let rc = unsafe {
            setsockopt(fd, SOL_SOCKET, SO_REUSEPORT, (&one as *const i32).cast::<u8>(), 4)
        };
        if rc < 0 {
            return Err(fail(fd));
        }
        let mut name = [0u8; SOCKADDR_BUF];
        let len = super::mmsg::write_sockaddr(&addr, &mut name);
        if unsafe { bind(fd, name.as_ptr(), len) } < 0 {
            return Err(fail(fd));
        }
        Ok(unsafe { UdpSocket::from_raw_fd(fd) })
    }
}

/// Bind a UDP socket to `addr` as a member of that port's
/// `SO_REUSEPORT` group: every socket bound this way to the same
/// address shares the port, and the kernel steers each inbound *flow*
/// (source/destination 4-tuple hash) to one member. This is the fleet
/// backend's substrate — one member socket per core. Note the steering
/// unit is the flow, not anything protocol-aware: a job's frames land
/// wherever its clients' flows hash, so fleet cores forward misdirected
/// frames to the owner core themselves.
///
/// On platforms without `SO_REUSEPORT` plumbing this is a plain
/// `UdpSocket::bind` — the first caller wins the port and subsequent
/// binds fail, which [`REUSEPORT_NATIVE`] lets callers anticipate.
pub fn bind_reuseport(addr: SocketAddr) -> io::Result<UdpSocket> {
    #[cfg(target_os = "linux")]
    {
        reuseport::bind_reuseport(addr)
    }
    #[cfg(not(target_os = "linux"))]
    {
        UdpSocket::bind(addr)
    }
}

/// Drain up to `batch.depth()` datagrams with one syscall (Linux:
/// `recvmmsg` with `MSG_DONTWAIT`; elsewhere: a single nonblocking
/// `recv_from`). Returns how many datagrams were filled; `WouldBlock`
/// when the socket is empty. Intended for nonblocking sockets (the
/// reactor) or after a readiness wait (the client driver).
pub fn recv_batch(socket: &UdpSocket, batch: &mut RecvBatch) -> io::Result<usize> {
    #[cfg(target_os = "linux")]
    {
        mmsg::recv_batch(socket, batch)
    }
    #[cfg(not(target_os = "linux"))]
    {
        batch.count = 0;
        let (n, from) = socket.recv_from(&mut batch.bufs[0])?;
        batch.lens[0] = n;
        batch.addrs[0] = from;
        batch.count = 1;
        Ok(1)
    }
}

/// Transmit a burst of `(bytes, destination)` datagrams, batching the
/// syscalls where the platform allows (`sendmmsg` on Linux; a plain
/// `send_to` loop elsewhere). `sendmmsg` stops at the first refused
/// datagram and reports the sent prefix (the refusal itself surfaces as
/// an error on the *next* call), so the loop here retries the unsent
/// tail and skips exactly one datagram per hard error — identical
/// per-datagram semantics to the naive send loop. Errors are swallowed
/// per frame; UDP callers rely on retransmission anyway. Returns the
/// count of datagrams confirmed sent.
pub fn send_batch(socket: &UdpSocket, msgs: &[(Vec<u8>, SocketAddr)]) -> io::Result<usize> {
    let mut sent_total = 0usize;
    let mut start = 0usize;
    while start < msgs.len() {
        let rest = &msgs[start..];
        #[cfg(target_os = "linux")]
        let attempt = mmsg::send_batch(socket, rest);
        #[cfg(not(target_os = "linux"))]
        let attempt = {
            let (bytes, dest) = &rest[0];
            socket.send_to(bytes, dest).map(|_| 1)
        };
        match attempt {
            Ok(0) => start += 1, // defensive: never spin in place
            Ok(sent) => {
                sent_total += sent;
                start += sent;
            }
            Err(_) => start += 1, // head datagram refused: skip it
        }
    }
    Ok(sent_total)
}

/// One batched send attempt on a *connected* socket (the client
/// driver): frames go to the connected peer. Unlike [`send_batch`] this
/// does NOT loop — it returns the count of *leading* frames confirmed
/// sent, so the caller can meter exactly which bytes hit the wire and
/// drive its own retry/skip policy. The contract on BOTH platforms:
/// `Ok(sent)` with `sent < frames.len()` means frames `0..sent` were
/// sent and frame `sent` was attempted and refused (`sendmmsg` stops at
/// the first failing datagram; the portable loop stops at the first
/// failing `send`), so the caller may skip exactly that frame. An
/// `Err` means the head frame was refused and nothing was sent.
pub fn send_batch_connected(socket: &UdpSocket, frames: &[&[u8]]) -> io::Result<usize> {
    if frames.is_empty() {
        return Ok(0);
    }
    #[cfg(target_os = "linux")]
    {
        mmsg::send_batch_connected(socket, frames)
    }
    #[cfg(not(target_os = "linux"))]
    {
        let mut sent = 0usize;
        for f in frames {
            match socket.send(f) {
                Ok(_) => sent += 1,
                Err(e) if sent == 0 => return Err(e),
                Err(_) => break,
            }
        }
        Ok(sent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const G: Duration = Duration::from_millis(10);

    #[test]
    fn fires_in_deadline_order_within_granularity() {
        let base = Instant::now();
        let mut wheel: TimerWheel<u32> = TimerWheel::new(G, 8, base);
        wheel.insert(base + Duration::from_millis(35), 3);
        wheel.insert(base + Duration::from_millis(15), 1);
        wheel.insert(base + Duration::from_millis(25), 2);
        assert_eq!(wheel.len(), 3);

        assert!(wheel.pop_due(base + Duration::from_millis(5)).is_empty());
        assert_eq!(wheel.pop_due(base + Duration::from_millis(19)), vec![1]);
        let rest = wheel.pop_due(base + Duration::from_millis(60));
        assert_eq!(rest.len(), 2);
        assert!(rest.contains(&2) && rest.contains(&3));
        assert!(wheel.is_empty());
    }

    #[test]
    fn past_deadlines_fire_immediately() {
        let base = Instant::now();
        let mut wheel: TimerWheel<&str> = TimerWheel::new(G, 8, base);
        // Sweep forward first, then arm something "in the past".
        wheel.pop_due(base + Duration::from_millis(100));
        wheel.insert(base + Duration::from_millis(20), "late");
        assert_eq!(wheel.pop_due(base + Duration::from_millis(120)), vec!["late"]);
    }

    #[test]
    fn far_deadlines_wait_their_turn() {
        let base = Instant::now();
        // 4 slots × 10 ms = one 40 ms turn; a 95 ms deadline shares a
        // slot with early ticks but must not fire on the first pass.
        let mut wheel: TimerWheel<u32> = TimerWheel::new(G, 4, base);
        wheel.insert(base + Duration::from_millis(95), 9);
        wheel.insert(base + Duration::from_millis(15), 1);
        assert_eq!(wheel.pop_due(base + Duration::from_millis(20)), vec![1]);
        assert!(wheel.pop_due(base + Duration::from_millis(60)).is_empty());
        assert_eq!(wheel.pop_due(base + Duration::from_millis(100)), vec![9]);
    }

    #[test]
    fn long_sleep_sweeps_every_slot_once() {
        let base = Instant::now();
        let mut wheel: TimerWheel<u32> = TimerWheel::new(G, 4, base);
        for i in 0..8u32 {
            wheel.insert(base + Duration::from_millis(10 * (i as u64 + 1)), i);
        }
        // Caller slept many turns: everything due fires in one call.
        let mut fired = wheel.pop_due(base + Duration::from_secs(5));
        fired.sort_unstable();
        assert_eq!(fired, (0..8).collect::<Vec<_>>());
        assert!(wheel.is_empty());
    }

    #[test]
    fn next_deadline_upper_bounds_the_earliest_entry() {
        let base = Instant::now();
        let mut wheel: TimerWheel<u32> = TimerWheel::new(G, 8, base);
        assert!(wheel.next_deadline().is_none());
        let deadline = base + Duration::from_millis(42);
        wheel.insert(deadline, 1);
        let nd = wheel.next_deadline().unwrap();
        assert!(nd >= deadline.checked_sub(G).unwrap(), "deadline too early");
        assert!(nd <= deadline + G, "deadline too late");
    }

    #[test]
    fn send_batch_and_recv_batch_roundtrip_many_datagrams() {
        let rx = UdpSocket::bind("127.0.0.1:0").unwrap();
        rx.set_nonblocking(true).unwrap();
        let tx = UdpSocket::bind("127.0.0.1:0").unwrap();
        let dest = rx.local_addr().unwrap();
        let msgs: Vec<(Vec<u8>, std::net::SocketAddr)> =
            (0..10u8).map(|i| (vec![i; (i as usize + 1) * 3], dest)).collect();
        assert_eq!(send_batch(&tx, &msgs).unwrap(), 10);

        // Drain with a batch smaller than the burst: two+ calls, every
        // datagram intact and correctly sized, source address right.
        let mut batch = RecvBatch::new(4, 2048);
        let mut got: Vec<Vec<u8>> = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(2);
        while got.len() < 10 {
            assert!(Instant::now() < deadline, "only {} of 10 datagrams", got.len());
            match recv_batch(&rx, &mut batch) {
                Ok(n) => {
                    assert!((1..=batch.depth()).contains(&n));
                    for i in 0..n {
                        let (bytes, from) = batch.datagram(i);
                        assert_eq!(from, tx.local_addr().unwrap());
                        got.push(bytes.to_vec());
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => panic!("recv_batch: {e}"),
            }
        }
        got.sort();
        let mut want: Vec<Vec<u8>> = msgs.into_iter().map(|(b, _)| b).collect();
        want.sort();
        assert_eq!(got, want);
        // Empty socket reports WouldBlock, not a phantom datagram.
        assert!(matches!(
            recv_batch(&rx, &mut batch),
            Err(e) if e.kind() == io::ErrorKind::WouldBlock
        ));
    }

    #[test]
    fn send_batch_connected_reports_sent_prefix() {
        let rx = UdpSocket::bind("127.0.0.1:0").unwrap();
        rx.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let tx = UdpSocket::bind("127.0.0.1:0").unwrap();
        tx.connect(rx.local_addr().unwrap()).unwrap();
        let frames: Vec<Vec<u8>> = (0..5u8).map(|i| vec![0x40 | i; 8]).collect();
        let refs: Vec<&[u8]> = frames.iter().map(|f| f.as_slice()).collect();
        let sent = send_batch_connected(&tx, &refs).unwrap();
        assert_eq!(sent, 5);
        let mut buf = [0u8; 64];
        let mut got = Vec::new();
        for _ in 0..5 {
            let (n, _) = rx.recv_from(&mut buf).unwrap();
            got.push(buf[..n].to_vec());
        }
        got.sort();
        let mut want = frames.clone();
        want.sort();
        assert_eq!(got, want);
        assert_eq!(send_batch_connected(&tx, &[]).unwrap(), 0);
    }

    #[test]
    fn wait_readable_many_reports_the_ready_subset() {
        let a = UdpSocket::bind("127.0.0.1:0").unwrap();
        let b = UdpSocket::bind("127.0.0.1:0").unwrap();
        let mut ready = Vec::new();
        // Empty socket set: trivially nothing ready.
        assert_eq!(wait_readable_many(&[], Some(Duration::from_millis(1)), &mut ready).unwrap(), 0);
        // Both sockets idle: a bounded wait reports none ready (on Unix;
        // the portable fallback deliberately reports all).
        let n =
            wait_readable_many(&[&a, &b], Some(Duration::from_millis(20)), &mut ready).unwrap();
        #[cfg(unix)]
        assert_eq!((n, ready.len()), (0, 0));
        #[cfg(not(unix))]
        let _ = n;

        let tx = UdpSocket::bind("127.0.0.1:0").unwrap();
        tx.send_to(b"x", b.local_addr().unwrap()).unwrap();
        let deadline = Instant::now() + Duration::from_secs(2);
        loop {
            let n = wait_readable_many(&[&a, &b], Some(Duration::from_millis(50)), &mut ready)
                .unwrap();
            if n > 0 {
                break;
            }
            assert!(Instant::now() < deadline, "datagram never surfaced");
        }
        #[cfg(unix)]
        assert_eq!(ready, vec![1], "wrong socket reported ready");
        let mut buf = [0u8; 8];
        let (n, _) = b.recv_from(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"x");
    }

    #[test]
    fn bind_reuseport_members_share_one_port() {
        let first = bind_reuseport("127.0.0.1:0".parse().unwrap()).unwrap();
        let addr = first.local_addr().unwrap();
        if REUSEPORT_NATIVE {
            // A second member joins the same concrete port, and a
            // datagram sent to the shared port lands on exactly one of
            // the two members.
            let second = bind_reuseport(addr).unwrap();
            assert_eq!(second.local_addr().unwrap(), addr);
            for s in [&first, &second] {
                s.set_nonblocking(true).unwrap();
            }
            let tx = UdpSocket::bind("127.0.0.1:0").unwrap();
            tx.send_to(b"fleet", addr).unwrap();
            let mut ready = Vec::new();
            let deadline = Instant::now() + Duration::from_secs(2);
            loop {
                let n = wait_readable_many(
                    &[&first, &second],
                    Some(Duration::from_millis(50)),
                    &mut ready,
                )
                .unwrap();
                if n > 0 {
                    break;
                }
                assert!(Instant::now() < deadline, "datagram never surfaced");
            }
            assert_eq!(ready.len(), 1, "one flow must land on exactly one member");
            let member = if ready[0] == 0 { &first } else { &second };
            let mut buf = [0u8; 16];
            let (n, _) = member.recv_from(&mut buf).unwrap();
            assert_eq!(&buf[..n], b"fleet");
        } else {
            // Fallback: a plain bind — the port is exclusively owned.
            assert!(bind_reuseport(addr).is_err());
        }
    }

    #[test]
    fn wait_readable_times_out_then_sees_data() {
        let socket = UdpSocket::bind("127.0.0.1:0").unwrap();
        let ready = wait_readable(&socket, Some(Duration::from_millis(20))).unwrap();
        #[cfg(unix)]
        assert!(!ready, "empty socket reported readable");
        #[cfg(not(unix))]
        let _ = ready;

        let sender = UdpSocket::bind("127.0.0.1:0").unwrap();
        sender.send_to(b"ping", socket.local_addr().unwrap()).unwrap();
        assert!(wait_readable(&socket, Some(Duration::from_secs(2))).unwrap());
        let mut buf = [0u8; 8];
        let (n, _) = socket.recv_from(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"ping");
    }
}
