//! Minimal readiness + timer substrate for the single-thread reactor
//! backend ([`crate::server`]'s `--io reactor`).
//!
//! Two pieces, both dependency-free:
//!
//! * [`wait_readable`] — block until a UDP socket has a datagram to read
//!   or a timeout elapses. On Unix this is a direct `poll(2)` call on the
//!   socket's file descriptor (declared here by hand — the crate builds
//!   offline without `libc`); elsewhere it degrades to a short bounded
//!   sleep, which keeps the reactor correct (its socket is nonblocking,
//!   so a spurious wake just reads `WouldBlock`) at the cost of latency.
//! * [`TimerWheel`] — a coarse hashed timer wheel for the reactor's
//!   retransmit/idle-reclaim deadlines: O(1) insert, O(slots) sweep,
//!   firing accuracy bounded by the wheel granularity. Deadlines beyond
//!   one wheel turn stay parked in their slot and are re-examined once
//!   per turn — the classic cheap trade for a device that only needs
//!   coarse deadlines (idle reclamation, chaos-lane flushes), not
//!   high-resolution timers.

use std::io;
use std::net::UdpSocket;
use std::time::{Duration, Instant};

/// Wait until `socket` is readable or `timeout` elapses. `None` blocks
/// indefinitely. Returns `Ok(true)` when the socket has an event pending
/// (data, or an error condition a subsequent `recv_from` will surface)
/// and `Ok(false)` on timeout. `EINTR` is retried internally.
#[cfg(unix)]
pub fn wait_readable(socket: &UdpSocket, timeout: Option<Duration>) -> io::Result<bool> {
    use std::os::unix::io::AsRawFd;

    // Hand-declared poll(2): the offline build has no libc crate. The
    // layout matches POSIX `struct pollfd`; `nfds_t` is C `unsigned
    // long`, which is `usize` on every Unix Rust targets.
    #[repr(C)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }
    const POLLIN: i16 = 0x001;
    extern "C" {
        fn poll(fds: *mut PollFd, nfds: usize, timeout: i32) -> i32;
    }

    let ms: i32 = match timeout {
        None => -1,
        // poll's timeout is an int of milliseconds; round a nonzero
        // sub-millisecond wait up so it is a real wait, not a busy spin.
        Some(d) => {
            let ms = d.as_millis().min(i32::MAX as u128) as i32;
            if ms == 0 && !d.is_zero() {
                1
            } else {
                ms
            }
        }
    };
    let mut pfd = PollFd { fd: socket.as_raw_fd(), events: POLLIN, revents: 0 };
    loop {
        let rc = unsafe { poll(&mut pfd as *mut PollFd, 1, ms) };
        if rc < 0 {
            let e = io::Error::last_os_error();
            if e.kind() == io::ErrorKind::Interrupted {
                continue;
            }
            return Err(e);
        }
        // Any revents (POLLIN, POLLERR, POLLHUP) means "go recv": error
        // conditions must be drained by the caller's read, not looped on
        // here.
        return Ok(rc > 0);
    }
}

/// Portability fallback: a bounded sleep standing in for readiness. The
/// reactor's socket is nonblocking, so waking without data is harmless
/// (`recv_from` returns `WouldBlock`); the cap keeps timer latency sane.
#[cfg(not(unix))]
pub fn wait_readable(_socket: &UdpSocket, timeout: Option<Duration>) -> io::Result<bool> {
    const CAP: Duration = Duration::from_millis(5);
    std::thread::sleep(timeout.unwrap_or(CAP).min(CAP));
    Ok(true)
}

/// A coarse hashed timer wheel: `n_slots` buckets of `granularity` each.
/// Entries land in the slot their deadline falls in modulo one wheel
/// turn; [`TimerWheel::pop_due`] sweeps the slots the cursor has passed
/// and fires entries whose deadline has actually arrived (entries parked
/// for a later turn stay put). Firing lateness is bounded by
/// `granularity` plus however late the owner calls `pop_due`.
#[derive(Debug)]
pub struct TimerWheel<T> {
    granularity: Duration,
    /// `slots[tick % n]` holds entries as `(absolute tick, item)`.
    slots: Vec<Vec<(u64, T)>>,
    /// Wheel epoch; ticks count `granularity` steps since here.
    base: Instant,
    /// First tick not yet swept by [`TimerWheel::pop_due`].
    next_tick: u64,
    len: usize,
}

impl<T> TimerWheel<T> {
    /// Empty wheel with its epoch at `now`. `granularity` must be
    /// nonzero and `n_slots` ≥ 2.
    pub fn new(granularity: Duration, n_slots: usize, now: Instant) -> Self {
        assert!(!granularity.is_zero(), "timer wheel granularity must be nonzero");
        assert!(n_slots >= 2, "timer wheel needs at least 2 slots");
        TimerWheel {
            granularity,
            slots: (0..n_slots).map(|_| Vec::new()).collect(),
            base: now,
            next_tick: 0,
            len: 0,
        }
    }

    fn tick_of(&self, t: Instant) -> u64 {
        (t.saturating_duration_since(self.base).as_nanos() / self.granularity.as_nanos()) as u64
    }

    /// Number of armed entries.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no entries are armed.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Arm `item` to fire at `deadline`. A deadline already in the past
    /// (or inside the current tick) fires on the next [`Self::pop_due`].
    pub fn insert(&mut self, deadline: Instant, item: T) {
        let tick = self.tick_of(deadline).max(self.next_tick);
        let slot = (tick % self.slots.len() as u64) as usize;
        self.slots[slot].push((tick, item));
        self.len += 1;
    }

    /// Sweep the wheel up to `now` and return every fired entry. Sweeps
    /// at most one full turn of slots per call regardless of how long the
    /// caller slept, which still visits every bucket once.
    pub fn pop_due(&mut self, now: Instant) -> Vec<T> {
        let mut fired = Vec::new();
        if self.len == 0 {
            self.next_tick = self.tick_of(now) + 1;
            return fired;
        }
        let now_tick = self.tick_of(now);
        if now_tick < self.next_tick {
            return fired;
        }
        let n = self.slots.len() as u64;
        let span = now_tick - self.next_tick + 1;
        if span >= n {
            // Slept a full turn (or more): every slot's window has
            // passed at least once — one linear pass over all buckets.
            for slot in &mut self.slots {
                let mut i = 0;
                while i < slot.len() {
                    if slot[i].0 <= now_tick {
                        fired.push(slot.swap_remove(i).1);
                    } else {
                        i += 1;
                    }
                }
            }
        } else {
            for tick in self.next_tick..=now_tick {
                let slot = &mut self.slots[(tick % n) as usize];
                let mut i = 0;
                while i < slot.len() {
                    if slot[i].0 <= now_tick {
                        fired.push(slot.swap_remove(i).1);
                    } else {
                        i += 1;
                    }
                }
            }
        }
        self.len -= fired.len();
        self.next_tick = now_tick + 1;
        fired
    }

    /// Earliest armed deadline (None when empty). Linear in armed
    /// entries — the reactor holds at most one entry per job, so this is
    /// cheap enough to call once per loop iteration.
    pub fn next_deadline(&self) -> Option<Instant> {
        let mut min: Option<u64> = None;
        for slot in &self.slots {
            for &(tick, _) in slot {
                min = Some(min.map_or(tick, |m: u64| m.min(tick)));
            }
        }
        min.map(|tick| {
            // End of the entry's tick window, so sleeping exactly until
            // the returned instant guarantees `pop_due` fires it.
            let nanos = self.granularity.as_nanos().saturating_mul(tick as u128 + 1);
            self.base + Duration::from_nanos(nanos.min(u64::MAX as u128) as u64)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const G: Duration = Duration::from_millis(10);

    #[test]
    fn fires_in_deadline_order_within_granularity() {
        let base = Instant::now();
        let mut wheel: TimerWheel<u32> = TimerWheel::new(G, 8, base);
        wheel.insert(base + Duration::from_millis(35), 3);
        wheel.insert(base + Duration::from_millis(15), 1);
        wheel.insert(base + Duration::from_millis(25), 2);
        assert_eq!(wheel.len(), 3);

        assert!(wheel.pop_due(base + Duration::from_millis(5)).is_empty());
        assert_eq!(wheel.pop_due(base + Duration::from_millis(19)), vec![1]);
        let rest = wheel.pop_due(base + Duration::from_millis(60));
        assert_eq!(rest.len(), 2);
        assert!(rest.contains(&2) && rest.contains(&3));
        assert!(wheel.is_empty());
    }

    #[test]
    fn past_deadlines_fire_immediately() {
        let base = Instant::now();
        let mut wheel: TimerWheel<&str> = TimerWheel::new(G, 8, base);
        // Sweep forward first, then arm something "in the past".
        wheel.pop_due(base + Duration::from_millis(100));
        wheel.insert(base + Duration::from_millis(20), "late");
        assert_eq!(wheel.pop_due(base + Duration::from_millis(120)), vec!["late"]);
    }

    #[test]
    fn far_deadlines_wait_their_turn() {
        let base = Instant::now();
        // 4 slots × 10 ms = one 40 ms turn; a 95 ms deadline shares a
        // slot with early ticks but must not fire on the first pass.
        let mut wheel: TimerWheel<u32> = TimerWheel::new(G, 4, base);
        wheel.insert(base + Duration::from_millis(95), 9);
        wheel.insert(base + Duration::from_millis(15), 1);
        assert_eq!(wheel.pop_due(base + Duration::from_millis(20)), vec![1]);
        assert!(wheel.pop_due(base + Duration::from_millis(60)).is_empty());
        assert_eq!(wheel.pop_due(base + Duration::from_millis(100)), vec![9]);
    }

    #[test]
    fn long_sleep_sweeps_every_slot_once() {
        let base = Instant::now();
        let mut wheel: TimerWheel<u32> = TimerWheel::new(G, 4, base);
        for i in 0..8u32 {
            wheel.insert(base + Duration::from_millis(10 * (i as u64 + 1)), i);
        }
        // Caller slept many turns: everything due fires in one call.
        let mut fired = wheel.pop_due(base + Duration::from_secs(5));
        fired.sort_unstable();
        assert_eq!(fired, (0..8).collect::<Vec<_>>());
        assert!(wheel.is_empty());
    }

    #[test]
    fn next_deadline_upper_bounds_the_earliest_entry() {
        let base = Instant::now();
        let mut wheel: TimerWheel<u32> = TimerWheel::new(G, 8, base);
        assert!(wheel.next_deadline().is_none());
        let deadline = base + Duration::from_millis(42);
        wheel.insert(deadline, 1);
        let nd = wheel.next_deadline().unwrap();
        assert!(nd >= deadline.checked_sub(G).unwrap(), "deadline too early");
        assert!(nd <= deadline + G, "deadline too late");
    }

    #[test]
    fn wait_readable_times_out_then_sees_data() {
        let socket = UdpSocket::bind("127.0.0.1:0").unwrap();
        let ready = wait_readable(&socket, Some(Duration::from_millis(20))).unwrap();
        #[cfg(unix)]
        assert!(!ready, "empty socket reported readable");
        #[cfg(not(unix))]
        let _ = ready;

        let sender = UdpSocket::bind("127.0.0.1:0").unwrap();
        sender.send_to(b"ping", socket.local_addr().unwrap()).unwrap();
        assert!(wait_readable(&socket, Some(Duration::from_secs(2))).unwrap());
        let mut buf = [0u8; 8];
        let (n, _) = socket.recv_from(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"ping");
    }
}
