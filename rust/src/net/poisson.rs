//! Poisson packet-arrival processes (§V-A2).
//!
//! "Each client uploads model updates following a Poisson process with the
//! rate determined by its network transmission rate." A client with n
//! packets to send at rate λ emits them at the event times of a Poisson
//! process; the superposition at the PS is again Poisson with Σλᵢ.

use crate::sim::SimTime;
use crate::util::Rng;

/// Homogeneous Poisson process generator.
#[derive(Debug, Clone)]
pub struct PoissonProcess {
    rate: f64,
    t: SimTime,
}

impl PoissonProcess {
    /// Start a process at `start` with `rate` events/second.
    pub fn new(rate: f64, start: SimTime) -> Self {
        assert!(rate > 0.0, "poisson rate must be positive");
        PoissonProcess { rate, t: start }
    }

    /// The process rate (events/second).
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Next event time (advances internal clock).
    pub fn next(&mut self, rng: &mut Rng) -> SimTime {
        self.t += rng.exponential(self.rate);
        self.t
    }

    /// Event times for the next `n` events.
    pub fn take(&mut self, rng: &mut Rng, n: usize) -> Vec<SimTime> {
        (0..n).map(|_| self.next(rng)).collect()
    }
}

/// Time to transmit `n` packets at `rate` pkts/s in expectation.
pub fn expected_duration(n: usize, rate: f64) -> f64 {
    n as f64 / rate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interarrival_mean_matches_rate() {
        let mut rng = Rng::new(3);
        let rate = 1000.0;
        let mut p = PoissonProcess::new(rate, 0.0);
        let n = 100_000;
        let times = p.take(&mut rng, n);
        let duration = *times.last().unwrap();
        let empirical_rate = n as f64 / duration;
        assert!(
            (empirical_rate - rate).abs() / rate < 0.02,
            "empirical {empirical_rate}"
        );
    }

    #[test]
    fn strictly_increasing() {
        let mut rng = Rng::new(4);
        let mut p = PoissonProcess::new(50.0, 10.0);
        let mut last = 10.0;
        for _ in 0..1000 {
            let t = p.next(&mut rng);
            assert!(t > last);
            last = t;
        }
    }

    #[test]
    fn superposition_rate_adds() {
        // Merge two processes; the merged count over a window matches Σλ.
        let mut rng = Rng::new(5);
        let mut a = PoissonProcess::new(300.0, 0.0);
        let mut b = PoissonProcess::new(700.0, 0.0);
        let horizon = 50.0;
        let mut count = 0;
        loop {
            let t = a.next(&mut rng);
            if t > horizon {
                break;
            }
            count += 1;
        }
        loop {
            let t = b.next(&mut rng);
            if t > horizon {
                break;
            }
            count += 1;
        }
        let rate = count as f64 / horizon;
        assert!((rate - 1000.0).abs() < 30.0, "rate {rate}");
    }
}
