//! M/G/1 queueing primitives (§V-A2).
//!
//! The PS aggregation pipeline and each client's download/update path are
//! modelled as M/G/1 queues: Poisson arrivals, a general (here Gaussian,
//! zero-truncated) service-time distribution, one server. The simulator
//! uses the exact sample-path recursion; `pollaczek_khinchine` provides
//! the analytic mean waiting time the tests validate against.

use crate::sim::SimTime;

/// Single-server FIFO queue: tracks when the server frees up.
#[derive(Debug, Clone, Default)]
pub struct Mg1Queue {
    next_free: SimTime,
    busy_time: f64,
    served: u64,
    wait_sum: f64,
}

impl Mg1Queue {
    /// Idle queue at time zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Serve a job arriving at `arrival` needing `service` seconds.
    /// Returns its departure time. Lindley recursion:
    /// start = max(arrival, previous departure).
    pub fn serve(&mut self, arrival: SimTime, service: f64) -> SimTime {
        let start = arrival.max(self.next_free);
        let depart = start + service;
        self.wait_sum += start - arrival;
        self.busy_time += service;
        self.served += 1;
        self.next_free = depart;
        depart
    }

    /// Time at which the server next becomes idle.
    pub fn next_free(&self) -> SimTime {
        self.next_free
    }

    /// Mean queueing delay (excluding service) over jobs served so far.
    pub fn mean_wait(&self) -> f64 {
        if self.served == 0 {
            0.0
        } else {
            self.wait_sum / self.served as f64
        }
    }

    /// Jobs served so far.
    pub fn served(&self) -> u64 {
        self.served
    }

    /// Cumulative service time (for utilisation checks).
    pub fn busy_time(&self) -> f64 {
        self.busy_time
    }

    /// Reset between rounds/phases while keeping cumulative stats external.
    pub fn reset(&mut self) {
        *self = Self::default();
    }
}

/// Analytic Pollaczek–Khinchine mean waiting time for an M/G/1 queue:
/// W = λ·E[S²] / (2·(1−ρ)) with ρ = λ·E[S]. Returns None when unstable
/// (ρ ≥ 1).
pub fn pollaczek_khinchine(lambda: f64, mean_s: f64, var_s: f64) -> Option<f64> {
    let rho = lambda * mean_s;
    if rho >= 1.0 {
        return None;
    }
    let es2 = var_s + mean_s * mean_s;
    Some(lambda * es2 / (2.0 * (1.0 - rho)))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn fifo_and_no_overlap() {
        let mut q = Mg1Queue::new();
        let d1 = q.serve(0.0, 1.0);
        let d2 = q.serve(0.5, 1.0); // arrives while busy
        let d3 = q.serve(5.0, 1.0); // arrives after idle period
        assert_eq!(d1, 1.0);
        assert_eq!(d2, 2.0);
        assert_eq!(d3, 6.0);
        assert!((q.mean_wait() - (0.0 + 0.5 + 0.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn pk_formula_md1() {
        // M/D/1: var = 0 ⇒ W = λ·E[S]² / (2(1−ρ)) = 0.5/(2·0.5) = 0.5.
        let w = pollaczek_khinchine(0.5, 1.0, 0.0).unwrap();
        assert!((w - 0.5).abs() < 1e-12);
        assert!(pollaczek_khinchine(1.1, 1.0, 0.0).is_none());
    }

    #[test]
    fn simulation_matches_pollaczek_khinchine() {
        // M/M/1 as a special case of M/G/1: exponential service.
        let lambda = 0.7;
        let mu = 1.0;
        let mut rng = Rng::new(11);
        let mut q = Mg1Queue::new();
        let mut t = 0.0;
        let n = 200_000;
        for _ in 0..n {
            t += rng.exponential(lambda);
            let s = rng.exponential(mu);
            q.serve(t, s);
        }
        let analytic =
            pollaczek_khinchine(lambda, 1.0 / mu, 1.0 / (mu * mu)).unwrap();
        let sim = q.mean_wait();
        assert!(
            (sim - analytic).abs() / analytic < 0.05,
            "sim {sim} vs analytic {analytic}"
        );
    }

    #[test]
    fn utilisation_tracks_busy_time() {
        let mut q = Mg1Queue::new();
        q.serve(0.0, 2.0);
        q.serve(10.0, 3.0);
        assert_eq!(q.busy_time(), 5.0);
        assert_eq!(q.served(), 2);
    }
}
