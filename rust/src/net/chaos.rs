//! Deterministic network-chaos layer: an in-process UDP proxy that
//! injects loss, duplication, bounded reordering and bit corruption into
//! both directions of a client↔server path.
//!
//! The simulator models lossy links analytically (`net::trace`,
//! `ClientOptions::send_loss` covers uplink drops); this module attacks
//! the *real* datagram path so `tests/wire_chaos.rs` can prove the
//! scoreboard-deduped, index-aligned aggregation protocol stays
//! bit-exact under downlink loss, duplication, reordering and corruption
//! too — the ROADMAP "Loss/reorder fuzzing" item.
//!
//! **Determinism contract.** All chaos decisions come from
//! [`crate::util::Rng`] streams derived from a single seed. A
//! [`ChaosLane`] consumes a fixed number of draws per packet in a fixed
//! order (drop, corrupt, duplicate, then one reorder draw per emitted
//! copy), so the same `(seed, config)` applied to the same packet
//! *sequence* makes identical decisions — rerunning a scenario replays
//! the exact same drop/dup/reorder/corrupt pattern per flow. What stays
//! nondeterministic over real sockets is only the arrival interleaving
//! *between* flows (each client flow gets its own lane pair, seeded by
//! flow-creation order).
//!
//! **Knob semantics** (per direction, all independent):
//!
//! * `drop` — probability a datagram vanishes entirely (evaluated first;
//!   a dropped datagram is never duplicated, reordered or corrupted);
//! * `corrupt` — probability 1–3 random bits of the datagram are
//!   flipped before forwarding (the wire CRC must catch these);
//! * `duplicate` — probability a second copy is emitted; each copy then
//!   takes its own reorder draw, so a duplicate can overtake the
//!   original;
//! * `reorder` — probability a copy is held back and released only after
//!   `reorder_depth`-ish later packets have passed (uniform in
//!   `[1, reorder_depth]`) or after `max_hold` elapses, whichever comes
//!   first. The deadline keeps the tail packet of a burst from being
//!   held hostage when no follow-up traffic arrives.

use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, ToSocketAddrs, UdpSocket};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::util::Rng;

/// How often proxy threads wake to flush overdue held-back packets. Must
/// be well under any client retransmission timeout so reordering adds
/// latency, not spurious timeouts.
const TICK: Duration = Duration::from_millis(5);

/// Per-direction chaos knobs. `Default` is a clean (pass-through) link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosDirection {
    /// Probability a datagram is dropped outright.
    pub drop: f64,
    /// Probability a datagram is emitted twice.
    pub duplicate: f64,
    /// Probability a copy is held back (bounded-delay reordering).
    pub reorder: f64,
    /// Probability 1–3 bits of the datagram are flipped.
    pub corrupt: f64,
    /// Maximum later-packet count a held copy waits for before release.
    pub reorder_depth: usize,
    /// Hard deadline on holding a copy back (liveness without traffic).
    pub max_hold: Duration,
}

impl Default for ChaosDirection {
    fn default() -> Self {
        ChaosDirection {
            drop: 0.0,
            duplicate: 0.0,
            reorder: 0.0,
            corrupt: 0.0,
            reorder_depth: 4,
            max_hold: Duration::from_millis(40),
        }
    }
}

impl ChaosDirection {
    /// A clean pass-through direction (no chaos).
    pub fn clean() -> Self {
        ChaosDirection::default()
    }

    /// The classic lossy-link trio; corruption stays off.
    pub fn lossy(drop: f64, duplicate: f64, reorder: f64) -> Self {
        ChaosDirection { drop, duplicate, reorder, ..ChaosDirection::default() }
    }

    /// Add bit-corruption to a direction.
    pub fn with_corrupt(mut self, corrupt: f64) -> Self {
        self.corrupt = corrupt;
        self
    }

    /// True when every rate is zero (the lane is a pure pass-through).
    pub fn is_clean(&self) -> bool {
        self.drop <= 0.0 && self.duplicate <= 0.0 && self.reorder <= 0.0 && self.corrupt <= 0.0
    }
}

/// A full proxy configuration: one seed, one knob set per direction.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ChaosConfig {
    /// Root seed; per-flow, per-direction lanes derive their streams
    /// from it deterministically.
    pub seed: u64,
    /// Client → server direction.
    pub uplink: ChaosDirection,
    /// Server → client direction.
    pub downlink: ChaosDirection,
}

impl ChaosConfig {
    /// Apply the same knobs to both directions.
    pub fn symmetric(seed: u64, both: ChaosDirection) -> Self {
        ChaosConfig { seed, uplink: both, downlink: both }
    }
}

/// Cross-thread counters for one direction.
#[derive(Debug, Default)]
pub struct LaneStats {
    /// Datagrams actually emitted (incl. duplicates and released holds).
    pub forwarded: AtomicU64,
    /// Datagrams removed by the `drop` knob.
    pub dropped: AtomicU64,
    /// Datagrams emitted twice by the `duplicate` knob.
    pub duplicated: AtomicU64,
    /// Copies held back by the `reorder` knob.
    pub reordered: AtomicU64,
    /// Datagrams with 1–3 bits flipped by the `corrupt` knob.
    pub corrupted: AtomicU64,
}

/// Point-in-time copy of [`LaneStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LaneSnapshot {
    /// See [`LaneStats::forwarded`].
    pub forwarded: u64,
    /// See [`LaneStats::dropped`].
    pub dropped: u64,
    /// See [`LaneStats::duplicated`].
    pub duplicated: u64,
    /// See [`LaneStats::reordered`].
    pub reordered: u64,
    /// See [`LaneStats::corrupted`].
    pub corrupted: u64,
}

impl LaneStats {
    fn snapshot(&self) -> LaneSnapshot {
        LaneSnapshot {
            forwarded: self.forwarded.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            duplicated: self.duplicated.load(Ordering::Relaxed),
            reordered: self.reordered.load(Ordering::Relaxed),
            corrupted: self.corrupted.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time proxy counters for reporting.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ChaosSnapshot {
    /// Client → server direction counters.
    pub up: LaneSnapshot,
    /// Server → client direction counters.
    pub down: LaneSnapshot,
    /// Distinct client flows seen so far.
    pub flows: u64,
    /// Datagrams from new sources dropped at the [`MAX_FLOWS`] cap.
    pub flows_rejected: u64,
}

/// Upper bound on concurrent client flows (each one costs a socket and a
/// relay thread). Without it, a blind spray of spoofed source addresses
/// at the proxy port would exhaust file descriptors — the same abuse
/// class the daemon's `MAX_JOBS` cap closes. Datagrams from new sources
/// beyond the cap are dropped (and counted); a real FL job has at most
/// 64 clients per the wire spec, so the default is generous.
pub const MAX_FLOWS: usize = 1024;

/// One direction's chaos engine, decoupled from sockets so the server
/// can embed it on its downlink and tests can drive it deterministically.
/// `M` is opaque per-packet metadata carried through holds (the daemon
/// uses the destination address; the proxy uses `()`).
pub struct ChaosLane<M = ()> {
    cfg: ChaosDirection,
    rng: Rng,
    stats: Arc<LaneStats>,
    /// Held-back copies: (deadline, packets-still-to-pass, bytes, meta).
    held: Vec<(Instant, usize, Vec<u8>, M)>,
}

impl<M: Clone> ChaosLane<M> {
    /// Lane with fresh private stats.
    pub fn new(cfg: ChaosDirection, seed: u64) -> Self {
        Self::with_stats(cfg, seed, Arc::new(LaneStats::default()))
    }

    /// Lane reporting into shared (e.g. per-direction) stats.
    pub fn with_stats(cfg: ChaosDirection, seed: u64, stats: Arc<LaneStats>) -> Self {
        ChaosLane { cfg, rng: Rng::new(seed ^ 0xC4A0_5EED), stats, held: Vec::new() }
    }

    /// The lane's counters.
    pub fn stats(&self) -> &Arc<LaneStats> {
        &self.stats
    }

    /// Number of copies currently held back.
    pub fn held_len(&self) -> usize {
        self.held.len()
    }

    /// Run one incoming datagram through the chaos decisions. Returns the
    /// datagrams to emit *now*, in order — possibly none (dropped or
    /// held), possibly several (a duplicate and/or holds released by this
    /// packet's passage).
    pub fn process(&mut self, pkt: &[u8], meta: M, now: Instant) -> Vec<(Vec<u8>, M)> {
        let mut out = Vec::new();
        if self.rng.f64() < self.cfg.drop {
            bump(&self.stats.dropped);
            // A dropped packet still "passes" the existing holds.
            self.release(&mut out, now, true);
            return out;
        }
        let mut bytes = pkt.to_vec();
        if self.rng.f64() < self.cfg.corrupt {
            self.flip_bits(&mut bytes);
            bump(&self.stats.corrupted);
        }
        let copies = if self.rng.f64() < self.cfg.duplicate {
            bump(&self.stats.duplicated);
            2
        } else {
            1
        };
        let mut new_holds = Vec::new();
        for _ in 0..copies {
            if self.rng.f64() < self.cfg.reorder && self.cfg.reorder_depth > 0 {
                let wait = 1 + self.rng.below(self.cfg.reorder_depth);
                new_holds.push((now + self.cfg.max_hold, wait, bytes.clone(), meta.clone()));
                bump(&self.stats.reordered);
            } else {
                out.push((bytes.clone(), meta.clone()));
                bump(&self.stats.forwarded);
            }
        }
        // Existing holds see this packet pass — released ones come out
        // *after* the current packet (that is the reordering). The copies
        // held just above join the queue only now, so they cannot count
        // their own packet's passage.
        self.release(&mut out, now, true);
        self.held.extend(new_holds);
        out
    }

    /// Release holds that are past their deadline (call on idle ticks so
    /// the last packet of a burst is not held forever).
    pub fn flush_due(&mut self, now: Instant) -> Vec<(Vec<u8>, M)> {
        let mut out = Vec::new();
        self.release(&mut out, now, false);
        out
    }

    /// Release every hold immediately (drain on shutdown).
    pub fn flush_all(&mut self) -> Vec<(Vec<u8>, M)> {
        let mut out = Vec::new();
        for (_, _, bytes, meta) in self.held.drain(..) {
            bump(&self.stats.forwarded);
            out.push((bytes, meta));
        }
        out
    }

    fn release(&mut self, out: &mut Vec<(Vec<u8>, M)>, now: Instant, packet_passed: bool) {
        let mut i = 0;
        while i < self.held.len() {
            if packet_passed {
                self.held[i].1 = self.held[i].1.saturating_sub(1);
            }
            if self.held[i].1 == 0 || self.held[i].0 <= now {
                let (_, _, bytes, meta) = self.held.swap_remove(i);
                bump(&self.stats.forwarded);
                out.push((bytes, meta));
            } else {
                i += 1;
            }
        }
    }

    fn flip_bits(&mut self, bytes: &mut [u8]) {
        if bytes.is_empty() {
            return;
        }
        let flips = 1 + self.rng.below(3);
        for _ in 0..flips {
            let bit = self.rng.below(bytes.len() * 8);
            bytes[bit / 8] ^= 1 << (bit % 8);
        }
    }
}

#[inline]
fn bump(counter: &AtomicU64) {
    counter.fetch_add(1, Ordering::Relaxed);
}

/// Proxy configuration: where to listen, where the real server is, and
/// the chaos to inject.
#[derive(Debug, Clone)]
pub struct ChaosProxyOptions {
    /// Client-facing bind address, e.g. "127.0.0.1:0" for tests.
    pub listen: String,
    /// The real server address datagrams are relayed to.
    pub upstream: String,
    /// Seed + per-direction knobs.
    pub config: ChaosConfig,
}

/// Running proxy handle: address, live stats, shutdown.
pub struct ChaosHandle {
    addr: SocketAddr,
    up_stats: Arc<LaneStats>,
    down_stats: Arc<LaneStats>,
    flows: Arc<AtomicU64>,
    flows_rejected: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    main: Option<JoinHandle<()>>,
}

impl ChaosHandle {
    /// The client-facing address (point `ClientOptions::server` here).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Point-in-time copy of both directions' counters.
    pub fn snapshot(&self) -> ChaosSnapshot {
        ChaosSnapshot {
            up: self.up_stats.snapshot(),
            down: self.down_stats.snapshot(),
            flows: self.flows.load(Ordering::Relaxed),
            flows_rejected: self.flows_rejected.load(Ordering::Relaxed),
        }
    }

    /// Stop the forwarder and join every flow thread.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.main.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosHandle {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.main.take() {
            let _ = h.join();
        }
    }
}

/// One client flow: its NAT socket toward the server, the uplink lane,
/// and the downlink relay thread feeding replies back.
struct Flow {
    up_sock: UdpSocket,
    lane: ChaosLane<()>,
    relay: JoinHandle<()>,
}

/// Start a chaos proxy. Clients talk to [`ChaosHandle::local_addr`];
/// each distinct client source address gets its own upstream socket
/// (NAT-style), so the server still sees one address per client and its
/// Join address book / reflection budgeting keep working through the
/// proxy.
pub fn chaos_proxy(opts: &ChaosProxyOptions) -> io::Result<ChaosHandle> {
    let down_sock = UdpSocket::bind(&opts.listen)?;
    down_sock.set_read_timeout(Some(TICK))?;
    let addr = down_sock.local_addr()?;
    let upstream: SocketAddr = opts
        .upstream
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "upstream did not resolve"))?;
    let up_stats = Arc::new(LaneStats::default());
    let down_stats = Arc::new(LaneStats::default());
    let flows = Arc::new(AtomicU64::new(0));
    let flows_rejected = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));

    let main = {
        let cfg = opts.config;
        let up_stats = Arc::clone(&up_stats);
        let down_stats = Arc::clone(&down_stats);
        let flows = Arc::clone(&flows);
        let flows_rejected = Arc::clone(&flows_rejected);
        let stop = Arc::clone(&stop);
        thread::Builder::new().name("fediac-chaos".into()).spawn(move || {
            proxy_loop(down_sock, upstream, cfg, up_stats, down_stats, flows, flows_rejected, stop);
        })?
    };

    Ok(ChaosHandle { addr, up_stats, down_stats, flows, flows_rejected, stop, main: Some(main) })
}

#[allow(clippy::too_many_arguments)]
fn proxy_loop(
    down_sock: UdpSocket,
    upstream: SocketAddr,
    cfg: ChaosConfig,
    up_stats: Arc<LaneStats>,
    down_stats: Arc<LaneStats>,
    flow_count: Arc<AtomicU64>,
    flows_rejected: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
) {
    let mut flows: HashMap<SocketAddr, Flow> = HashMap::new();
    let mut next_flow = 0u64;
    let mut buf = vec![0u8; 65536];
    while !stop.load(Ordering::SeqCst) {
        match down_sock.recv_from(&mut buf) {
            Ok((n, from)) => {
                if !flows.contains_key(&from) {
                    if flows.len() >= MAX_FLOWS {
                        flows_rejected.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    match spawn_flow(
                        &down_sock,
                        upstream,
                        from,
                        &cfg,
                        next_flow,
                        Arc::clone(&up_stats),
                        Arc::clone(&down_stats),
                        Arc::clone(&stop),
                    ) {
                        Ok(flow) => {
                            next_flow += 1;
                            flow_count.fetch_add(1, Ordering::Relaxed);
                            flows.insert(from, flow);
                        }
                        Err(_) => continue,
                    }
                }
                let flow = flows.get_mut(&from).expect("flow just ensured");
                let now = Instant::now();
                for (pkt, ()) in flow.lane.process(&buf[..n], (), now) {
                    let _ = flow.up_sock.send(&pkt);
                }
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut => {}
            // Transient socket errors (e.g. an ICMP unreachable surfacing
            // as ECONNRESET after a client exits) must not tear the proxy
            // down for every other flow; back off briefly and carry on.
            Err(_) => thread::sleep(Duration::from_millis(1)),
        }
        // Idle tick: release overdue held-back uplink copies.
        let now = Instant::now();
        for flow in flows.values_mut() {
            for (pkt, ()) in flow.lane.flush_due(now) {
                let _ = flow.up_sock.send(&pkt);
            }
        }
    }
    for (_, flow) in flows {
        let _ = flow.relay.join();
    }
}

#[allow(clippy::too_many_arguments)]
fn spawn_flow(
    down_sock: &UdpSocket,
    upstream: SocketAddr,
    client: SocketAddr,
    cfg: &ChaosConfig,
    flow_idx: u64,
    up_stats: Arc<LaneStats>,
    down_stats: Arc<LaneStats>,
    stop: Arc<AtomicBool>,
) -> io::Result<Flow> {
    // Bind on the unspecified address of the upstream's family so the
    // proxy also works across real hosts, not just loopback.
    let bind_any = if upstream.is_ipv4() { "0.0.0.0:0" } else { "[::]:0" };
    let up_sock = UdpSocket::bind(bind_any)?;
    up_sock.connect(upstream)?;
    up_sock.set_read_timeout(Some(TICK))?;
    let relay_sock = up_sock.try_clone()?;
    let reply_sock = down_sock.try_clone()?;
    // Flow lanes derive their streams from (seed, flow index, direction).
    let lane = ChaosLane::with_stats(cfg.uplink, cfg.seed ^ (flow_idx << 1), up_stats);
    let mut down_lane: ChaosLane<()> =
        ChaosLane::with_stats(cfg.downlink, cfg.seed ^ (flow_idx << 1) ^ 1, down_stats);
    let relay = thread::Builder::new().name(format!("fediac-chaos-dl-{flow_idx}")).spawn(
        move || {
            let mut buf = vec![0u8; 65536];
            while !stop.load(Ordering::SeqCst) {
                match relay_sock.recv(&mut buf) {
                    Ok(n) => {
                        let now = Instant::now();
                        for (pkt, ()) in down_lane.process(&buf[..n], (), now) {
                            let _ = reply_sock.send_to(&pkt, client);
                        }
                    }
                    Err(e)
                        if e.kind() == io::ErrorKind::WouldBlock
                            || e.kind() == io::ErrorKind::TimedOut => {}
                    // E.g. ECONNREFUSED while the upstream restarts:
                    // back off briefly instead of spinning.
                    Err(_) => thread::sleep(Duration::from_millis(1)),
                }
                let now = Instant::now();
                for (pkt, ()) in down_lane.flush_due(now) {
                    let _ = reply_sock.send_to(&pkt, client);
                }
            }
        },
    )?;
    Ok(Flow { up_sock, lane, relay })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seq_packets(n: usize) -> Vec<Vec<u8>> {
        (0..n).map(|i| (i as u32).to_le_bytes().to_vec()).collect()
    }

    fn run_lane(cfg: ChaosDirection, seed: u64, pkts: &[Vec<u8>]) -> Vec<Vec<u8>> {
        let mut lane: ChaosLane<()> = ChaosLane::new(cfg, seed);
        let base = Instant::now();
        let mut out = Vec::new();
        for (i, p) in pkts.iter().enumerate() {
            let now = base + Duration::from_millis(i as u64);
            out.extend(lane.process(p, (), now).into_iter().map(|(b, ())| b));
        }
        // Drain whatever is still held (deadline far in the future).
        out.extend(lane.flush_all().into_iter().map(|(b, ())| b));
        out
    }

    #[test]
    fn lane_is_deterministic_per_seed() {
        let cfg = ChaosDirection::lossy(0.2, 0.15, 0.3).with_corrupt(0.1);
        let pkts = seq_packets(500);
        let a = run_lane(cfg, 42, &pkts);
        let b = run_lane(cfg, 42, &pkts);
        assert_eq!(a, b, "same seed must replay the same chaos");
        let c = run_lane(cfg, 43, &pkts);
        assert_ne!(a, c, "different seeds should diverge");
    }

    #[test]
    fn clean_lane_is_identity() {
        let pkts = seq_packets(100);
        let out = run_lane(ChaosDirection::clean(), 7, &pkts);
        assert_eq!(out, pkts);
    }

    #[test]
    fn lossless_lane_conserves_packets() {
        // No drop, no corruption: every input appears in the output
        // (maybe twice for duplicates), just possibly out of order.
        let cfg = ChaosDirection::lossy(0.0, 0.2, 0.4);
        let pkts = seq_packets(300);
        let out = run_lane(cfg, 11, &pkts);
        let mut counts: HashMap<Vec<u8>, usize> = HashMap::new();
        for p in &out {
            *counts.entry(p.clone()).or_insert(0) += 1;
        }
        for p in &pkts {
            let c = counts.get(p).copied().unwrap_or(0);
            assert!(c == 1 || c == 2, "packet {p:?} emitted {c} times");
        }
        assert!(out.len() > pkts.len(), "no duplicate ever fired");
        assert_ne!(out[..pkts.len()], pkts[..], "no reordering happened");
    }

    #[test]
    fn drop_rate_matches_configuration() {
        let cfg = ChaosDirection::lossy(0.3, 0.0, 0.0);
        let lane: ChaosLane<()> = ChaosLane::new(cfg, 5);
        let stats = Arc::clone(lane.stats());
        let mut lane = lane;
        let base = Instant::now();
        let pkts = seq_packets(10_000);
        for p in &pkts {
            lane.process(p, (), base);
        }
        let dropped = stats.dropped.load(Ordering::Relaxed) as f64 / pkts.len() as f64;
        assert!((0.25..0.35).contains(&dropped), "drop rate {dropped}");
    }

    #[test]
    fn reorder_is_bounded_by_depth_and_deadline() {
        let cfg = ChaosDirection { reorder: 1.0, reorder_depth: 3, ..ChaosDirection::default() };
        let mut lane: ChaosLane<()> = ChaosLane::new(cfg, 9);
        let base = Instant::now();
        // Every packet is held; each later packet decrements the holds,
        // so nothing can lag more than `reorder_depth` packets behind.
        let pkts = seq_packets(50);
        let mut emitted = 0usize;
        for (i, p) in pkts.iter().enumerate() {
            emitted += lane.process(p, (), base).len();
            assert!(lane.held_len() <= cfg.reorder_depth, "hold queue grew past depth at {i}");
        }
        // The stragglers release on the deadline tick even with no more
        // traffic.
        emitted += lane.flush_due(base + cfg.max_hold + Duration::from_millis(1)).len();
        assert_eq!(emitted, pkts.len());
    }

    #[test]
    fn corruption_flips_bits_but_keeps_length() {
        let cfg = ChaosDirection { corrupt: 1.0, ..ChaosDirection::default() };
        let mut lane: ChaosLane<()> = ChaosLane::new(cfg, 3);
        let pkt = vec![0u8; 64];
        let mut mutated = 0;
        for _ in 0..16 {
            let out = lane.process(&pkt, (), Instant::now());
            assert_eq!(out.len(), 1);
            assert_eq!(out[0].0.len(), pkt.len(), "corruption changed the length");
            if out[0].0 != pkt {
                mutated += 1;
            }
        }
        // An even number of flips can land on one bit and cancel, but not
        // 16 packets in a row.
        assert!(mutated > 0, "corruption never flipped a bit");
    }

    #[test]
    fn proxy_relays_both_directions() {
        // Echo "server": replies with the payload reversed.
        let server = UdpSocket::bind("127.0.0.1:0").unwrap();
        server.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        let server_addr = server.local_addr().unwrap();
        let echo = thread::spawn(move || {
            let mut buf = [0u8; 256];
            let (n, from) = server.recv_from(&mut buf).unwrap();
            let mut reply = buf[..n].to_vec();
            reply.reverse();
            server.send_to(&reply, from).unwrap();
        });

        let handle = chaos_proxy(&ChaosProxyOptions {
            listen: "127.0.0.1:0".into(),
            upstream: server_addr.to_string(),
            config: ChaosConfig::default(),
        })
        .unwrap();

        let client = UdpSocket::bind("127.0.0.1:0").unwrap();
        client.set_read_timeout(Some(Duration::from_secs(2))).unwrap();
        client.send_to(b"chaos", handle.local_addr()).unwrap();
        let mut buf = [0u8; 256];
        let (n, _) = client.recv_from(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"soahc");
        echo.join().unwrap();

        let snap = handle.snapshot();
        assert_eq!(snap.flows, 1);
        assert_eq!(snap.up.forwarded, 1);
        assert_eq!(snap.down.forwarded, 1);
        handle.shutdown();
    }

    #[test]
    fn proxy_full_drop_blackholes_uplink() {
        let server = UdpSocket::bind("127.0.0.1:0").unwrap();
        server.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        let server_addr = server.local_addr().unwrap();
        let handle = chaos_proxy(&ChaosProxyOptions {
            listen: "127.0.0.1:0".into(),
            upstream: server_addr.to_string(),
            config: ChaosConfig {
                seed: 1,
                uplink: ChaosDirection::lossy(1.0, 0.0, 0.0),
                downlink: ChaosDirection::clean(),
            },
        })
        .unwrap();
        let client = UdpSocket::bind("127.0.0.1:0").unwrap();
        client.send_to(b"void", handle.local_addr()).unwrap();
        let mut buf = [0u8; 16];
        assert!(server.recv_from(&mut buf).is_err(), "dropped datagram arrived");
        assert_eq!(handle.snapshot().up.dropped, 1);
        handle.shutdown();
    }
}
