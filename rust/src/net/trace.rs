//! Synthetic cellular upload traces (NYC-subway substitute).
//!
//! The paper assigns client upload rates from packet traces "collected from
//! scenarios of subway traveling in New York City" [38], yielding rates of
//! 200–2,800 packets/s across clients (§V-A2). Those traces are not
//! public, so this generator reproduces the two properties the experiments
//! actually consume:
//!
//! 1. heterogeneous *mean* rates across clients spanning that range, and
//! 2. heavy-tailed within-trace variability (tunnels vs stations vs moving)
//!    via a regime-switching Markov chain.
//!
//! DESIGN.md §2 substitution 2 documents this.

use crate::util::Rng;

/// Paper-reported lower bound on per-client upload rates (packets/s).
pub const MIN_RATE: f64 = 200.0;
/// Paper-reported upper bound on per-client upload rates (packets/s).
pub const MAX_RATE: f64 = 2_800.0;

/// Connectivity regime of a subway rider.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Regime {
    /// Deep tunnel: weak link.
    Tunnel,
    /// Moving between stations: medium link.
    Moving,
    /// In/near a station: strong link.
    Station,
}

impl Regime {
    /// Rate multiplier applied to the client's base rate.
    fn multiplier(self) -> f64 {
        match self {
            Regime::Tunnel => 0.25,
            Regime::Moving => 1.0,
            Regime::Station => 1.8,
        }
    }

    /// Markov transition: rides alternate tunnel → moving → station.
    fn next(self, rng: &mut Rng) -> Regime {
        let u = rng.f64();
        match self {
            Regime::Tunnel => {
                if u < 0.6 {
                    Regime::Tunnel
                } else {
                    Regime::Moving
                }
            }
            Regime::Moving => {
                if u < 0.3 {
                    Regime::Tunnel
                } else if u < 0.6 {
                    Regime::Moving
                } else {
                    Regime::Station
                }
            }
            Regime::Station => {
                if u < 0.5 {
                    Regime::Station
                } else {
                    Regime::Moving
                }
            }
        }
    }
}

/// One client's synthetic trace: a piecewise-constant rate function.
#[derive(Debug, Clone)]
pub struct CellularTrace {
    /// (segment start time s, rate pkts/s); segments are contiguous.
    segments: Vec<(f64, f64)>,
    /// Total generated horizon (s); `rate_at` extends periodically.
    horizon_s: f64,
    /// Mean over the generated horizon.
    mean_rate: f64,
}

impl CellularTrace {
    /// Generate a trace of `horizon_s` seconds with ~`segment_s`-long
    /// regimes around a log-uniform base rate.
    pub fn generate(rng: &mut Rng, horizon_s: f64, segment_s: f64) -> Self {
        // Log-uniform base so the population spreads across the range the
        // way heterogeneous radio conditions do.
        let log_lo = (MIN_RATE * 1.6).ln();
        let log_hi = (MAX_RATE / 1.9).ln();
        let base = rng.range_f64(log_lo, log_hi).exp();
        let mut regime = Regime::Moving;
        let mut t = 0.0;
        let mut segments = Vec::new();
        let mut weighted = 0.0;
        while t < horizon_s {
            let dur = rng.exponential(1.0 / segment_s).min(horizon_s - t).max(0.01);
            let rate = (base * regime.multiplier()).clamp(MIN_RATE, MAX_RATE);
            segments.push((t, rate));
            weighted += rate * dur;
            t += dur;
            regime = regime.next(rng);
        }
        CellularTrace { segments, horizon_s, mean_rate: weighted / horizon_s }
    }

    /// Rate at simulated time `t` (clamped into the horizon; periodic
    /// extension past the end).
    pub fn rate_at(&self, t: f64) -> f64 {
        let t = if t < 0.0 { 0.0 } else { t % self.horizon_s.max(1.0) };
        match self.segments.binary_search_by(|&(s, _)| s.partial_cmp(&t).unwrap()) {
            Ok(i) => self.segments[i].1,
            Err(0) => self.segments[0].1,
            Err(i) => self.segments[i - 1].1,
        }
    }

    /// Time-averaged rate over the whole trace.
    pub fn mean_rate(&self) -> f64 {
        self.mean_rate
    }
}

/// Population helper: one mean upload rate per client, as the experiments
/// use (§V-A2 assigns the trace-calculated rate to each client).
pub fn client_rates(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x7ace);
    (0..n)
        .map(|i| {
            let mut r = rng.fork(i as u64);
            CellularTrace::generate(&mut r, 600.0, 30.0).mean_rate()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_within_paper_range() {
        let rates = client_rates(100, 1);
        for &r in &rates {
            assert!((MIN_RATE..=MAX_RATE).contains(&r), "rate {r}");
        }
    }

    #[test]
    fn rates_are_heterogeneous() {
        let rates = client_rates(50, 2);
        let min = rates.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = rates.iter().cloned().fold(0.0, f64::max);
        assert!(max / min > 2.0, "spread too small: {min}..{max}");
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(client_rates(10, 3), client_rates(10, 3));
        assert_ne!(client_rates(10, 3), client_rates(10, 4));
    }

    #[test]
    fn rate_at_piecewise_lookup() {
        let mut rng = Rng::new(5);
        let trace = CellularTrace::generate(&mut rng, 100.0, 10.0);
        for t in [0.0, 1.0, 50.0, 99.9, 150.0] {
            let r = trace.rate_at(t);
            assert!((MIN_RATE..=MAX_RATE).contains(&r));
        }
    }

    #[test]
    fn mean_rate_consistent_with_segments() {
        let mut rng = Rng::new(6);
        let trace = CellularTrace::generate(&mut rng, 200.0, 20.0);
        // Numeric average of rate_at over the horizon ≈ stored mean.
        let samples = 2000;
        let avg: f64 = (0..samples)
            .map(|i| trace.rate_at(i as f64 * 200.0 / samples as f64))
            .sum::<f64>()
            / samples as f64;
        assert!(
            (avg - trace.mean_rate()).abs() / trace.mean_rate() < 0.05,
            "avg {avg} vs mean {}",
            trace.mean_rate()
        );
    }
}
