//! Power-law magnitude model fit (Definition 1).
//!
//! The analysis assumes |U{l}| ≤ φ·l^α for the rank-l update (descending
//! magnitude order, α < 0). §IV-D's implementation note: in the first
//! global iteration a parameter server "can fit the power-law distribution
//! in reported model updates to obtain α and φ", then derive a and b.
//! This module is that fit: OLS on (log rank, log magnitude).

use crate::util::stats::linear_fit;

/// Fitted power-law parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerLaw {
    /// Scale φ (magnitude of the rank-1 update).
    pub phi: f64,
    /// Decay exponent α < 0.
    pub alpha: f64,
}

impl PowerLaw {
    /// Predicted magnitude of the rank-l (1-based) update.
    pub fn magnitude(&self, rank: usize) -> f64 {
        self.phi * (rank as f64).powf(self.alpha)
    }
}

/// Fit φ, α from one round of model updates.
///
/// Magnitudes are sorted descending; ranks are subsampled geometrically
/// (every fit point costs a log) and zero magnitudes are skipped. Returns
/// None when fewer than 2 usable points exist.
pub fn fit_power_law(updates: &[f32]) -> Option<PowerLaw> {
    let mut mags: Vec<f64> =
        updates.iter().map(|u| u.abs() as f64).filter(|&m| m > 0.0).collect();
    if mags.len() < 2 {
        return None;
    }
    mags.sort_by(|a, b| b.partial_cmp(a).unwrap());
    // Geometric rank subsampling: ranks 1, ~1.25, ~1.5625, ...
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    let mut rank = 1usize;
    while rank <= mags.len() {
        xs.push((rank as f64).ln());
        ys.push(mags[rank - 1].ln());
        rank = ((rank as f64 * 1.25).ceil() as usize).max(rank + 1);
    }
    if xs.len() < 2 {
        return None;
    }
    let (intercept, slope) = linear_fit(&xs, &ys);
    Some(PowerLaw { phi: intercept.exp(), alpha: slope })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn recovers_exact_power_law() {
        let phi = 0.5;
        let alpha = -0.8;
        let updates: Vec<f32> = (1..=5000)
            .map(|l| (phi * (l as f64).powf(alpha)) as f32)
            .collect();
        let fit = fit_power_law(&updates).unwrap();
        assert!((fit.alpha - alpha).abs() < 0.02, "alpha {}", fit.alpha);
        assert!((fit.phi - phi).abs() / phi < 0.05, "phi {}", fit.phi);
    }

    #[test]
    fn recovers_under_shuffle_and_sign() {
        let mut rng = Rng::new(1);
        let mut updates: Vec<f32> = (1..=4000)
            .map(|l| {
                let sign = if rng.f64() < 0.5 { -1.0 } else { 1.0 };
                (sign * 0.2 * (l as f64).powf(-0.6)) as f32
            })
            .collect();
        rng.shuffle(&mut updates);
        let fit = fit_power_law(&updates).unwrap();
        assert!((fit.alpha + 0.6).abs() < 0.03, "alpha {}", fit.alpha);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(fit_power_law(&[]).is_none());
        assert!(fit_power_law(&[1.0]).is_none());
        assert!(fit_power_law(&[0.0, 0.0, 0.0]).is_none());
        assert!(fit_power_law(&[1.0, 0.5]).is_some());
    }

    #[test]
    fn magnitude_prediction() {
        let pl = PowerLaw { phi: 1.0, alpha: -1.0 };
        assert!((pl.magnitude(1) - 1.0).abs() < 1e-12);
        assert!((pl.magnitude(4) - 0.25).abs() < 1e-12);
    }
}
