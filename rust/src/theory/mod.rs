//! Analytic machinery of §IV-B/C: power-law fit (Definition 1),
//! Proposition 1 (γ, E[k_S]), Corollary 1 (bit lower bound) and the
//! Theorem-1 convergence bound.

pub mod convergence;
pub mod corollary1;
pub mod power_law;
pub mod prop1;

pub use corollary1::{bits_lower_bound, min_bits};
pub use power_law::{fit_power_law, PowerLaw};
pub use prop1::{evaluate as prop1_evaluate, Prop1Output, Prop1Params};
