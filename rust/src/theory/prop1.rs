//! Proposition 1: the FediAC compression-error bound γ.
//!
//! Chain of quantities (§IV-B, Eqs. 2–5):
//!   p_l — probability one vote lands on the rank-l update,
//!   q_l — probability client votes rank-l at least once in k draws,
//!   r_l — probability ≥ a of N clients vote rank-l (GIA inclusion),
//!   E[k_S] = Σ r_l — expected uploaded dimensions,
//!   γ — bound on E‖Π(Θ(fU)) − fU‖² / ‖fU‖².
//!
//! `examples/theory_explorer.rs` (E7) Monte-Carlo-validates these.

use crate::theory::power_law::PowerLaw;

/// Inputs to the Proposition-1 computation.
#[derive(Debug, Clone, Copy)]
pub struct Prop1Params {
    /// Model dimension d.
    pub d: usize,
    /// Clients N.
    pub n_clients: usize,
    /// Votes per client (k in the paper).
    pub k: usize,
    /// Consensus threshold a.
    pub threshold_a: usize,
    /// Fitted power law (α, φ).
    pub law: PowerLaw,
    /// Quantisation bits b.
    pub bits_b: usize,
}

/// Full analytic output of Proposition 1.
#[derive(Debug, Clone)]
pub struct Prop1Output {
    /// GIA-inclusion probability per rank, r_l (Eq. 4).
    pub r: Vec<f64>,
    /// Expected uploaded dimensions E[k_S] = Σ r_l.
    pub expected_uploads: f64,
    /// Compression error bound γ (Eq. 5).
    pub gamma: f64,
    /// Amplification factor f = (2^{b−1} − N)/(N·m), m = φ.
    pub f: f64,
}

/// Vote probability p_l = l^α / Σ l'^α (Eq. 2).
pub fn vote_prob(d: usize, alpha: f64) -> Vec<f64> {
    let weights: Vec<f64> = (1..=d).map(|l| (l as f64).powf(alpha)).collect();
    let sum: f64 = weights.iter().sum();
    weights.into_iter().map(|w| w / sum).collect()
}

/// q_l = 1 − (1 − p_l)^k (Eq. 3). Uses ln1p for small p numerical safety.
pub fn voted_prob(p: &[f64], k: usize) -> Vec<f64> {
    p.iter().map(|&pl| 1.0 - ((1.0 - pl).ln() * k as f64).exp()).collect()
}

/// Binomial upper tail P[X ≥ a], X ~ Bin(n, q), computed by a
/// multiplicative pmf recurrence (n ≤ 64 in all experiments).
pub fn binom_tail_geq(n: usize, q: f64, a: usize) -> f64 {
    if a == 0 {
        return 1.0;
    }
    if a > n {
        return 0.0;
    }
    if q <= 0.0 {
        return 0.0;
    }
    if q >= 1.0 {
        return 1.0;
    }
    // pmf(0) = (1-q)^n; pmf(j+1) = pmf(j) · (n-j)/(j+1) · q/(1-q).
    let ratio = q / (1.0 - q);
    let mut pmf = (1.0 - q).powi(n as i32);
    let mut cdf_below = 0.0; // P[X < a]
    for j in 0..a {
        cdf_below += pmf;
        pmf *= (n - j) as f64 / (j + 1) as f64 * ratio;
    }
    (1.0 - cdf_below).clamp(0.0, 1.0)
}

/// Evaluate Proposition 1 end-to-end.
pub fn evaluate(params: &Prop1Params) -> Prop1Output {
    let Prop1Params { d, n_clients, k, threshold_a, law, bits_b } = *params;
    let p = vote_prob(d, law.alpha);
    let q = voted_prob(&p, k);
    let r: Vec<f64> =
        q.iter().map(|&ql| binom_tail_geq(n_clients, ql, threshold_a)).collect();
    let expected_uploads: f64 = r.iter().sum();

    // m = max update magnitude = φ·1^α = φ under Definition 1.
    let m = law.phi;
    let f = ((1u64 << (bits_b - 1)) as f64 - n_clients as f64) / (n_clients as f64 * m);

    // γ = 1 − Σ r_l·l^{2α}/Σ l^{2α} + (1/4f²)·Σ r_l/(φ²·Σ l^{2α})  (Eq. 5).
    let mut sum_l2a = 0.0;
    let mut sum_r_l2a = 0.0;
    for l in 1..=d {
        let w = (l as f64).powf(2.0 * law.alpha);
        sum_l2a += w;
        sum_r_l2a += r[l - 1] * w;
    }
    let gamma = 1.0 - sum_r_l2a / sum_l2a
        + expected_uploads / (4.0 * f * f * law.phi * law.phi * sum_l2a);

    Prop1Output { r, expected_uploads, gamma, f }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn default_law() -> PowerLaw {
        PowerLaw { phi: 0.1, alpha: -0.7 }
    }

    #[test]
    fn vote_prob_normalised_and_decreasing() {
        let p = vote_prob(1000, -0.8);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        for w in p.windows(2) {
            assert!(w[0] >= w[1]);
        }
    }

    #[test]
    fn voted_prob_monotone_in_k() {
        let p = vote_prob(100, -0.5);
        let q1 = voted_prob(&p, 5);
        let q2 = voted_prob(&p, 20);
        for (a, b) in q1.iter().zip(&q2) {
            assert!(b >= a);
        }
    }

    #[test]
    fn binom_tail_exact_small_cases() {
        // n=2, q=0.5: P[X≥1] = 0.75, P[X≥2] = 0.25.
        assert!((binom_tail_geq(2, 0.5, 1) - 0.75).abs() < 1e-12);
        assert!((binom_tail_geq(2, 0.5, 2) - 0.25).abs() < 1e-12);
        assert_eq!(binom_tail_geq(2, 0.5, 0), 1.0);
        assert_eq!(binom_tail_geq(2, 0.5, 3), 0.0);
        assert_eq!(binom_tail_geq(10, 0.0, 1), 0.0);
        assert_eq!(binom_tail_geq(10, 1.0, 10), 1.0);
    }

    #[test]
    fn binom_tail_matches_monte_carlo() {
        use crate::util::Rng;
        let (n, q, a) = (20, 0.3, 7);
        let analytic = binom_tail_geq(n, q, a);
        let mut rng = Rng::new(17);
        let trials = 100_000;
        let mut hits = 0u64;
        for _ in 0..trials {
            let x = (0..n).filter(|_| rng.f64() < q).count();
            if x >= a {
                hits += 1;
            }
        }
        let mc = hits as f64 / trials as f64;
        assert!((mc - analytic).abs() < 0.01, "mc {mc} vs analytic {analytic}");
    }

    #[test]
    fn gamma_monotone_in_threshold_a() {
        // Larger a ⇒ fewer uploads ⇒ larger sparsification error term.
        let mut prev = 0.0;
        for a in [1usize, 4, 8, 16] {
            let out = evaluate(&Prop1Params {
                d: 5000,
                n_clients: 20,
                k: 250,
                threshold_a: a,
                law: default_law(),
                bits_b: 12,
            });
            assert!(out.gamma >= prev - 1e-12, "a={a}: {} < {prev}", out.gamma);
            prev = out.gamma;
        }
    }

    #[test]
    fn expected_uploads_shrink_with_a() {
        let mk = |a| {
            evaluate(&Prop1Params {
                d: 5000,
                n_clients: 20,
                k: 250,
                threshold_a: a,
                law: default_law(),
                bits_b: 12,
            })
            .expected_uploads
        };
        assert!(mk(1) > mk(3));
        assert!(mk(3) > mk(10));
    }

    #[test]
    fn gamma_in_unit_interval_for_paper_settings() {
        // §V-A3 defaults: k = 5%·d, a = 3, N = 20, b = 12.
        let d = 10_000;
        let out = evaluate(&Prop1Params {
            d,
            n_clients: 20,
            k: d / 20,
            threshold_a: 3,
            law: default_law(),
            bits_b: 12,
        });
        assert!(out.gamma > 0.0 && out.gamma < 1.0, "γ = {}", out.gamma);
        assert!(out.expected_uploads > 0.0 && out.expected_uploads < d as f64);
    }

    #[test]
    fn more_bits_reduce_gamma() {
        let mk = |b| {
            evaluate(&Prop1Params {
                d: 2000,
                n_clients: 20,
                k: 100,
                threshold_a: 3,
                law: default_law(),
                bits_b: b,
            })
            .gamma
        };
        assert!(mk(16) < mk(8));
    }
}
