//! Theorem 1: convergence-rate bound evaluator.
//!
//!   ‖∇F(x)‖² ≤ (F(w₁) − F* + δ)/(c·E·√T) + L²·E·G²·γ/(1−√γ)² / (2c√T)
//!
//! with c = 1/2 − 15E²η²L² and δ = (L+1)/2·E²G² + 5E²L²/2·(σ² + 6EΓ²).
//! Experiments use this to sanity-check hyper-parameter choices (a larger
//! γ inflates the bound; γ → 1 blows it up, matching Corollary 1's role).

/// Problem/algorithm constants appearing in Theorem 1.
#[derive(Debug, Clone, Copy)]
pub struct TheoremParams {
    /// Initial optimality gap F(w₁) − F*.
    pub init_gap: f64,
    /// Smoothness constant L (Assumption 1).
    pub smooth_l: f64,
    /// Gradient-norm bound G (Assumption 3).
    pub grad_bound: f64,
    /// Gradient-variance bound σ² (Assumption 2).
    pub sigma_sq: f64,
    /// non-IID degree Γ² (Definition 2).
    pub gamma_noniid_sq: f64,
    /// Local iterations E.
    pub local_iters: usize,
    /// Learning rate η (constant-step evaluation of the bound).
    pub eta: f64,
    /// Global iterations T.
    pub rounds: usize,
}

/// Evaluate the Theorem-1 RHS for compression error `gamma_c` ∈ (0, 1).
/// Returns None when the step-size condition c ≥ 0 or 0 < γ < 1 fails.
pub fn theorem1_bound(p: &TheoremParams, gamma_c: f64) -> Option<f64> {
    if !(0.0..1.0).contains(&gamma_c) || gamma_c == 0.0 {
        // γ = 0 (lossless) is allowed as a limit; treat separately below.
    }
    if gamma_c < 0.0 || gamma_c >= 1.0 {
        return None;
    }
    let e = p.local_iters as f64;
    let c = 0.5 - 15.0 * e * e * p.eta * p.eta * p.smooth_l * p.smooth_l;
    if c < 0.0 {
        return None;
    }
    let c = c.max(1e-12);
    let delta = (p.smooth_l + 1.0) / 2.0 * e * e * p.grad_bound * p.grad_bound
        + 5.0 * e * e * p.smooth_l * p.smooth_l / 2.0
            * (p.sigma_sq + 6.0 * e * p.gamma_noniid_sq);
    let t_sqrt = (p.rounds as f64).sqrt();
    let term1 = (p.init_gap + delta) / (c * e * t_sqrt);
    let gamma_amp = if gamma_c == 0.0 {
        0.0
    } else {
        gamma_c / (1.0 - gamma_c.sqrt()).powi(2)
    };
    let term2 = p.smooth_l * p.smooth_l * e * p.grad_bound * p.grad_bound * gamma_amp
        / (2.0 * c * t_sqrt);
    Some(term1 + term2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> TheoremParams {
        TheoremParams {
            init_gap: 10.0,
            smooth_l: 0.1,
            grad_bound: 1.0,
            sigma_sq: 0.5,
            gamma_noniid_sq: 0.2,
            local_iters: 5,
            eta: 0.05,
            rounds: 400,
        }
    }

    #[test]
    fn bound_decays_with_rounds() {
        let p = params();
        let b1 = theorem1_bound(&p, 0.3).unwrap();
        let b2 = theorem1_bound(&TheoremParams { rounds: 1600, ..p }, 0.3).unwrap();
        // √T scaling: 4× rounds ⇒ half the bound.
        assert!((b2 - b1 / 2.0).abs() / b1 < 1e-9);
    }

    #[test]
    fn bound_grows_with_compression_error() {
        let p = params();
        let lo = theorem1_bound(&p, 0.1).unwrap();
        let hi = theorem1_bound(&p, 0.9).unwrap();
        assert!(hi > lo);
    }

    #[test]
    fn bound_explodes_near_gamma_one() {
        let p = params();
        let near = theorem1_bound(&p, 0.9999).unwrap();
        let mid = theorem1_bound(&p, 0.5).unwrap();
        assert!(near > 100.0 * mid);
        assert!(theorem1_bound(&p, 1.0).is_none());
        assert!(theorem1_bound(&p, -0.1).is_none());
    }

    #[test]
    fn step_size_condition_enforced() {
        let p = TheoremParams { eta: 10.0, ..params() }; // violates c ≥ 0
        assert!(theorem1_bound(&p, 0.3).is_none());
    }

    #[test]
    fn noniid_degree_inflates_bound() {
        let p = params();
        let iid = theorem1_bound(&TheoremParams { gamma_noniid_sq: 0.0, ..p }, 0.3).unwrap();
        let noniid =
            theorem1_bound(&TheoremParams { gamma_noniid_sq: 5.0, ..p }, 0.3).unwrap();
        assert!(noniid > iid);
    }
}
