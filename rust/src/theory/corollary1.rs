//! Corollary 1: the quantisation-bit lower bound that guarantees 0 < γ < 1.
//!
//!   b > log₂( √(Σ r_l) / (2·φ·√(Σ r_l·l^{2α})) · N·m + N ) + 1   (Eq. 6)
//!
//! §IV-D: "For each given value of a, b is set according to (6) to
//! minimize the load on the PS" — so FediAC runs with the *smallest*
//! convergent b, which is what `min_bits` returns.

use crate::theory::power_law::PowerLaw;
use crate::theory::prop1::{binom_tail_geq, vote_prob, voted_prob};

/// Exact RHS of Eq. (6) (not yet rounded to an integer bit count).
pub fn bits_lower_bound(
    d: usize,
    n_clients: usize,
    k: usize,
    threshold_a: usize,
    law: &PowerLaw,
) -> f64 {
    let p = vote_prob(d, law.alpha);
    let q = voted_prob(&p, k);
    let mut sum_r = 0.0;
    let mut sum_r_l2a = 0.0;
    for l in 1..=d {
        let r = binom_tail_geq(n_clients, q[l - 1], threshold_a);
        sum_r += r;
        sum_r_l2a += r * (l as f64).powf(2.0 * law.alpha);
    }
    let m = law.phi; // rank-1 magnitude under Definition 1
    let inner =
        sum_r.sqrt() / (2.0 * law.phi * sum_r_l2a.sqrt()) * n_clients as f64 * m
            + n_clients as f64;
    inner.log2() + 1.0
}

/// Smallest integer b satisfying Corollary 1 (clamped to a sane range;
/// the data plane cannot exceed 31-bit signed lanes).
pub fn min_bits(
    d: usize,
    n_clients: usize,
    k: usize,
    threshold_a: usize,
    law: &PowerLaw,
) -> usize {
    let bound = bits_lower_bound(d, n_clients, k, threshold_a, law);
    let b = bound.floor() as i64 + 1; // strictly greater than the bound
    b.clamp(2, 31) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::theory::prop1::{evaluate, Prop1Params};

    fn law() -> PowerLaw {
        PowerLaw { phi: 0.1, alpha: -0.7 }
    }

    #[test]
    fn min_bits_strictly_exceeds_bound() {
        let d = 5000;
        let bound = bits_lower_bound(d, 20, 250, 3, &law());
        let b = min_bits(d, 20, 250, 3, &law());
        assert!((b as f64) > bound, "b {b} ≤ bound {bound}");
        assert!((b as f64) - bound <= 1.0 + 1e-9, "not minimal: {b} vs {bound}");
    }

    #[test]
    fn chosen_bits_give_convergent_gamma() {
        // The whole point of Corollary 1: plugging min_bits back into
        // Proposition 1 must land γ strictly inside (0, 1).
        for a in [1usize, 3, 6] {
            let d = 4000;
            let b = min_bits(d, 20, 200, a, &law());
            let out = evaluate(&Prop1Params {
                d,
                n_clients: 20,
                k: 200,
                threshold_a: a,
                law: law(),
                bits_b: b,
            });
            assert!(
                out.gamma > 0.0 && out.gamma < 1.0,
                "a={a}, b={b}: γ = {}",
                out.gamma
            );
        }
    }

    #[test]
    fn one_fewer_bit_can_break_convergence_margin() {
        // b−1 must violate the bound (that's what minimality means).
        let d = 4000;
        let bound = bits_lower_bound(d, 20, 200, 3, &law());
        let b = min_bits(d, 20, 200, 3, &law());
        assert!(((b - 1) as f64) <= bound);
    }

    #[test]
    fn more_clients_need_more_bits() {
        let d = 4000;
        let b_small = bits_lower_bound(d, 10, 200, 3, &law());
        let b_large = bits_lower_bound(d, 50, 200, 3, &law());
        assert!(b_large > b_small);
    }

    #[test]
    fn clamped_to_valid_range() {
        // Extreme φ forces the clamp rather than a panic.
        let crazy = PowerLaw { phi: 1e30, alpha: -0.01 };
        let b = min_bits(100, 20, 5, 1, &crazy);
        assert!((2..=31).contains(&b));
    }
}
