//! Command-line argument parsing (clap is not available offline).
//!
//! Grammar: `fediac <subcommand> [--key value | --key=value | --flag] ...`.
//! Typed getters with defaults keep call sites terse; unknown-argument
//! detection catches typos (`finish()` must be called after all reads).

use std::collections::BTreeMap;

/// Parsed command line: one optional subcommand + key/value options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    subcommand: Option<String>,
    options: BTreeMap<String, String>,
    consumed: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

/// Argument-parsing failures surfaced to the user.
#[derive(Debug, thiserror::Error)]
pub enum CliError {
    /// A value-style option was given without a value.
    #[error("option --{0} expects a value")]
    MissingValue(String),
    /// An option's value failed to parse as the expected type.
    #[error("cannot parse --{key} value '{value}' as {ty}")]
    BadValue { key: String, value: String, ty: &'static str },
    /// Options nobody read — almost always a typo (see [`Args::finish`]).
    #[error("unknown option(s): {0}")]
    Unknown(String),
    /// A bare token after the subcommand.
    #[error("unexpected positional argument '{0}'")]
    UnexpectedPositional(String),
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self, CliError> {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else {
                    // Value style: `--key value` unless next token is an option
                    // or absent, in which case it is a boolean flag.
                    match iter.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = iter.next().unwrap();
                            args.options.insert(stripped.to_string(), v);
                        }
                        _ => {
                            args.options.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                return Err(CliError::UnexpectedPositional(tok));
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Self, CliError> {
        Self::parse(std::env::args().skip(1))
    }

    /// The leading subcommand token, if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.subcommand.as_deref()
    }

    fn raw(&self, key: &str) -> Option<&str> {
        self.consumed.borrow_mut().insert(key.to_string());
        self.options.get(key).map(|s| s.as_str())
    }

    /// String option, or `default` when absent.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.raw(key).unwrap_or(default).to_string()
    }

    /// String option, `None` when absent.
    pub fn get_opt_str(&self, key: &str) -> Option<String> {
        self.raw(key).map(|s| s.to_string())
    }

    /// f64 option, or `default`; errors on an unparsable value.
    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.into(),
                value: v.into(),
                ty: "f64",
            }),
        }
    }

    /// usize option, or `default`; errors on an unparsable value.
    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.into(),
                value: v.into(),
                ty: "usize",
            }),
        }
    }

    /// u64 option, or `default`; errors on an unparsable value.
    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.into(),
                value: v.into(),
                ty: "u64",
            }),
        }
    }

    /// u32 option, or `default`; errors on an unparsable value.
    pub fn get_u32(&self, key: &str, default: u32) -> Result<u32, CliError> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.into(),
                value: v.into(),
                ty: "u32",
            }),
        }
    }

    /// u16 option, or `default`; errors on an unparsable value.
    pub fn get_u16(&self, key: &str, default: u16) -> Result<u16, CliError> {
        match self.raw(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| CliError::BadValue {
                key: key.into(),
                value: v.into(),
                ty: "u16",
            }),
        }
    }

    /// Boolean flag: present (or `=true`) ⇒ true.
    pub fn get_flag(&self, key: &str) -> bool {
        matches!(self.raw(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error on any option that was provided but never read (typo guard).
    pub fn finish(&self) -> Result<(), CliError> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> =
            self.options.keys().filter(|k| !consumed.contains(*k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(CliError::Unknown(
                unknown.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(", "),
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(toks.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["fig2", "--rounds", "40", "--ps=low", "--quiet"]);
        assert_eq!(a.subcommand(), Some("fig2"));
        assert_eq!(a.get_usize("rounds", 0).unwrap(), 40);
        assert_eq!(a.get_str("ps", "high"), "low");
        assert!(a.get_flag("quiet"));
        assert!(!a.get_flag("verbose"));
        a.finish().unwrap();
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["table"]);
        assert_eq!(a.get_f64("beta", 0.5).unwrap(), 0.5);
        assert_eq!(a.get_str("dataset", "cifar10"), "cifar10");
        a.finish().unwrap();
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse(&["x", "--rounds", "abc"]);
        assert!(a.get_usize("rounds", 1).is_err());
    }

    #[test]
    fn unknown_option_detected() {
        let a = parse(&["x", "--runds", "3"]);
        let _ = a.get_usize("rounds", 1);
        assert!(a.finish().is_err());
    }

    #[test]
    fn unexpected_positional_rejected() {
        assert!(Args::parse(["a".to_string(), "b".to_string()]).is_err());
    }

    #[test]
    fn equals_and_space_styles_agree() {
        let a = parse(&["run", "--n=30"]);
        let b = parse(&["run", "--n", "30"]);
        assert_eq!(a.get_usize("n", 0).unwrap(), b.get_usize("n", 0).unwrap());
    }
}
