//! Metrics: traffic accounting, per-round records and CSV emission.
//!
//! Tables I/II compare "total communication traffic (upload + download)"
//! to reach target accuracy; Fig. 2 plots accuracy against simulated
//! wall-clock. Every experiment funnels through [`RunRecorder`] so that
//! benches and examples emit the same machine-readable rows.

pub mod plot;

/// Byte counters split by direction and phase.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TrafficMeter {
    /// Client → PS bytes on the wire (headers included).
    pub up_bytes: u64,
    /// PS → client bytes, charged per receiving client.
    pub down_bytes: u64,
    /// Phase-1 (vote/GIA) share of the above, FediAC only.
    pub vote_up_bytes: u64,
    /// Phase-1 share of the download bytes, FediAC only.
    pub vote_down_bytes: u64,
}

impl TrafficMeter {
    /// Upload + download bytes.
    pub fn total(&self) -> u64 {
        self.up_bytes + self.down_bytes
    }

    /// Total in decimal megabytes (the tables' unit).
    pub fn total_mb(&self) -> f64 {
        self.total() as f64 / 1e6
    }

    /// Fold another meter in.
    pub fn add(&mut self, other: &TrafficMeter) {
        self.up_bytes += other.up_bytes;
        self.down_bytes += other.down_bytes;
        self.vote_up_bytes += other.vote_up_bytes;
        self.vote_down_bytes += other.vote_down_bytes;
    }
}

/// One global iteration's outcome.
#[derive(Debug, Clone)]
pub struct RoundRecord {
    /// Global iteration index.
    pub round: usize,
    /// Simulated wall-clock at the *end* of this round (s).
    pub sim_time_s: f64,
    /// Mean training loss across clients this round.
    pub train_loss: f64,
    /// Test accuracy if evaluated this round.
    pub test_accuracy: Option<f64>,
    /// Test loss if evaluated this round.
    pub test_loss: Option<f64>,
    /// Bytes this round moved.
    pub traffic: TrafficMeter,
    /// Aggregation operations the switch performed this round.
    pub agg_ops: u64,
    /// Dimensions uploaded per client (k_S for FediAC; d for SwitchML...).
    pub uploaded_elems: f64,
}

/// Accumulates rounds and renders CSV.
#[derive(Debug, Default, Clone)]
pub struct RunRecorder {
    /// Run label (dataset/partition/algorithm).
    pub label: String,
    /// One record per completed round.
    pub records: Vec<RoundRecord>,
}

impl RunRecorder {
    /// Empty recorder for `label`.
    pub fn new(label: impl Into<String>) -> Self {
        RunRecorder { label: label.into(), records: Vec::new() }
    }

    /// Append one round's record.
    pub fn push(&mut self, rec: RoundRecord) {
        self.records.push(rec);
    }

    /// Cumulative traffic up to and including round index `i`.
    pub fn cumulative_traffic(&self, i: usize) -> TrafficMeter {
        let mut t = TrafficMeter::default();
        for r in &self.records[..=i] {
            t.add(&r.traffic);
        }
        t
    }

    /// Total traffic of the whole run.
    pub fn total_traffic(&self) -> TrafficMeter {
        let mut t = TrafficMeter::default();
        for r in &self.records {
            t.add(&r.traffic);
        }
        t
    }

    /// First round index whose evaluated accuracy reaches `target`, with
    /// the simulated time and cumulative traffic at that point.
    pub fn time_to_accuracy(&self, target: f64) -> Option<(usize, f64, TrafficMeter)> {
        for (i, r) in self.records.iter().enumerate() {
            if let Some(acc) = r.test_accuracy {
                if acc >= target {
                    return Some((i, r.sim_time_s, self.cumulative_traffic(i)));
                }
            }
        }
        None
    }

    /// Best accuracy observed.
    pub fn best_accuracy(&self) -> Option<f64> {
        self.records.iter().filter_map(|r| r.test_accuracy).fold(None, |best, a| {
            Some(best.map_or(a, |b: f64| b.max(a)))
        })
    }

    /// Final simulated time.
    pub fn final_time(&self) -> f64 {
        self.records.last().map(|r| r.sim_time_s).unwrap_or(0.0)
    }

    /// Render as CSV (header + one row per round).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "label,round,sim_time_s,train_loss,test_accuracy,test_loss,\
             up_bytes,down_bytes,agg_ops,uploaded_elems\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{:.6},{:.6},{},{},{},{},{},{:.1}\n",
                self.label,
                r.round,
                r.sim_time_s,
                r.train_loss,
                r.test_accuracy.map_or(String::new(), |a| format!("{a:.4}")),
                r.test_loss.map_or(String::new(), |l| format!("{l:.4}")),
                r.traffic.up_bytes,
                r.traffic.down_bytes,
                r.agg_ops,
                r.uploaded_elems,
            ));
        }
        out
    }

    /// Write the CSV next to other experiment outputs.
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_csv())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, t: f64, acc: Option<f64>, up: u64) -> RoundRecord {
        RoundRecord {
            round,
            sim_time_s: t,
            train_loss: 1.0,
            test_accuracy: acc,
            test_loss: acc.map(|_| 0.5),
            traffic: TrafficMeter { up_bytes: up, down_bytes: up / 2, ..Default::default() },
            agg_ops: 10,
            uploaded_elems: 100.0,
        }
    }

    #[test]
    fn traffic_accumulates() {
        let mut rr = RunRecorder::new("x");
        rr.push(rec(0, 1.0, None, 100));
        rr.push(rec(1, 2.0, Some(0.5), 100));
        assert_eq!(rr.total_traffic().up_bytes, 200);
        assert_eq!(rr.total_traffic().down_bytes, 100);
        assert_eq!(rr.cumulative_traffic(0).total(), 150);
    }

    #[test]
    fn time_to_accuracy_finds_first_crossing() {
        let mut rr = RunRecorder::new("x");
        rr.push(rec(0, 1.0, Some(0.3), 10));
        rr.push(rec(1, 2.0, Some(0.6), 10));
        rr.push(rec(2, 3.0, Some(0.9), 10));
        let (round, t, traffic) = rr.time_to_accuracy(0.6).unwrap();
        assert_eq!(round, 1);
        assert_eq!(t, 2.0);
        assert_eq!(traffic.total(), 30);
        assert!(rr.time_to_accuracy(0.95).is_none());
        assert_eq!(rr.best_accuracy(), Some(0.9));
    }

    #[test]
    fn csv_shape() {
        let mut rr = RunRecorder::new("run1");
        rr.push(rec(0, 1.0, Some(0.25), 42));
        let csv = rr.to_csv();
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("label,round"));
        assert!(lines[1].starts_with("run1,0,1.000000"));
    }
}
