//! Terminal line plots for experiment output (no plotting crates offline).
//!
//! Renders multiple (x, y) series into a fixed-size ASCII grid with axis
//! labels — enough to eyeball the Fig. 2/3/4 shapes straight from the
//! terminal.

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct Series {
    /// Legend label.
    pub name: String,
    /// (x, y) samples in plot order.
    pub points: Vec<(f64, f64)>,
}

/// Plot dimensions.
#[derive(Debug, Clone, Copy)]
pub struct PlotSpec {
    /// Grid columns.
    pub width: usize,
    /// Grid rows.
    pub height: usize,
}

impl Default for PlotSpec {
    fn default() -> Self {
        PlotSpec { width: 72, height: 18 }
    }
}

const MARKS: [char; 6] = ['*', 'o', '+', 'x', '#', '@'];

/// Render series into an ASCII chart with a legend.
pub fn render(series: &[Series], spec: PlotSpec, x_label: &str, y_label: &str) -> String {
    let pts: Vec<(f64, f64)> =
        series.iter().flat_map(|s| s.points.iter().cloned()).collect();
    if pts.is_empty() {
        return String::from("(no data)\n");
    }
    let (mut x_min, mut x_max) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y_min, mut y_max) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x_min = x_min.min(x);
        x_max = x_max.max(x);
        y_min = y_min.min(y);
        y_max = y_max.max(y);
    }
    if (x_max - x_min).abs() < 1e-12 {
        x_max = x_min + 1.0;
    }
    if (y_max - y_min).abs() < 1e-12 {
        y_max = y_min + 1.0;
    }
    let (w, h) = (spec.width, spec.height);
    let mut grid = vec![vec![' '; w]; h];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        // Draw line segments between consecutive points.
        for pair in s.points.windows(2) {
            let (x0, y0) = pair[0];
            let (x1, y1) = pair[1];
            let steps = (w * 2).max(2);
            for t in 0..=steps {
                let f = t as f64 / steps as f64;
                let x = x0 + (x1 - x0) * f;
                let y = y0 + (y1 - y0) * f;
                let cx = ((x - x_min) / (x_max - x_min) * (w - 1) as f64).round() as usize;
                let cy = ((y - y_min) / (y_max - y_min) * (h - 1) as f64).round() as usize;
                grid[h - 1 - cy][cx] = mark;
            }
        }
        if let Some(&(x, y)) = s.points.first() {
            let cx = ((x - x_min) / (x_max - x_min) * (w - 1) as f64).round() as usize;
            let cy = ((y - y_min) / (y_max - y_min) * (h - 1) as f64).round() as usize;
            grid[h - 1 - cy][cx] = mark;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{y_label}\n"));
    for (i, row) in grid.iter().enumerate() {
        let y_val = y_max - (y_max - y_min) * i as f64 / (h - 1) as f64;
        let label = if i % 4 == 0 { format!("{y_val:>8.3} ") } else { " ".repeat(9) };
        out.push_str(&label);
        out.push('|');
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&" ".repeat(9));
    out.push('+');
    out.push_str(&"-".repeat(w));
    out.push('\n');
    out.push_str(&format!(
        "{}{:<12.4}{}{:>12.4}   ({x_label})\n",
        " ".repeat(10),
        x_min,
        " ".repeat(w.saturating_sub(26)),
        x_max
    ));
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", MARKS[si % MARKS.len()], s.name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nonempty_chart() {
        let s = vec![
            Series {
                name: "a".into(),
                points: (0..20).map(|i| (i as f64, (i as f64).sqrt())).collect(),
            },
            Series {
                name: "b".into(),
                points: (0..20).map(|i| (i as f64, i as f64 / 20.0)).collect(),
            },
        ];
        let txt = render(&s, PlotSpec::default(), "time", "acc");
        assert!(txt.contains('*') && txt.contains('o'));
        assert!(txt.contains("time") && txt.contains("acc"));
        assert!(txt.lines().count() > 18);
    }

    #[test]
    fn handles_degenerate_inputs() {
        assert_eq!(render(&[], PlotSpec::default(), "x", "y"), "(no data)\n");
        let s = vec![Series { name: "p".into(), points: vec![(1.0, 1.0)] }];
        let txt = render(&s, PlotSpec::default(), "x", "y");
        assert!(txt.contains('*'));
    }
}
