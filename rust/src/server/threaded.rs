//! Thread-per-job I/O backend: one dispatch thread owns the socket's
//! receive side and routes datagrams by job id (a cheap
//! [`crate::wire::peek_route`] — no checksum work on the hot thread) to
//! per-job worker threads over mpsc channels. Each worker owns its
//! [`Job`] exclusively (no locks on the aggregation path) and transmits
//! the [`crate::server::JobOutput`] frames through a cloned socket
//! handle. Jobs are therefore concurrent with each other and serialized
//! internally — the same discipline a switch pipeline imposes per
//! register block.
//!
//! Workers are event-driven, not polled: each blocks on its channel
//! until traffic arrives, the job's own timer deadline expires (idle
//! register reclamation — counted in `ServerStats::idle_wakeups`), or an
//! attached chaos lane is holding reordered copies that need a flush
//! tick. An idle job costs zero wakeups.

use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, UdpSocket};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use crate::configx::PsProfile;
use crate::net::chaos::ChaosLane;
use crate::server::daemon::{trace_front, transmit, unknown_job_reply, BackendShared, MAX_JOBS};
use crate::server::job::{Job, JobLimits};
use crate::server::{HostBudget, ServerStats};
use crate::telemetry::{FlightRecorder, TraceNote};
use crate::wire::{decode_frame, peek_route, WireKind, MAX_DATAGRAM};

type WorkerTx = Sender<(Vec<u8>, SocketAddr)>;

/// One spawned job worker: its input channel, its thread handle, and
/// whether its `Job` has been configured by a valid `Join` (unconfigured
/// workers are the eviction candidates under cap pressure).
struct WorkerSlot {
    tx: WorkerTx,
    handle: JoinHandle<()>,
    configured: Arc<AtomicBool>,
}

/// How often a worker whose chaos lane is holding reordered copies wakes
/// to flush the overdue ones. Lanes with nothing held cost no wakeups.
const CHAOS_TICK: Duration = Duration::from_millis(10);

pub(crate) fn dispatch_loop(socket: UdpSocket, shared: BackendShared) {
    let BackendShared { profile, limits, chaos, chaos_seed, stats, stop, budget, recorder } =
        shared;
    let mut workers: HashMap<u32, WorkerSlot> = HashMap::new();
    // Sized so no legitimate frame can be truncated by a short recv.
    let mut buf = vec![0u8; MAX_DATAGRAM];
    while !stop.load(Ordering::SeqCst) {
        let (n, from) = match socket.recv_from(&mut buf) {
            Ok(ok) => ok,
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                continue
            }
            Err(_) => break,
        };
        ServerStats::bump(&stats.packets);
        let now = Instant::now();
        let Some((job_id, kind)) = peek_route(&buf[..n]) else {
            ServerStats::bump(&stats.decode_errors);
            trace_front(recorder.as_deref(), 0, None, from, TraceNote::DecodeError, now);
            continue;
        };
        if !workers.contains_key(&job_id) {
            // Workers are born only on Join; everything else gets the
            // shared front-door treatment (JoinAck/UNKNOWN for genuine
            // uplink kinds, silence for downlink spoofs).
            if kind != WireKind::Join {
                let rec = recorder.as_deref();
                match unknown_job_reply(job_id, kind, &stats) {
                    Some(reply) => {
                        trace_front(rec, job_id, Some(kind), from, TraceNote::UnknownJob, now);
                        let _ = socket.send_to(&reply, from);
                    }
                    None => {
                        trace_front(rec, job_id, Some(kind), from, TraceNote::DownlinkSpoof, now)
                    }
                }
                continue;
            }
            if workers.len() >= MAX_JOBS && !evict_unconfigured(&mut workers) {
                ServerStats::bump(&stats.jobs_rejected);
                trace_front(
                    recorder.as_deref(),
                    job_id,
                    Some(kind),
                    from,
                    TraceNote::CapRejected,
                    now,
                );
                crate::warn!("job={job_id} rejected: {MAX_JOBS}-job cap, all slots configured");
                continue;
            }
        }
        let worker = workers.entry(job_id).or_insert_with(|| {
            spawn_worker(
                job_id,
                &socket,
                profile.clone(),
                limits,
                chaos,
                chaos_seed,
                Arc::clone(&stats),
                Arc::clone(&budget),
                recorder.clone(),
            )
        });
        if worker.tx.send((buf[..n].to_vec(), from)).is_err() {
            // Worker died (should not happen); drop the datagram — the
            // client's retransmission will respawn it.
            crate::warn!("job={job_id} worker channel closed; dropping datagram");
            workers.remove(&job_id);
        }
    }
    for (_, slot) in workers {
        drop(slot.tx);
        let _ = slot.handle.join();
    }
}

/// Drop one worker whose job was never configured by a valid `Join`.
/// Returns false when every resident job is real (the cap then holds).
fn evict_unconfigured(workers: &mut HashMap<u32, WorkerSlot>) -> bool {
    let victim = workers
        .iter()
        .find(|(_, slot)| !slot.configured.load(Ordering::SeqCst))
        .map(|(&id, _)| id);
    let Some(id) = victim else {
        return false;
    };
    if let Some(slot) = workers.remove(&id) {
        drop(slot.tx);
        let _ = slot.handle.join();
    }
    crate::debug!("job={id} evicted (never configured) to admit a new tenant");
    true
}

#[allow(clippy::too_many_arguments)]
fn spawn_worker(
    job_id: u32,
    socket: &UdpSocket,
    profile: PsProfile,
    limits: JobLimits,
    chaos: Option<crate::net::chaos::ChaosDirection>,
    chaos_seed: u64,
    stats: Arc<ServerStats>,
    budget: Arc<HostBudget>,
    recorder: Option<Arc<FlightRecorder>>,
) -> WorkerSlot {
    let (tx, rx) = mpsc::channel::<(Vec<u8>, SocketAddr)>();
    let out = socket.try_clone().expect("cloning UDP socket for worker");
    let configured = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&configured);
    ServerStats::bump(&stats.workers_spawned);
    let handle = thread::Builder::new()
        .name(format!("fediac-job-{job_id}"))
        .spawn(move || {
            let mut job = Job::with_budget(job_id, profile, limits, budget, Arc::clone(&stats));
            if let Some(rec) = recorder.clone() {
                job.attach_recorder(rec);
            }
            // Downlink chaos lane (None = send straight through). Held
            // copies carry their destination as lane metadata.
            let mut lane: Option<ChaosLane<SocketAddr>> =
                chaos.map(|cfg| ChaosLane::new(cfg, chaos_seed ^ job_id as u64));
            // The deadline the job most recently asked to be ticked at.
            let mut timer: Option<Instant> = None;
            loop {
                // Sleep until traffic, the job's timer, or (only while a
                // chaos lane holds reordered copies) the flush tick —
                // never a fixed polling interval.
                let chaos_due = lane
                    .as_ref()
                    .and_then(|l| (l.held_len() > 0).then(|| Instant::now() + CHAOS_TICK));
                let deadline = match (timer, chaos_due) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, b) => a.or(b),
                };
                let msg = match deadline {
                    None => match rx.recv() {
                        Ok(m) => Some(m),
                        Err(_) => break,
                    },
                    Some(d) => {
                        let wait = d.saturating_duration_since(Instant::now());
                        match rx.recv_timeout(wait) {
                            Ok(m) => Some(m),
                            Err(RecvTimeoutError::Timeout) => None,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                };
                let now = Instant::now();
                // An overdue job deadline fires regardless of how the
                // wait ended: `recv_timeout(0)` keeps returning frames
                // while the channel is non-empty, so a sustained flood
                // (e.g. unauthenticated Polls) must not defer idle
                // register reclamation forever. Chaos flush ticks are
                // not idle wakeups; only the job's own deadline is.
                if timer.is_some_and(|t| t <= now) {
                    ServerStats::bump(&stats.idle_wakeups);
                    let outp = job.on_tick(now);
                    transmit(&out, &mut lane, &outp.frames, now);
                    job.recycle(outp.frames);
                    timer = outp.timer;
                }
                if let Some((datagram, from)) = msg {
                    match decode_frame(&datagram) {
                        Ok(frame) => {
                            let outp = job.handle(&frame, from, now);
                            transmit(&out, &mut lane, &outp.frames, now);
                            job.recycle(outp.frames);
                            timer = outp.timer;
                            if !flag.load(Ordering::SeqCst) && job.is_configured() {
                                flag.store(true, Ordering::SeqCst);
                            }
                        }
                        Err(_) => {
                            ServerStats::bump(&stats.decode_errors);
                            trace_front(
                                recorder.as_deref(),
                                job_id,
                                None,
                                from,
                                TraceNote::DecodeError,
                                now,
                            );
                        }
                    }
                }
                if let Some(l) = lane.as_mut() {
                    for (pkt, to) in l.flush_due(Instant::now()) {
                        let _ = out.send_to(&pkt, to);
                    }
                }
            }
        })
        .expect("spawning job worker");
    WorkerSlot { tx, handle, configured }
}
