//! Multi-core reactor fleet: N single-thread reactors (one per core),
//! each owning a member socket of one `SO_REUSEPORT` group bound to the
//! shared port, so the whole machine serves what one reactor thread
//! served before — the server half of the million-client scale-out
//! (`fediac swarm` is the client half).
//!
//! Three design rules keep the hot path core-local:
//!
//! * **Deterministic job partitioning.** Every job id hashes to exactly
//!   one owner core ([`owner_core`]) and that core alone holds the job's
//!   [`Job`] state machine, chaos lane, frame pool and timer-wheel
//!   entry. No job state is shared, so the per-core loop is the
//!   existing reactor loop unchanged — zero cross-core locking on the
//!   hot path (the one shared structure, the [`HostBudget`] accountant,
//!   is touched only at Join/Drop).
//! * **Core-to-core steering.** Kernel `SO_REUSEPORT` steering is
//!   per-*flow* (a source/destination 4-tuple hash), not per-job, so a
//!   client's datagrams land on whichever member socket its flow hashes
//!   to. A core receiving a frame for a job it does not own forwards
//!   the frame to the owner over that core's unbounded inbox channel
//!   and rings the owner's private wake socket (a 1-byte loopback
//!   datagram, so a sleeping owner's `poll(2)` returns immediately);
//!   each forward bumps [`ServerStats::steered_frames`]. The owner
//!   replies from its *own* member socket — same source port, so
//!   steering is invisible on the wire (PROTOCOL.md §10).
//! * **Fair cross-job arbitration.** All cores share ONE
//!   [`HostBudget`] Arc, and the fleet defaults it to
//!   [`crate::server::BudgetMode::FairShare`] (DSLab-style equal
//!   throughput split): with many tenants spread over many cores, no
//!   tenant can first-come-starve the rest of the host budget.
//!
//! Telemetry stays per-core: each core owns a private
//! [`ServerStats`] block (counters + latency histograms) so the hot
//! path never contends on shared cachelines;
//! [`crate::server::ServerHandle::stats`] K-way-merges the blocks into
//! one deployment view and
//! [`crate::server::ServerHandle::per_core_stats`] exposes the raw
//! per-core blocks (`bench-wire --io fleet` reports per-core rounds/s
//! and p99 from them).
//!
//! Platforms without `SO_REUSEPORT` plumbing
//! ([`crate::net::poll::REUSEPORT_NATIVE`] = false) fall back to a
//! single-core fleet over a plain bind — same code path, one member.

use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, ToSocketAddrs, UdpSocket};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::configx::PsProfile;
use crate::net::chaos::{ChaosDirection, ChaosLane};
use crate::net::poll::{
    bind_reuseport, recv_batch, wait_readable_many, RecvBatch, TimerWheel, REUSEPORT_NATIVE,
};
use crate::server::daemon::{
    default_budget, trace_front, transmit, unknown_job_reply, ServeOptions, ServerHandle,
    MAX_JOBS, STOP_POLL,
};
use crate::server::job::{Job, JobLimits};
use crate::server::{HostBudget, ServerStats};
use crate::telemetry::{FlightRecorder, TraceNote};
use crate::wire::{decode_frame, peek_route, WireKind, MAX_DATAGRAM};

/// Hard ceiling on fleet cores (`--cores`); matches the shard plane's
/// fan-out bound so one deployment never explodes past 16 event threads
/// per daemon.
pub const MAX_FLEET_CORES: usize = 16;

// Same event-loop geometry as the single reactor (reactor.rs): the
// per-core loop IS that loop, so the constants must not drift.
const WHEEL_GRANULARITY: Duration = Duration::from_millis(10);
const WHEEL_SLOTS: usize = 512;
const CHAOS_TICK: Duration = Duration::from_millis(10);
const RECV_BUDGET: usize = 256;
const RECV_BATCH_DEPTH: usize = 32;

/// A frame steered between cores: the raw datagram plus the client
/// address it arrived from (the owner handles it as if received
/// locally).
type Steered = (Vec<u8>, SocketAddr);

/// The core owning `job_id` in a fleet of `cores`: a splitmix64-style
/// avalanche of the id, reduced modulo the fleet size. Deterministic
/// and stateless, so forwarders, tests and operators all compute the
/// same owner; the avalanche keeps adjacent job ids from piling onto
/// one core.
pub fn owner_core(job_id: u32, cores: usize) -> usize {
    debug_assert!(cores >= 1);
    let mut z = (job_id as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    ((z ^ (z >> 31)) % cores.max(1) as u64) as usize
}

/// Resolve a requested core count (0 = auto) to the fleet size actually
/// spawned: `min(available cores, 8)` on auto, clamped to
/// `[1, MAX_FLEET_CORES]` when explicit, and always 1 where
/// `SO_REUSEPORT` is unavailable (only one socket can own the port).
pub fn resolve_cores(requested: usize) -> usize {
    if !REUSEPORT_NATIVE {
        return 1;
    }
    if requested == 0 {
        thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(8)
    } else {
        requested.clamp(1, MAX_FLEET_CORES)
    }
}

/// One hosted job on its owner core — same shape as the reactor's slot:
/// the sans-I/O state machine, the downlink chaos lane, and whether a
/// wheel entry is currently armed for it.
struct Slot {
    job: Job,
    lane: Option<ChaosLane<SocketAddr>>,
    armed: Option<Instant>,
}

/// Everything one fleet core owns: its member socket, its hosted jobs,
/// its timer wheel, and its PRIVATE stats block (merged only at export).
struct Core {
    id: usize,
    member: UdpSocket,
    slots: HashMap<u32, Slot>,
    wheel: TimerWheel<u32>,
    profile: PsProfile,
    limits: JobLimits,
    chaos: Option<ChaosDirection>,
    chaos_seed: u64,
    stats: Arc<ServerStats>,
    budget: Arc<HostBudget>,
    recorder: Option<Arc<FlightRecorder>>,
}

impl Core {
    /// Feed one owned datagram through the job machinery — the reactor
    /// loop's per-datagram body, verbatim: front-door admission, Join
    /// birth, decode, `Job::handle`, transmit, pool recycle, wheel arm.
    /// Callers route ownership BEFORE this point, so there is no
    /// protocol branching below here (steered and direct frames take
    /// the identical path).
    fn ingest(&mut self, datagram: &[u8], from: SocketAddr, now: Instant) {
        let rec = self.recorder.as_deref();
        let Some((job_id, kind)) = peek_route(datagram) else {
            ServerStats::bump(&self.stats.decode_errors);
            trace_front(rec, 0, None, from, TraceNote::DecodeError, now);
            return;
        };
        if !self.slots.contains_key(&job_id) {
            if kind != WireKind::Join {
                match unknown_job_reply(job_id, kind, &self.stats) {
                    Some(reply) => {
                        trace_front(rec, job_id, Some(kind), from, TraceNote::UnknownJob, now);
                        let _ = self.member.send_to(&reply, from);
                    }
                    None => {
                        trace_front(rec, job_id, Some(kind), from, TraceNote::DownlinkSpoof, now)
                    }
                }
                return;
            }
            if self.slots.len() >= MAX_JOBS && !evict_unconfigured(&mut self.slots) {
                ServerStats::bump(&self.stats.jobs_rejected);
                trace_front(rec, job_id, Some(kind), from, TraceNote::CapRejected, now);
                crate::warn!(
                    "job={job_id} rejected: {MAX_JOBS}-job per-core cap, all slots configured"
                );
                return;
            }
            let mut job = Job::with_budget(
                job_id,
                self.profile.clone(),
                self.limits,
                Arc::clone(&self.budget),
                Arc::clone(&self.stats),
            );
            if let Some(r) = self.recorder.clone() {
                job.attach_recorder(r);
            }
            self.slots.insert(
                job_id,
                Slot {
                    job,
                    lane: self
                        .chaos
                        .map(|cfg| ChaosLane::new(cfg, self.chaos_seed ^ job_id as u64)),
                    armed: None,
                },
            );
        }
        let slot = self.slots.get_mut(&job_id).expect("slot just ensured");
        match decode_frame(datagram) {
            Ok(frame) => {
                let outp = slot.job.handle(&frame, from, now);
                transmit(&self.member, &mut slot.lane, &outp.frames, now);
                slot.job.recycle(outp.frames);
                // One live wheel entry per job, re-armed when the job's
                // deadline moves earlier (a quorum phase deadline can
                // tighten an idle-reclaim one); a superseded later entry
                // fires as a harmless stale wakeup.
                if let Some(t) = outp.timer {
                    if slot.armed.is_none_or(|armed| t < armed) {
                        self.wheel.insert(t, job_id);
                        slot.armed = Some(t);
                    }
                }
            }
            Err(_) => {
                ServerStats::bump(&self.stats.decode_errors);
                trace_front(rec, job_id, None, from, TraceNote::DecodeError, now);
            }
        }
    }

    /// Fire due wheel entries into `Job::on_tick` (idle reclamation).
    fn fire_timers(&mut self, now: Instant) {
        for job_id in self.wheel.pop_due(now) {
            let Some(slot) = self.slots.get_mut(&job_id) else {
                continue; // evicted since arming
            };
            if slot.armed.is_none() {
                continue; // stale entry (job re-admitted after eviction)
            }
            slot.armed = None;
            ServerStats::bump(&self.stats.idle_wakeups);
            let outp = slot.job.on_tick(now);
            transmit(&self.member, &mut slot.lane, &outp.frames, now);
            slot.job.recycle(outp.frames);
            if let Some(t) = outp.timer {
                self.wheel.insert(t, job_id);
                slot.armed = Some(t);
            }
        }
    }

    /// Release overdue reordered copies held by downlink chaos lanes.
    fn flush_chaos(&mut self, now: Instant) {
        for slot in self.slots.values_mut() {
            if let Some(l) = slot.lane.as_mut() {
                for (pkt, to) in l.flush_due(now) {
                    let _ = self.member.send_to(&pkt, to);
                }
            }
        }
    }

    /// True while any chaos lane holds reordered copies awaiting flush.
    fn chaos_pending(&self) -> bool {
        self.slots.values().any(|s| s.lane.as_ref().is_some_and(|l| l.held_len() > 0))
    }
}

/// Drop one slot whose job was never configured by a valid `Join`
/// (same cap policy as the single reactor — see `daemon::MAX_JOBS`).
fn evict_unconfigured(slots: &mut HashMap<u32, Slot>) -> bool {
    let victim = slots.iter().find(|(_, s)| !s.job.is_configured()).map(|(&id, _)| id);
    match victim {
        Some(id) => {
            slots.remove(&id);
            crate::debug!("job={id} evicted (never configured) to admit a new tenant");
            true
        }
        None => false,
    }
}

/// Bind the `SO_REUSEPORT` member group and spawn one reactor core per
/// member. Called by [`crate::server::serve`] for
/// [`crate::server::IoBackend::Fleet`]; not public because the handle
/// API is identical to every other backend's.
pub(crate) fn serve_fleet(opts: &ServeOptions) -> io::Result<ServerHandle> {
    let requested: SocketAddr = opts
        .bind
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "bind resolved to nothing"))?;
    let cores = resolve_cores(opts.cores);

    // The first member resolves an ephemeral port 0 to a concrete port;
    // the remaining members must join that same concrete port (binding
    // each to port 0 would scatter them over different ports).
    let first = bind_reuseport(requested)?;
    let addr = first.local_addr()?;
    let mut members = vec![first];
    for _ in 1..cores {
        members.push(bind_reuseport(addr)?);
    }
    // Per-core private wake sockets: a forwarder rings the owner's so a
    // sleeping owner's poll returns without waiting out its timeout.
    let mut poke_socks = Vec::with_capacity(cores);
    let mut poke_addrs = Vec::with_capacity(cores);
    for _ in 0..cores {
        let s = UdpSocket::bind("127.0.0.1:0")?;
        s.set_nonblocking(true)?;
        poke_addrs.push(s.local_addr()?);
        poke_socks.push(s);
    }
    let mut senders: Vec<Sender<Steered>> = Vec::with_capacity(cores);
    let mut inboxes: Vec<Receiver<Steered>> = Vec::with_capacity(cores);
    for _ in 0..cores {
        let (tx, rx) = mpsc::channel();
        senders.push(tx);
        inboxes.push(rx);
    }

    let stop = Arc::new(AtomicBool::new(false));
    let budget = opts.host_budget.clone().unwrap_or_else(|| Arc::new(default_budget(opts)));
    let per_core: Vec<Arc<ServerStats>> =
        (0..cores).map(|_| Arc::new(ServerStats::default())).collect();
    crate::debug!("bound {addr} backend=fleet cores={cores}");

    let mut threads = Vec::with_capacity(cores);
    for (id, ((member, poke), inbox)) in
        members.into_iter().zip(poke_socks).zip(inboxes).enumerate()
    {
        member.set_nonblocking(true)?;
        let core = Core {
            id,
            member,
            slots: HashMap::new(),
            wheel: TimerWheel::new(WHEEL_GRANULARITY, WHEEL_SLOTS, Instant::now()),
            profile: opts.profile.clone(),
            limits: opts.limits,
            chaos: opts.downlink_chaos,
            chaos_seed: opts.chaos_seed,
            stats: Arc::clone(&per_core[id]),
            budget: Arc::clone(&budget),
            recorder: opts.trace.clone(),
        };
        let peers = senders.clone();
        let wake_addrs = poke_addrs.clone();
        let stop_flag = Arc::clone(&stop);
        threads.push(
            thread::Builder::new()
                .name(format!("fediac-fleet-{id}"))
                .spawn(move || fleet_core_loop(core, poke, inbox, peers, wake_addrs, stop_flag))?,
        );
    }

    Ok(ServerHandle { addr, per_core, stop, threads })
}

/// One core's event loop: the single reactor's loop plus two extra
/// event sources — the wake socket and the steering inbox. Ownership is
/// the ONLY new decision: a frame whose job hashes elsewhere is
/// forwarded, everything owned runs the unmodified reactor body
/// ([`Core::ingest`]).
fn fleet_core_loop(
    mut core: Core,
    poke_rx: UdpSocket,
    inbox: Receiver<Steered>,
    peers: Vec<Sender<Steered>>,
    wake_addrs: Vec<SocketAddr>,
    stop: Arc<AtomicBool>,
) {
    let me = core.id;
    let n_cores = peers.len();
    // Private uplink for ringing peers' wake sockets. If loopback binds
    // ever fail we still make progress: the owner's sleep is capped at
    // STOP_POLL, so an unrung steered frame waits at most that long.
    let poke_tx = UdpSocket::bind("127.0.0.1:0").ok();
    if let Some(s) = &poke_tx {
        let _ = s.set_nonblocking(true);
    }
    let mut batch = RecvBatch::new(RECV_BATCH_DEPTH, MAX_DATAGRAM);
    let mut ready: Vec<usize> = Vec::new();
    let mut poke_buf = [0u8; 8];
    while !stop.load(Ordering::SeqCst) {
        // ---- sleep until something needs doing -------------------------
        let now = Instant::now();
        let mut wake = now + STOP_POLL;
        if let Some(t) = core.wheel.next_deadline() {
            wake = wake.min(t);
        }
        if core.chaos_pending() {
            wake = wake.min(now + CHAOS_TICK);
        }
        let timeout = wake.saturating_duration_since(now);
        if wait_readable_many(&[&core.member, &poke_rx], Some(timeout), &mut ready).is_err() {
            std::thread::sleep(Duration::from_millis(1));
            ready.clear();
        }

        // ---- drain the member socket -----------------------------------
        let now = Instant::now();
        if ready.contains(&0) {
            let mut drained = 0usize;
            while drained < RECV_BUDGET {
                let got = match recv_batch(&core.member, &mut batch) {
                    Ok(n) => n,
                    Err(e)
                        if e.kind() == io::ErrorKind::WouldBlock
                            || e.kind() == io::ErrorKind::TimedOut =>
                    {
                        break
                    }
                    Err(_) => break, // e.g. ICMP unreachable: not fatal
                };
                drained += got;
                for i in 0..got {
                    let (datagram, from) = batch.datagram(i);
                    ServerStats::bump(&core.stats.packets);
                    let Some((job_id, _)) = peek_route(datagram) else {
                        ServerStats::bump(&core.stats.decode_errors);
                        trace_front(
                            core.recorder.as_deref(),
                            0,
                            None,
                            from,
                            TraceNote::DecodeError,
                            now,
                        );
                        continue;
                    };
                    let owner = owner_core(job_id, n_cores);
                    if owner != me {
                        // Flow-misdirected: hand the frame to its owner
                        // and ring its wake socket. The channel is
                        // unbounded and the owner drains it every loop,
                        // so a send only fails at shutdown.
                        ServerStats::bump(&core.stats.steered_frames);
                        if peers[owner].send((datagram.to_vec(), from)).is_ok() {
                            if let Some(tx) = &poke_tx {
                                let _ = tx.send_to(&[1], wake_addrs[owner]);
                            }
                        }
                        continue;
                    }
                    core.ingest(datagram, from, now);
                }
                if got < batch.depth() {
                    break; // socket drained
                }
            }
        }

        // ---- drain wakes and the steering inbox ------------------------
        while poke_rx.recv_from(&mut poke_buf).is_ok() {}
        while let Ok((bytes, from)) = inbox.try_recv() {
            // `packets` was counted by the receiving core; the owner
            // only runs the protocol.
            core.ingest(&bytes, from, now);
        }

        // ---- fire due timers, flush chaos lanes ------------------------
        let now = Instant::now();
        core.fire_timers(now);
        core.flush_chaos(now);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn owner_core_is_deterministic_and_covers_all_cores() {
        for cores in 1..=8usize {
            let mut hit = vec![0usize; cores];
            for job in 0..512u32 {
                let o = owner_core(job, cores);
                assert!(o < cores);
                assert_eq!(o, owner_core(job, cores), "ownership must be stable");
                hit[o] += 1;
            }
            // The avalanche must actually spread consecutive ids: with
            // 512 jobs every core owns a healthy share (exact counts are
            // pinned by determinism, this guards against a degenerate
            // hash sending everything to one core).
            for (c, &n) in hit.iter().enumerate() {
                assert!(n > 0, "core {c} of {cores} owns no jobs");
                assert!(n < 512, "core {c} of {cores} owns everything");
            }
        }
        assert_eq!(owner_core(7, 1), 0, "a single core owns everything");
    }

    #[test]
    fn resolve_cores_clamps_and_falls_back() {
        if REUSEPORT_NATIVE {
            assert!((1..=8).contains(&resolve_cores(0)), "auto sizes within [1, 8]");
            assert_eq!(resolve_cores(3), 3);
            assert_eq!(resolve_cores(usize::MAX), MAX_FLEET_CORES);
        } else {
            assert_eq!(resolve_cores(0), 1);
            assert_eq!(resolve_cores(4), 1, "no SO_REUSEPORT: single-core fleet");
        }
    }
}
