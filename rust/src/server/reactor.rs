//! Single-thread reactor I/O backend: every hosted job is multiplexed
//! onto ONE event loop — a nonblocking `std::net::UdpSocket`, readiness
//! polling through [`crate::net::poll::wait_readable`] (a thin `poll(2)`
//! wrapper), and a coarse [`crate::net::poll::TimerWheel`] for the jobs'
//! idle-reclaim deadlines. Zero per-job threads, zero channels, zero
//! allocations on the idle path — the switch-class resource discipline
//! the paper's aggregation point assumes, and the shape a smart-NIC
//! front-end takes (one fixed compute budget, thousands of clients).
//!
//! The loop is a classic readiness reactor:
//!
//! ```text
//! loop {
//!   sleep until: socket readable | earliest wheel deadline
//!                | chaos flush tick (only while copies are held)
//!   drain the socket (recvmmsg batches, bounded budget), feeding Job::handle
//!   fire due wheel entries, feeding Job::on_tick
//!   flush chaos lanes holding overdue reordered copies
//! }
//! ```
//!
//! I/O is batched on both sides: receives pull up to `RECV_BATCH_DEPTH`
//! datagrams per `recvmmsg(2)` call and clean-path transmits flush
//! through `sendmmsg(2)` bursts (the shared `daemon::transmit`).
//! Emitted frame buffers recycle through the per-job
//! [`crate::wire::FrameScratch`] pool, so steady-state frame emission
//! allocates nothing (`pool_misses` stays flat); what remains per burst
//! is a few small `iovec`/`mmsghdr` scratch vectors inside the mmsg
//! wrappers, amortised across the whole batch.
//!
//! Routing and admission (job cap, unconfigured-job eviction, the
//! unknown-job `JoinAck`, downlink-spoof silence) are shared with the
//! threaded backend through [`crate::server::daemon`], and both backends
//! feed the same sans-I/O [`Job`] core — the two are bit-exact on the
//! wire by construction (`tests/wire_backend.rs` proves it anyway).

use std::collections::HashMap;
use std::io;
use std::net::{SocketAddr, UdpSocket};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::net::chaos::ChaosLane;
use crate::net::poll::{recv_batch, wait_readable, RecvBatch, TimerWheel};
use crate::server::daemon::{
    trace_front, transmit, unknown_job_reply, BackendShared, MAX_JOBS, STOP_POLL,
};
use crate::server::job::Job;
use crate::server::ServerStats;
use crate::telemetry::TraceNote;
use crate::wire::{decode_frame, peek_route, WireKind, MAX_DATAGRAM};

/// Wheel geometry: 10 ms × 512 slots ≈ a 5 s turn. Idle-reclaim
/// deadlines (tens of seconds by default) park for a few turns; firing
/// lateness is bounded by the granularity.
const WHEEL_GRANULARITY: Duration = Duration::from_millis(10);
const WHEEL_SLOTS: usize = 512;
/// Chaos lanes holding reordered copies are flushed at this cadence
/// (lanes with nothing held cost no wakeups).
const CHAOS_TICK: Duration = Duration::from_millis(10);
/// Datagrams drained per readiness event before timers are serviced, so
/// a flood cannot starve deadline work.
const RECV_BUDGET: usize = 256;
/// Datagrams pulled per `recvmmsg(2)` syscall within that budget.
const RECV_BATCH_DEPTH: usize = 32;

/// One hosted job: its sans-I/O state machine, its downlink chaos lane,
/// and the deadline currently armed for it in the wheel (`None` = no
/// pending wheel entry; at most one entry per job is live at a time).
struct Slot {
    job: Job,
    lane: Option<ChaosLane<SocketAddr>>,
    armed: Option<Instant>,
}

pub(crate) fn reactor_loop(socket: UdpSocket, shared: BackendShared) {
    let BackendShared { profile, limits, chaos, chaos_seed, stats, stop, budget, recorder } =
        shared;
    let mut slots: HashMap<u32, Slot> = HashMap::new();
    let mut wheel: TimerWheel<u32> =
        TimerWheel::new(WHEEL_GRANULARITY, WHEEL_SLOTS, Instant::now());
    // Batched receive: up to RECV_BATCH_DEPTH datagrams per syscall,
    // every buffer sized so no legitimate frame can be truncated.
    let mut batch = RecvBatch::new(RECV_BATCH_DEPTH, MAX_DATAGRAM);
    while !stop.load(Ordering::SeqCst) {
        // ---- sleep until something needs doing -------------------------
        let now = Instant::now();
        let mut wake = now + STOP_POLL;
        if let Some(t) = wheel.next_deadline() {
            wake = wake.min(t);
        }
        if slots.values().any(|s| s.lane.as_ref().is_some_and(|l| l.held_len() > 0)) {
            wake = wake.min(now + CHAOS_TICK);
        }
        let timeout = wake.saturating_duration_since(now);
        let readable = match wait_readable(&socket, Some(timeout)) {
            Ok(r) => r,
            // Transient poll failure: back off briefly, keep serving.
            Err(_) => {
                std::thread::sleep(Duration::from_millis(1));
                false
            }
        };

        // ---- drain the socket ------------------------------------------
        let now = Instant::now();
        if readable {
            let mut drained = 0usize;
            while drained < RECV_BUDGET {
                let got = match recv_batch(&socket, &mut batch) {
                    Ok(n) => n,
                    Err(e)
                        if e.kind() == io::ErrorKind::WouldBlock
                            || e.kind() == io::ErrorKind::TimedOut =>
                    {
                        break
                    }
                    // E.g. an ICMP unreachable surfacing as ECONNRESET:
                    // not fatal for the other flows.
                    Err(_) => break,
                };
                drained += got;
                for i in 0..got {
                    let (datagram, from) = batch.datagram(i);
                    ServerStats::bump(&stats.packets);
                    let rec = recorder.as_deref();
                    let Some((job_id, kind)) = peek_route(datagram) else {
                        ServerStats::bump(&stats.decode_errors);
                        trace_front(rec, 0, None, from, TraceNote::DecodeError, now);
                        continue;
                    };
                    if !slots.contains_key(&job_id) {
                        // Jobs are born only on Join; everything else gets
                        // the shared front-door treatment.
                        if kind != WireKind::Join {
                            match unknown_job_reply(job_id, kind, &stats) {
                                Some(reply) => {
                                    trace_front(
                                        rec,
                                        job_id,
                                        Some(kind),
                                        from,
                                        TraceNote::UnknownJob,
                                        now,
                                    );
                                    let _ = socket.send_to(&reply, from);
                                }
                                None => trace_front(
                                    rec,
                                    job_id,
                                    Some(kind),
                                    from,
                                    TraceNote::DownlinkSpoof,
                                    now,
                                ),
                            }
                            continue;
                        }
                        if slots.len() >= MAX_JOBS && !evict_unconfigured(&mut slots) {
                            ServerStats::bump(&stats.jobs_rejected);
                            trace_front(
                                rec,
                                job_id,
                                Some(kind),
                                from,
                                TraceNote::CapRejected,
                                now,
                            );
                            crate::warn!(
                                "job={job_id} rejected: {MAX_JOBS}-job cap, all slots configured"
                            );
                            continue;
                        }
                        let mut job = Job::with_budget(
                            job_id,
                            profile.clone(),
                            limits,
                            Arc::clone(&budget),
                            Arc::clone(&stats),
                        );
                        if let Some(r) = recorder.clone() {
                            job.attach_recorder(r);
                        }
                        slots.insert(
                            job_id,
                            Slot {
                                job,
                                lane: chaos
                                    .map(|cfg| ChaosLane::new(cfg, chaos_seed ^ job_id as u64)),
                                armed: None,
                            },
                        );
                    }
                    let slot = slots.get_mut(&job_id).expect("slot just ensured");
                    match decode_frame(datagram) {
                        Ok(frame) => {
                            let outp = slot.job.handle(&frame, from, now);
                            transmit(&socket, &mut slot.lane, &outp.frames, now);
                            slot.job.recycle(outp.frames);
                            // Arm the wheel on the None→Some edge, or when
                            // the job's deadline moved EARLIER than the
                            // armed entry — a quorum phase deadline can
                            // tighten an idle-reclaim one. The superseded
                            // later entry stays in the wheel and fires as a
                            // harmless stale wakeup (`on_tick` is
                            // idempotent and re-reports the real deadline).
                            if let Some(t) = outp.timer {
                                if slot.armed.is_none_or(|armed| t < armed) {
                                    wheel.insert(t, job_id);
                                    slot.armed = Some(t);
                                }
                            }
                        }
                        Err(_) => {
                            ServerStats::bump(&stats.decode_errors);
                            trace_front(rec, job_id, None, from, TraceNote::DecodeError, now);
                        }
                    }
                }
                if got < batch.depth() {
                    break; // socket drained
                }
            }
        }

        // ---- fire due timers -------------------------------------------
        let now = Instant::now();
        for job_id in wheel.pop_due(now) {
            let Some(slot) = slots.get_mut(&job_id) else {
                continue; // evicted since arming
            };
            if slot.armed.is_none() {
                continue; // stale entry (job re-admitted after eviction)
            }
            slot.armed = None;
            ServerStats::bump(&stats.idle_wakeups);
            // `on_tick` may run a wheel-granularity early for the job's
            // true deadline; it reaps only what is actually overdue and
            // returns the next deadline, which we re-arm.
            let outp = slot.job.on_tick(now);
            transmit(&socket, &mut slot.lane, &outp.frames, now);
            slot.job.recycle(outp.frames);
            if let Some(t) = outp.timer {
                wheel.insert(t, job_id);
                slot.armed = Some(t);
            }
        }

        // ---- flush chaos lanes -----------------------------------------
        for slot in slots.values_mut() {
            if let Some(l) = slot.lane.as_mut() {
                for (pkt, to) in l.flush_due(now) {
                    let _ = socket.send_to(&pkt, to);
                }
            }
        }
    }
}

/// Drop one slot whose job was never configured by a valid `Join`.
/// Returns false when every resident job is real (the cap then holds).
/// The dropped `Job` releases any budget reservation on drop.
fn evict_unconfigured(slots: &mut HashMap<u32, Slot>) -> bool {
    let victim =
        slots.iter().find(|(_, s)| !s.job.is_configured()).map(|(&id, _)| id);
    match victim {
        Some(id) => {
            slots.remove(&id);
            crate::debug!("job={id} evicted (never configured) to admit a new tenant");
            true
        }
        None => false,
    }
}
